"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["linear_warmup", "cosine_warmup"]


def linear_warmup(step, base_lr: float, warmup: int):
    s = step.astype(jnp.float32)
    return base_lr * jnp.minimum(1.0, (s + 1.0) / max(1, warmup))


def cosine_warmup(step, base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(1, warmup))
    prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos
