"""Decoupled AdamW with f32 master accumulators.

State is a pytree mirroring params (m, v in f32) plus a step counter —
shards exactly like the parameters under the same NamedSharding rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["OptState", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


OptState = dict  # {"m": pytree, "v": pytree, "step": scalar}


def adamw_init(params) -> OptState:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(
    params,
    grads,
    state: OptState,
    lr: jnp.ndarray | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    step = state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mh = m / c1
        vh = v / c2
        new_p = p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
