"""Optimizer substrate (in-house, no external deps)."""

from .adamw import adamw_init, adamw_update, OptState  # noqa: F401
from .schedules import cosine_warmup, linear_warmup  # noqa: F401
from .clip import global_norm, clip_by_global_norm  # noqa: F401
