"""Helpers shared by every Pallas kernel in the package."""

from __future__ import annotations

import jax

__all__ = ["default_interpret", "resolve_interpret"]


def default_interpret() -> bool:
    """Auto-detect: compile natively on TPU, interpret elsewhere."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the kernels' ``interpret: bool | None = None`` convention."""
    return default_interpret() if interpret is None else bool(interpret)
