"""Public attention op with GQA head mapping and pallas/jnp dispatch."""

from __future__ import annotations

import functools

import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_blocked, attention_ref

__all__ = ["gqa_attention", "merged_bh_constraint", "attention_fold_specs"]

# jnp path switches to blocked online-softmax attention above this kv length
BLOCKED_ATTN_THRESHOLD = 8192


def _axis_sizes(flags):
    import numpy as np

    mesh = flags.mesh
    dp = tuple(flags.dp)
    dp_n = int(np.prod([mesh.shape[a] for a in dp]))
    model_n = int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
    return dp, dp_n, model_n


def attention_fold_specs(flags, bh: int, lq: int, is_kv: bool = False):
    """Sharding policy for folded [B*H, L, D] attention tensors.

    Priority (DESIGN/EXPERIMENTS §Perf):
      1. merged batch*head over ALL axes (always even, no head padding) when
         bh divides dp*model;
      2. otherwise bh over dp + QUERY SEQUENCE over model (sequence-parallel
         attention — e.g. starcoder2: bh=32*36=1152 doesn't divide 256, but
         1152%16==0 and 32768%16==0); kv tensors stay batch-sharded only
         (their sequence dim is the contraction);
      3. otherwise bh over dp only;
      4. otherwise no constraint.
    Returns a PartitionSpec or None.
    """
    if flags is None or getattr(flags, "mesh", None) is None:
        return None
    from jax.sharding import PartitionSpec as P

    dp, dp_n, model_n = _axis_sizes(flags)
    if bh % (dp_n * model_n) == 0:
        return P((*dp, "model"), None, None)
    if bh % dp_n == 0 and lq % model_n == 0 and not is_kv:
        return P(dp, "model", None)
    if bh % dp_n == 0:
        return P(dp, None, None)
    return None


def constrain_folded(xf: jnp.ndarray, flags, bh: int, is_kv: bool = False):
    spec = attention_fold_specs(flags, bh, xf.shape[1], is_kv=is_kv)
    if spec is None:
        return xf
    import jax as _jax
    from jax.sharding import NamedSharding

    return _jax.lax.with_sharding_constraint(
        xf, NamedSharding(flags.mesh, spec)
    )


def merged_bh_constraint(xf: jnp.ndarray, flags, bh: int) -> jnp.ndarray:
    """Constrain a folded [B*H, L, D] tensor per `attention_fold_specs`."""
    return constrain_folded(xf, flags, bh)


def gqa_attention_folded(
    qf: jnp.ndarray,  # [B*Hq, Lq, D]  (b-major, consecutive heads per kv head)
    kf: jnp.ndarray,  # [B*Hkv, Lk, D]
    vf: jnp.ndarray,  # [B*Hkv, Lk, D]
    *,
    batch: int,
    causal: bool = True,
    use_pallas: bool = False,
    interpret: bool | None = None,
    block_q: int = 128,
    block_k: int = 1024,
    flags=None,
) -> jnp.ndarray:
    """GQA attention entirely in folded space.

    KV heads are broadcast to query heads with a reshape-broadcast in the
    merged dim (never `jnp.repeat` on [B, L, H, D] — uneven head sharding
    replicates there); the merged dim's sharding survives because the outer
    factors of the reshape are preserved.
    """
    bhq, lq, d = qf.shape
    bhkv, lk, _ = kf.shape
    hq, hkv = bhq // batch, bhkv // batch
    g = hq // hkv
    scale = 1.0 / (d ** 0.5)
    if g > 1:
        def rep(t):
            t = t.reshape(batch, hkv, 1, lk, d)
            t = jnp.broadcast_to(t, (batch, hkv, g, lk, d))
            return t.reshape(bhq, lk, d)
        kq, vq = rep(kf), rep(vf)
    else:
        kq, vq = kf, vf
    if use_pallas:
        return flash_attention_pallas(
            qf, kq, vq, scale=scale, causal=causal,
            block_q=block_q, block_k=block_k, interpret=interpret,
        )
    if lk > BLOCKED_ATTN_THRESHOLD:
        return attention_blocked(qf, kq, vq, scale=scale, causal=causal,
                                 block_k=block_k)
    return attention_ref(qf, kq, vq, scale=scale, causal=causal)


def gqa_attention(
    q: jnp.ndarray,  # [B, Lq, Hq, D]
    k: jnp.ndarray,  # [B, Lk, Hkv, D]
    v: jnp.ndarray,  # [B, Lk, Hkv, D]
    *,
    causal: bool = True,
    use_pallas: bool = False,
    interpret: bool | None = None,
    block_q: int = 128,
    block_k: int = 128,
    flags=None,
) -> jnp.ndarray:
    """Grouped-query attention on [B, L, H, D] tensors (wraps the folded
    implementation; models fold earlier themselves — see layers.attention).

    ``interpret=None`` auto-detects like every kernel here: native compile
    on TPU, Pallas interpreter elsewhere (`kernels.common.default_interpret`).
    """
    b, lq, hq, d = q.shape
    hkv = k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    fold = lambda x, h: x.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    qf = constrain_folded(fold(q, hq), flags, b * hq)
    kf = constrain_folded(fold(k, hkv), flags, b * hkv, is_kv=True)
    vf = constrain_folded(fold(v, hkv), flags, b * hkv, is_kv=True)
    of = gqa_attention_folded(
        qf, kf, vf, batch=b, causal=causal, use_pallas=use_pallas,
        interpret=interpret, block_q=block_q, block_k=block_k, flags=flags,
    )
    of = constrain_folded(of, flags, b * hq)
    return of.reshape(b, hq, lq, d).transpose(0, 2, 1, 3)
