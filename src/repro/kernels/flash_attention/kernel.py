"""Flash attention Pallas kernel (TPU): online-softmax tiled attention.

The perf-critical hot spot of every transformer arch in the zoo.  Standard
FlashAttention-2 scheme adapted to TPU VMEM tiling:

  grid = (batch*q_heads, num_q_blocks, num_kv_blocks)

with the running max / normalizer / accumulator kept in VMEM scratch across
the (sequential, innermost) kv-block axis and the output normalized and
emitted on the last kv block.  Causal masking skips fully-masked kv blocks
via `pl.when`.  Block sizes are BlockSpec parameters; MXU-aligned defaults
(128) are chosen by ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import resolve_interpret

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(
    q_ref,    # [1, Bq, D]
    k_ref,    # [1, Bk, D]
    v_ref,    # [1, Bk, D]
    o_ref,    # [1, Bq, D]
    m_ref,    # scratch [Bq]
    l_ref,    # scratch [Bq]
    acc_ref,  # scratch [Bq, D]
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
    seq_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [Bq, Bk]

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < seq_len
        if causal:
            mask &= rows >= cols
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_prev * alpha + p.sum(axis=-1)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )

    if causal:
        # skip kv blocks strictly above the diagonal band
        pl.when(k_start <= q_start + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == num_kv_blocks - 1)
    def _emit():
        l = l_ref[...]
        norm = jnp.where(l > 0, 1.0 / jnp.where(l > 0, l, 1.0), 0.0)
        o_ref[0] = (acc_ref[...] * norm[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "scale"),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [BH, Lq, D]
    k: jnp.ndarray,  # [BH, Lk, D]
    v: jnp.ndarray,  # [BH, Lk, D]
    *,
    scale: float,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)
    bh, lq, d = q.shape
    lk = k.shape[1]
    pad_q = (-lq) % block_q
    pad_k = (-lk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq = (lq + pad_q) // block_q
    nk = (lk + pad_k) // block_k

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=nk,
        seq_len=lk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lq + pad_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :lq]
