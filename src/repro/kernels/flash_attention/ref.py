"""Pure-jnp oracles: exact softmax attention + blocked (online-softmax)
variant for long sequences.

`attention_blocked` is the XLA-path equivalent of the Pallas flash kernel:
a `lax.scan` over kv blocks carrying (running max, normalizer, accumulator)
so the [L, L] score matrix is never materialized — required for the
prefill_32k / train_4k dry-run cells to fit HBM (an exact-softmax 32k x 32k
f32 score tensor is 4 GB per head).  Causal masking is applied per block
(the fully-masked upper blocks still execute — a 2x flop overhead on causal
traded for O(L*block) memory; see EXPERIMENTS.md §Perf)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "attention_blocked"]


def attention_ref(
    q: jnp.ndarray,  # [BH, Lq, D]
    k: jnp.ndarray,  # [BH, Lk, D]
    v: jnp.ndarray,  # [BH, Lk, D]
    *,
    scale: float,
    causal: bool = True,
) -> jnp.ndarray:
    f32 = jnp.float32
    s = jnp.einsum("bqd,bkd->bqk", q.astype(f32) * scale, k.astype(f32))
    if causal:
        lq, lk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(lq)[:, None] >= jnp.arange(lk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(f32)).astype(q.dtype)


def attention_blocked(
    q: jnp.ndarray,  # [BH, Lq, D]
    k: jnp.ndarray,  # [BH, Lk, D]
    v: jnp.ndarray,  # [BH, Lk, D]
    *,
    scale: float,
    causal: bool = True,
    block_k: int = 1024,
) -> jnp.ndarray:
    f32 = jnp.float32
    bh, lq, d = q.shape
    lk = k.shape[1]
    pad = (-lk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    nk = (lk + pad) // block_k
    qf = q.astype(f32) * scale
    kb = k.astype(f32).reshape(bh, nk, block_k, d).transpose(1, 0, 2, 3)
    vb = v.astype(f32).reshape(bh, nk, block_k, d).transpose(1, 0, 2, 3)
    rows = jnp.arange(lq)[None, :, None]  # [1, Lq, 1]

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        kc, vc, j = blk
        s = jnp.einsum("bqd,bkd->bqk", qf, kc)
        cols = j * block_k + jnp.arange(block_k)[None, None, :]
        mask = cols < lk
        if causal:
            mask &= rows >= cols
        s = jnp.where(mask, s, -1e30)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        pexp = jnp.exp(s - m_cur[..., None])
        l_cur = l_prev * alpha + pexp.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bqk,bkd->bqd", pexp, vc)
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((bh, lq), -1e30, f32)
    l0 = jnp.zeros((bh, lq), f32)
    a0 = jnp.zeros((bh, lq, d), f32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nk))
    )
    norm = jnp.where(l > 0, 1.0 / jnp.where(l > 0, l, 1.0), 0.0)
    return (acc * norm[..., None]).astype(q.dtype)
