"""Custom Pallas TPU kernels for the paper's compute hot-spots.

Three kernel families, each shipped as ``kernel.py`` (the Pallas kernel) +
``ops.py`` (staging/jit wrapper) + ``ref.py`` (pure-jnp oracle):

  * ``sptrsv``          — the accelerator's VLIW instruction-stream
    executor (VMEM-resident and row-blocked HBM-resident placements,
    DESIGN.md §1);
  * ``ssd_scan``        — the medium-granularity chunked linear recurrence
    (SSD / GLA / WKV) the sequence models run on;
  * ``flash_attention`` — blocked GQA attention for the hybrid archs.

`common.default_interpret` / `common.resolve_interpret` give every family
the same interpret auto-detect: native compile on TPU, interpreter
elsewhere.
"""
