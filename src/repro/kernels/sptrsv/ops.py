"""Jitted wrapper: run a compiled `Program` through the Pallas kernel."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.executor import as_batch, pad_batch
from repro.core.program import Program
from repro.core.schedule import PSUM_OVERFLOW_SLOTS

from .kernel import F_CTL, F_OP, F_OUT, F_SLT, F_SRC, N_FIELDS, sptrsv_pallas

__all__ = ["solve"]


def _pad_to(arr: np.ndarray, t_pad: int, fill=0) -> np.ndarray:
    t, p = arr.shape
    if t == t_pad:
        return arr
    out = np.full((t_pad, p), fill, dtype=arr.dtype)
    out[:t] = arr
    return out


def solve(
    prog: Program,
    b: np.ndarray,
    *,
    cycles_per_block: int = 128,
    interpret: bool | None = None,
) -> np.ndarray:
    """Solve Lx=b by executing `prog` in the Pallas kernel.

    ``b`` may be ``[n]`` (single RHS) or ``[n, B]`` (batched multi-RHS);
    the result has the matching shape.  Batched solves stream the
    instruction tensor once for all B columns; the batch axis is padded to
    a lane-friendly width (`pad_batch`) so nearby widths share one compile.

    ``interpret=None`` auto-detects: native compile on TPU, interpreter
    elsewhere.

    The wrapper performs the compiler-side data staging the hardware's
    stream memory provides: values are pre-gathered per instruction word so
    the kernel streams them sequentially (no positional indirection, as in
    the paper's stream-memory design), and the five int32 instruction
    planes are stacked into one ``[T, N_FIELDS, P]`` tensor so each cycle
    block arrives in VMEM with a single DMA.
    """
    bmat, single = as_batch(b)
    nb = bmat.shape[1]
    nb_pad = pad_batch(nb)

    t, p = prog.opcode.shape
    t_pad = -(-t // cycles_per_block) * cycles_per_block

    values = prog.stream[prog.val_idx]          # [T, P] pre-gathered
    values = values * (prog.opcode != 0)        # NOP lanes -> 0.0
    n_pad = prog.n + 1

    planes: list = [None] * N_FIELDS
    planes[F_OP] = _pad_to(prog.opcode.astype(np.int32), t_pad)
    planes[F_SRC] = _pad_to(prog.src_idx.astype(np.int32), t_pad)
    planes[F_OUT] = _pad_to(prog.out_idx.astype(np.int32), t_pad, fill=prog.n)
    planes[F_CTL] = _pad_to(prog.psum_ctrl.astype(np.int32), t_pad)
    planes[F_SLT] = _pad_to(prog.psum_slot.astype(np.int32), t_pad)
    instr = np.stack(planes, axis=1)  # [T, N_FIELDS, P]
    b_pad = np.zeros((n_pad, nb_pad), dtype=np.float32)
    b_pad[: prog.n, :nb] = bmat
    n_slots = max(prog.config.psum_words + PSUM_OVERFLOW_SLOTS,
                  prog.num_slots or 0)
    x = sptrsv_pallas(
        jnp.asarray(instr),
        jnp.asarray(_pad_to(values.astype(np.float32), t_pad)),
        jnp.asarray(b_pad),
        cycles_per_block=cycles_per_block,
        num_slots=n_slots,
        interpret=interpret,
    )
    x = np.asarray(x)[: prog.n, :nb]
    return x[:, 0] if single else x
