"""Compiler-side wrapper: run a compiled `Program` through the Pallas kernel.

Two memory placements for the solve state (DESIGN.md §1):

  * ``resident`` — x and b live in VMEM for the whole solve
    (`kernel.sptrsv_pallas`); fastest while ``2 * n_pad * B * 4`` bytes fit.
  * ``blocked``  — x and b stay in HBM and the kernel slides a row-blocked
    VMEM window over them (`kernel.sptrsv_pallas_blocked`), flushing and
    refilling at cycle-block boundaries with async DMA overlapped against
    compute.  This is the large-n path: VMEM use is bounded by the window,
    not by n.

``placement="auto"`` (the default) picks per solve: resident while the
x+b footprint is under ``vmem_limit_bytes``, blocked beyond it whenever the
program's row-access envelope admits a sliding window (`plan_window`).

The wrapper performs the compiler-side data staging the hardware's stream
memory provides: values are pre-gathered per instruction word so the kernel
streams them sequentially (no positional indirection, as in the paper's
stream-memory design), and the compiler's packed instruction words
(``Program.instr``, ``[T, planes, P]`` int32 — DESIGN.md §Perf,
"Instruction encoding") are padded to the cycle-block multiple so each
block arrives in VMEM with a single DMA.  Per lane-cycle the kernel
streams ``4 * planes + 4`` bytes (8 B in the single-plane regime) instead
of the 24 B the historical five unpacked planes cost.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.errors import PlacementInfeasibleError
from repro.core.executor import _psum_slots, as_batch
from repro.core.program import Program, decode_instructions

from .kernel import sptrsv_pallas, sptrsv_pallas_blocked

__all__ = [
    "solve",
    "plan_window",
    "resolve_placement",
    "build_solver_cols",
    "instr_buffer_bytes",
    "state_bytes",
    "WindowPlan",
    "DEFAULT_STATE_BYTES",
]

# auto-placement threshold for the VMEM x+b solve-state footprint.  Real
# TPU cores have ~16 MiB of VMEM shared with the instruction double
# buffers and the psum register file; 4 MiB of solve state is a
# comfortable default and is overridable per call (``vmem_limit_bytes``).
DEFAULT_STATE_BYTES = 4 << 20

_ROW_ALIGN = 8  # window/stride row granularity (f32 sublane tile)


@dataclasses.dataclass(frozen=True)
class WindowPlan:
    """A feasible sliding-window placement for the blocked kernel.

    Cycle block g executes against x/b rows ``[g*stride, g*stride +
    window)``; ``n_hbm`` is the padded HBM row count covering the full
    window sweep.  ``feasible=False`` carries a human-readable ``reason``
    (the auto path then falls back to the VMEM-resident placement).
    """

    feasible: bool
    stride: int = 0
    window: int = 0
    n_hbm: int = 0
    num_blocks: int = 0
    reason: str = ""

    def state_bytes(self, nb: int) -> int:
        """VMEM bytes for the double-buffered x+b windows."""
        return (2 * (self.window + 1) + 2 * self.window) * nb * 4


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def plan_window(
    prog: Program,
    cycles_per_block: int = 128,
    min_window: int | None = None,
) -> WindowPlan:
    """Derive a (stride, window) pair from the program's row-range metadata.

    The compiler records, per cycle, the min/max solution row any active
    lane touches (`Program.row_lo/row_hi`).  Reducing those over each cycle
    block gives the block's touched-row envelope ``[lo_g, hi_g]``; the
    window for block g is placed at base ``g * stride``, so feasibility
    requires ``g*stride <= lo_g`` and ``hi_g < g*stride + window`` for all
    g.  The stride is maximized (smallest window), then the window sized to
    the worst block — both rounded to the f32 sublane granularity.

    Programs whose row envelope does not advance monotonically enough
    (e.g. circuit matrices with hub columns read across the whole DAG)
    yield ``feasible=False``; such DAGs genuinely need the whole x vector
    live and must use the resident placement.
    """
    if prog.row_lo is None or prog.row_hi is None:
        return WindowPlan(False, reason="program has no row-range metadata "
                                        "(recompile with this version)")
    t = prog.cycles
    g = -(-t // cycles_per_block)
    lo = np.full(g * cycles_per_block, prog.n, dtype=np.int64)
    hi = np.full(g * cycles_per_block, -1, dtype=np.int64)
    lo[:t] = prog.row_lo
    hi[:t] = prog.row_hi
    lo = lo.reshape(g, cycles_per_block).min(axis=1)
    hi = hi.reshape(g, cycles_per_block).max(axis=1)
    nonempty = hi >= 0

    stride = prog.n
    for gi in range(1, g):
        if nonempty[gi]:
            stride = min(stride, int(lo[gi]) // gi)
    stride -= stride % _ROW_ALIGN
    if g > 1 and stride <= 0:
        return WindowPlan(False, reason="row envelope not monotone: an "
                                        "early row stays live across the "
                                        "whole schedule")
    if g == 1:
        stride = _ROW_ALIGN  # unused by a single-block sweep, but traced

    w_req = 0
    for gi in range(g):
        if nonempty[gi]:
            w_req = max(w_req, int(hi[gi]) - gi * stride + 1)
    window = max(w_req, 2 * stride, min_window or 0, 2 * _ROW_ALIGN)
    window = _round_up(window, _ROW_ALIGN)
    n_hbm = (g - 1) * stride + window
    return WindowPlan(True, stride=stride, window=window, n_hbm=n_hbm,
                      num_blocks=g)


def resolve_placement(
    prog: Program,
    nb: int,
    *,
    placement: str = "auto",
    vmem_limit_bytes: int | None = None,
    cycles_per_block: int = 128,
    x_block_rows: int | None = None,
) -> tuple[str, WindowPlan | None]:
    """Pick ``("resident", None)`` or ``("blocked", plan)`` for a solve.

    ``placement`` forces a regime (``"blocked"`` raises if the program's
    row envelope admits no window); ``"auto"`` compares the VMEM-resident
    x+b footprint for ``nb`` RHS columns against ``vmem_limit_bytes``
    (``None`` -> `DEFAULT_STATE_BYTES`) and only goes blocked when that
    saves memory and a window exists.  ``x_block_rows`` floors the planned
    window (perf knob; the planner still enlarges it to whatever the
    schedule requires).
    """
    if vmem_limit_bytes is None:
        vmem_limit_bytes = DEFAULT_STATE_BYTES
    if placement == "resident":
        return "resident", None
    if placement not in ("auto", "blocked"):
        raise ValueError(f"unknown placement {placement!r}")
    plan = plan_window(prog, cycles_per_block, min_window=x_block_rows)
    if placement == "blocked":
        if not plan.feasible:
            # taxonomy leaf (DESIGN.md §7); still a ValueError for
            # pre-taxonomy callers, and the fallback ladder treats it as
            # "this rung cannot serve this program" and degrades
            raise PlacementInfeasibleError(
                f"row-blocked placement infeasible: {plan.reason}",
                detail={"reason": plan.reason})
        return "blocked", plan
    resident_bytes = 2 * (prog.n + 1) * nb * 4
    if resident_bytes <= vmem_limit_bytes or not plan.feasible:
        return "resident", None
    if plan.state_bytes(nb) >= resident_bytes:
        return "resident", None  # window as big as the vector: no point
    return "blocked", plan


def _pad_to(arr: np.ndarray, t_pad: int, fill=0) -> np.ndarray:
    t = arr.shape[0]
    if t == t_pad:
        return arr
    out = np.full((t_pad,) + arr.shape[1:], fill, dtype=arr.dtype)
    out[:t] = arr
    return out


def _stage_instructions(prog: Program, cycles_per_block: int):
    """Pad the packed instruction words and pre-gather the stream values.

    The program already carries the packed ``[T, planes, P]`` words — the
    pack happens once at compile time; staging only pads to the cycle-block
    multiple (pad rows are the all-NOP word 0) and gathers the f32 values
    per instruction slot so the kernel streams them positionally.
    """
    t = prog.cycles
    t_pad = _round_up(t, cycles_per_block)
    values = prog.stream[prog.val_idx]          # [T, P] pre-gathered
    # transient decode for the NOP mask (don't touch the prog.opcode
    # property: it would pin all four decoded planes on the Program)
    op = decode_instructions(prog.instr, prog.planes)[0]
    values = values * (op != 0)                 # NOP lanes -> 0.0
    instr = _pad_to(prog.instr, t_pad)          # [T_pad, planes, P]
    return instr, _pad_to(values.astype(np.float32), t_pad)


def instr_buffer_bytes(prog: Program, cycles_per_block: int = 128) -> int:
    """VMEM bytes of the kernel's double-buffered instruction streaming.

    Two cycle-block buffers of packed words plus two of pre-gathered f32
    values: ``2 * tb * P * (4 * planes + 4)`` — halved-plus by the packed
    single-word encoding (planes=1: 8 B per buffered lane-cycle vs the 24 B
    of the historical five-plane layout).
    """
    return 2 * cycles_per_block * prog.num_cus * (4 * prog.planes + 4)


def state_bytes(prog: Program, nb: int, *, placement: str,
                plan: WindowPlan | None = None,
                cycles_per_block: int = 128) -> dict:
    """VMEM accounting of one Pallas solve: solve state + instruction buffers.

    Returns ``{"xb": ..., "instr": ..., "total": ...}`` bytes for ``nb``
    RHS columns under ``placement`` (``"blocked"`` needs the `WindowPlan`).
    """
    if placement == "blocked":
        if plan is None or not plan.feasible:
            raise ValueError("blocked accounting needs a feasible WindowPlan")
        xb = plan.state_bytes(nb)
    elif placement == "resident":
        xb = 2 * (prog.n + 1) * nb * 4
    else:
        raise ValueError(f"unknown placement {placement!r}")
    ib = instr_buffer_bytes(prog, cycles_per_block)
    return {"xb": xb, "instr": ib, "total": xb + ib}


def build_solver_cols(
    prog: Program,
    width: int,
    *,
    cycles_per_block: int = 128,
    placement: str = "auto",
    vmem_limit_bytes: int | None = None,
    x_block_rows: int | None = None,
    interpret: bool | None = None,
):
    """Build an unjitted ``solve(b[n, width]) -> x[n, width]`` closure.

    Stages the instruction tensors once (device-resident across calls),
    resolves the memory placement, and returns a closure suitable for the
    per-(program, knobs) executor cache (`executor.make_pallas_executor`).
    The chosen regime is exposed as ``closure.placement`` /
    ``closure.plan`` for tests and diagnostics.
    """
    mode, plan = resolve_placement(
        prog, width, placement=placement, vmem_limit_bytes=vmem_limit_bytes,
        cycles_per_block=cycles_per_block, x_block_rows=x_block_rows,
    )
    instr_np, values_np = _stage_instructions(prog, cycles_per_block)
    instr = jnp.asarray(instr_np)
    values = jnp.asarray(values_np)
    n = prog.n
    n_slots = _psum_slots(prog)
    n_rows = (n + 1) if mode == "resident" else plan.n_hbm

    @jax.jit  # fold the pad/slice into the kernel dispatch
    def solve_cols(bmat: jnp.ndarray) -> jnp.ndarray:
        bp = jnp.zeros((n_rows, width), jnp.float32)
        bp = bp.at[:n].set(jnp.asarray(bmat, jnp.float32))
        if mode == "resident":
            x = sptrsv_pallas(
                instr, values, bp, cycles_per_block=cycles_per_block,
                num_slots=n_slots, interpret=interpret,
            )
        else:
            x = sptrsv_pallas_blocked(
                instr, values, bp, window=plan.window, stride=plan.stride,
                cycles_per_block=cycles_per_block, num_slots=n_slots,
                interpret=interpret,
            )
        return x[:n]

    solve_cols.placement = mode
    solve_cols.plan = plan
    return solve_cols


def solve(
    prog: Program,
    b: np.ndarray,
    *,
    cycles_per_block: int = 128,
    interpret: bool | None = None,
    placement: str = "auto",
    vmem_limit_bytes: int = DEFAULT_STATE_BYTES,
    x_block_rows: int | None = None,
) -> np.ndarray:
    """Solve Lx=b by executing `prog` in the Pallas kernel.

    ``b`` may be ``[n]`` (single RHS) or ``[n, B]`` (batched multi-RHS);
    the result has the matching shape.  Batched solves stream the
    instruction tensor once for all B columns; the batch axis is padded to
    a lane-friendly width (`executor.pad_batch`) so nearby widths share one
    compile, and the underlying solver is cached per (program, padded
    width, placement knobs) — repeated solves never retrace.

    ``placement`` selects the memory regime (see module docstring);
    ``interpret=None`` auto-detects: native compile on TPU, interpreter
    elsewhere.
    """
    from repro.core.executor import make_pallas_executor

    bmat, single = as_batch(b)
    solver = make_pallas_executor(
        prog, batch=bmat.shape[1], cycles_per_block=cycles_per_block,
        placement=placement, vmem_limit_bytes=vmem_limit_bytes,
        x_block_rows=x_block_rows, interpret=interpret,
    )
    x = np.asarray(solver(bmat))
    return x[:, 0] if single else x
