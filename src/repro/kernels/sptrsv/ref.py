"""Pure-jnp oracle for the SpTRSV kernels.

Two oracles:
  * `solve_dense` — dense lower-triangular back-substitution in jnp
    (mathematical ground truth, independent of the compiler);
  * `solve_program` — the `lax.scan` executor over the instruction stream
    (checks the kernel against the exact program semantics).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.csr import TriCSR
from repro.core.executor import execute_jax
from repro.core.program import Program

__all__ = ["solve_dense", "solve_program"]


def solve_dense(mat: TriCSR, b: np.ndarray) -> np.ndarray:
    """jnp dense forward substitution (O(n^2), oracle only)."""
    dense = jnp.asarray(mat.to_dense(), dtype=jnp.float64)
    n = mat.n
    x = jnp.zeros(n, dtype=jnp.float64)

    def body(i, x):
        s = jnp.dot(dense[i, :], x)
        return x.at[i].set((b[i] - s + dense[i, i] * x[i]) / dense[i, i])

    import jax

    return np.asarray(jax.lax.fori_loop(0, n, body, x))


def solve_program(prog: Program, b: np.ndarray) -> np.ndarray:
    return execute_jax(prog, b)
