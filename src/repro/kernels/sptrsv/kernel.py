"""Pallas TPU kernel executing a compiled SpTRSV VLIW instruction stream.

TPU adaptation of the paper's accelerator (DESIGN.md §1):
  * the 64 CUs map onto a 64-wide vector lane dimension;
  * the x_i / psum register files and the solution vector live in VMEM
    scratch (the software-managed scratchpads of the paper);
  * the instruction stream stays in HBM (`pltpu.ANY`) and is streamed into
    VMEM in cycle blocks by explicit async DMA ("data in the instruction
    memory ... is accessed sequentially", §III-B);
  * stream-memory values are pre-gathered per instruction word by the
    compiler wrapper (ops.py), so the kernel reads them sequentially too.

Double-buffered cycle-block streaming: the kernel owns two VMEM instruction
buffers and, while executing cycle block g out of one buffer, prefetches
block g+1 into the other (`pltpu.make_async_copy` + per-slot DMA
semaphores).  Instruction HBM->VMEM traffic thus overlaps compute — the
software realization of the paper's sequential stream-memory pipeline.  The
RHS matrix b is a plain VMEM input loaded ONCE per solve (it used to ride a
grid BlockSpec that re-fetched the full [n_pad, B] matrix every cycle
block); the solve state (x, feedback, psum register file) is carried as
loop state across all blocks in a single kernel invocation.

The kernel is branch-free: every cycle executes the same gather/FMA/select/
scatter pattern for all lanes, with opcodes selecting behaviour via
`jnp.where` — the VLIW philosophy carried into the VPU.

Multi-RHS batching: the solve state carries a trailing batch axis
(`x[n_pad, B]`, `feedback[P, B]`, `rf[P, S, B]`), so one pass over the
instruction stream solves B right-hand sides — the instruction words
broadcast over the batch axis, amortizing instruction traffic exactly as
the VLIW program amortizes scheduling across CUs.

Two memory-placement regimes for the solve state (DESIGN.md §1):

  * `sptrsv_pallas` — x and b fully VMEM-resident.  Fastest while
    `x[n_pad, B]` + `b[n_pad, B]` fit; caps solvable n well below the
    paper's 85k-node DAGs on a real TPU.
  * `sptrsv_pallas_blocked` — x and b stay HBM-resident (`pltpu.ANY`); the
    kernel owns a row-blocked VMEM *window* of `window` solution rows that
    slides forward by a fixed `stride` rows per cycle block.  At each block
    boundary the `stride` rows that leave the window are flushed to HBM
    (they are final — the schedule metadata proves no later block touches
    them), the shared `window - stride` rows are carried across by a
    VMEM-to-VMEM copy, and the `stride` rows that enter the window are
    refilled from HBM by an async DMA issued one block early, overlapping
    the *previous* block's compute.  This is the level-boundary streaming
    of the solution vector: x rows retire monotonically as the schedule
    sweeps the DAG levels, exactly the traffic/compute overlap multi-GPU
    SpTRSV implementations use for large n.

The feasibility conditions (every block's touched-row envelope inside its
window; see `ops.plan_window`) are checked by the wrapper against the
compiler-emitted per-cycle row ranges (`Program.row_lo/row_hi`), so the
kernel itself stays branch-free and assert-free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.program import (
    OP_EDGE,
    OP_FINAL,
    PS_LOAD,
    PS_RESET,
    PS_STORE_RESET,
    PS_SWAP,
    decode_instructions,
)
from repro.kernels.common import default_interpret, resolve_interpret

__all__ = ["sptrsv_pallas", "sptrsv_pallas_blocked", "default_interpret"]


def _exec_cycle(instrs, vals, t, xw, fb, rf, bw, lanes, base, win_rows,
                dummy_row, planes):
    """One VLIW cycle over all lanes and RHS columns (shared by both
    placements).

    ``instrs`` is the packed ``[tb, planes, P]`` int32 cycle block; the
    fields are decoded in-register with the shared bitwise helper
    (`program.decode_instructions`) — the same format all three backends
    consume.  ``xw``/``bw`` hold solution/RHS rows ``[base, base +
    win_rows)`` (the whole padded vector with ``base=0`` in the
    VMEM-resident kernel, the sliding window in the blocked one);
    ``dummy_row`` absorbs the scatter of non-FINAL lanes.  Instruction row
    indices are rebased and clipped — active lanes are in-window by the
    wrapper's feasibility check, so the clip only tames NOP lanes' zero
    indices.  The write index is derived from ``(op, src)``: FINAL lanes
    write x[src], everything else the dummy row.
    """
    op, si, ct, sl = decode_instructions(instrs[t], planes)
    ct = ct[:, None]
    v = vals[t][:, None]                # [P, 1] broadcast over batch

    pv = fb
    slot_val = rf[lanes, sl]            # [P, B]
    pv = jnp.where(ct == PS_RESET, 0.0, pv)
    pv = jnp.where(ct == PS_LOAD, slot_val, pv)
    store_val = jnp.where(
        (ct == PS_STORE_RESET) | (ct == PS_SWAP), fb, slot_val
    )
    rf = rf.at[lanes, sl].set(store_val)
    pv = jnp.where(ct == PS_STORE_RESET, 0.0, pv)
    pv = jnp.where(ct == PS_SWAP, slot_val, pv)

    si_l = jnp.clip(si - base, 0, win_rows - 1)
    fin = (op == OP_FINAL)[:, None]
    pv = jnp.where(
        (op == OP_EDGE)[:, None], pv + v * jnp.take(xw, si_l, axis=0), pv
    )
    outv = (jnp.take(bw, si_l, axis=0) - pv) * v
    widx = jnp.where(op == OP_FINAL, si_l, dummy_row)
    xw = xw.at[widx].set(jnp.where(fin, outv, jnp.take(xw, widx, axis=0)))
    return xw, pv, rf


def _kernel(
    # inputs
    instr_ref,  # [T, planes, P] int32, HBM-resident (streamed by DMA)
    val_ref,    # [T, P]         f32,   HBM-resident (pre-gathered values)
    b_ref,      # [n_pad, B]     f32,   VMEM — loaded once per solve
    # outputs
    x_out_ref,  # [n_pad, B]     f32
    *,
    cycles_per_block: int,
    num_blocks: int,
    num_slots: int,
    planes: int,
):
    tb = cycles_per_block
    p = instr_ref.shape[-1]
    n_pad, nb = b_ref.shape
    lanes = jax.lax.iota(jnp.int32, p)
    b = b_ref[...]

    def body(ibuf, vbuf, isem, vsem):
        # ibuf/vbuf: [2, tb, ...] double buffers; one DMA semaphore per slot.
        def instr_dma(slot, g):
            return pltpu.make_async_copy(
                instr_ref.at[pl.ds(g * tb, tb)], ibuf.at[slot], isem.at[slot]
            )

        def val_dma(slot, g):
            return pltpu.make_async_copy(
                val_ref.at[pl.ds(g * tb, tb)], vbuf.at[slot], vsem.at[slot]
            )

        # warm-up: block 0 in flight before the block loop starts
        instr_dma(0, 0).start()
        val_dma(0, 0).start()

        def run_block(g, carry):
            slot = jax.lax.rem(g, 2)
            nxt = jax.lax.rem(g + 1, 2)

            # prefetch block g+1 into the other buffer while g executes
            @pl.when(g + 1 < num_blocks)
            def _prefetch():
                instr_dma(nxt, g + 1).start()
                val_dma(nxt, g + 1).start()

            instr_dma(slot, g).wait()
            val_dma(slot, g).wait()
            instrs = ibuf[slot]     # [tb, planes, P]
            vals = vbuf[slot]       # [tb, P]

            def cycle(t, c):
                x, fb, rf = c
                # base=0: absolute row indices; x[n_pad - 1] is the dummy row
                return _exec_cycle(instrs, vals, t, x, fb, rf, b, lanes,
                                   0, n_pad, n_pad - 1, planes)

            return jax.lax.fori_loop(0, tb, cycle, carry)

        x0 = jnp.zeros((n_pad, nb), jnp.float32)
        fb0 = jnp.zeros((p, nb), jnp.float32)
        rf0 = jnp.zeros((p, num_slots, nb), jnp.float32)
        x, _, _ = jax.lax.fori_loop(0, num_blocks, run_block, (x0, fb0, rf0))
        x_out_ref[...] = x

    pl.run_scoped(
        body,
        ibuf=pltpu.VMEM((2, tb, planes, p), jnp.int32),
        vbuf=pltpu.VMEM((2, tb, p), jnp.float32),
        isem=pltpu.SemaphoreType.DMA((2,)),
        vsem=pltpu.SemaphoreType.DMA((2,)),
    )


@functools.partial(
    jax.jit,
    static_argnames=("cycles_per_block", "num_slots", "interpret"),
)
def sptrsv_pallas(
    instr: jnp.ndarray,    # [T, planes, P] packed int32 (T padded to block multiple)
    values: jnp.ndarray,   # [T, P] f32 (pre-gathered stream values)
    b: jnp.ndarray,        # [n_pad, B] f32 (n + 1 dummy tail row)
    *,
    cycles_per_block: int = 128,
    num_slots: int = 12,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)
    t, planes, p = instr.shape
    assert planes in (1, 2), f"expected packed 1- or 2-plane words, got {planes}"
    assert t % cycles_per_block == 0, "pad the instruction stream first"
    num_blocks = t // cycles_per_block
    n_pad, nb = b.shape

    kernel = functools.partial(
        _kernel,
        cycles_per_block=cycles_per_block,
        num_blocks=num_blocks,
        num_slots=num_slots,
        planes=planes,
    )
    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),   # instr stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # values stay in HBM
            pl.BlockSpec(memory_space=pltpu.VMEM),  # b loaded once
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((n_pad, nb), jnp.float32),
        interpret=interpret,
    )(instr, values, b)


# ---------------------------------------------------------------------------
# Row-blocked HBM-resident placement (large n)
# ---------------------------------------------------------------------------
def _blocked_kernel(
    # inputs
    instr_ref,   # [T, planes, P] int32, HBM (streamed by DMA)
    val_ref,     # [T, P]         f32,   HBM (pre-gathered values)
    b_hbm_ref,   # [n_hbm, B]     f32,   HBM (windowed by DMA)
    # outputs
    x_hbm_ref,   # [n_hbm, B]     f32,   HBM (windowed by DMA)
    *,
    cycles_per_block: int,
    num_blocks: int,
    num_slots: int,
    window: int,
    stride: int,
    planes: int,
):
    """x/b HBM-resident solve over a sliding VMEM row window.

    Cycle block g executes against window rows ``[g*stride, g*stride +
    window)`` of x and b held in VMEM.  Boundary g -> g+1 (all async DMA):

      * flush  — rows ``[g*stride, (g+1)*stride)`` leave every later window;
        the schedule's feasibility check proves no later block touches
        them, so they are final and stream out to HBM;
      * shift  — the ``window - stride`` shared rows are copied into the
        other window buffer (VMEM -> VMEM, cheap);
      * refill — the ``stride`` rows entering window g+1 stream in from
        HBM.  The refill is issued at the TOP of block g, so it overlaps
        block g's compute (those rows are beyond window g, hence untouched
        by any flush up to and including boundary g — no hazard).

    The instruction/value/b-window prefetch reuses the double-buffer
    machinery of the VMEM-resident kernel.  Hazard ordering is enforced by
    waiting the boundary shift before issuing the next refill into the same
    buffer (the refill overwrites rows the shift read), and by keeping
    flush/refill HBM ranges disjoint (``window >= 2*stride``, checked by
    the wrapper).
    """
    tb = cycles_per_block
    p = instr_ref.shape[-1]
    nb = b_hbm_ref.shape[-1]
    w, r = window, stride
    lanes = jax.lax.iota(jnp.int32, p)

    def body(ibuf, vbuf, xwin, bwin, isem, vsem, bsem, xrsem, xssem, xfsem):
        # ibuf/vbuf: instruction double buffers (as in the resident kernel).
        # xwin: [2, w + 1, nb] — two x windows (row w is the NOP dummy row).
        # bwin: [2, w, nb]     — two b windows (read-only, full refetch).
        def instr_dma(slot, g):
            return pltpu.make_async_copy(
                instr_ref.at[pl.ds(g * tb, tb)], ibuf.at[slot], isem.at[slot]
            )

        def val_dma(slot, g):
            return pltpu.make_async_copy(
                val_ref.at[pl.ds(g * tb, tb)], vbuf.at[slot], vsem.at[slot]
            )

        def b_dma(slot, g):
            return pltpu.make_async_copy(
                b_hbm_ref.at[pl.ds(g * r, w)], bwin.at[slot, pl.ds(0, w)],
                bsem.at[slot],
            )

        def x_refill_dma(slot, g):
            # rows entering window g: [g*r + w - r, g*r + w)
            return pltpu.make_async_copy(
                x_hbm_ref.at[pl.ds(g * r + (w - r), r)],
                xwin.at[slot, pl.ds(w - r, r)],
                xrsem.at[slot],
            )

        def x_shift_dma(src_slot, dst_slot):
            # carry the shared rows of boundary g -> g+1 across buffers
            return pltpu.make_async_copy(
                xwin.at[src_slot, pl.ds(r, w - r)],
                xwin.at[dst_slot, pl.ds(0, w - r)],
                xssem,
            )

        def x_flush_dma(slot, g):
            # retire rows [g*r, g*r + r) — final, never touched again
            return pltpu.make_async_copy(
                xwin.at[slot, pl.ds(0, r)], x_hbm_ref.at[pl.ds(g * r, r)],
                xfsem,
            )

        # warm-up: block 0 inputs in flight before the block loop starts
        instr_dma(0, 0).start()
        val_dma(0, 0).start()
        b_dma(0, 0).start()

        def run_block(g, carry):
            fb, rf = carry
            slot = jax.lax.rem(g, 2)
            nxt = jax.lax.rem(g + 1, 2)

            # inputs for block g (prefetched during g-1; warm-up for g=0)
            instr_dma(slot, g).wait()
            val_dma(slot, g).wait()
            b_dma(slot, g).wait()

            @pl.when(g > 0)
            def _assemble():
                x_shift_dma(nxt, slot).wait()   # shared rows carried over
                x_refill_dma(slot, g).wait()    # entering rows (issued @ g-1)
                x_flush_dma(nxt, g - 1).wait()  # retired rows landed in HBM

            # prefetch block g+1.  The x refill into xwin[nxt] may only
            # start after the boundary shift read xwin[nxt] — guaranteed:
            # _assemble waited on that shift just above.
            @pl.when(g + 1 < num_blocks)
            def _prefetch():
                instr_dma(nxt, g + 1).start()
                val_dma(nxt, g + 1).start()
                b_dma(nxt, g + 1).start()
                x_refill_dma(nxt, g + 1).start()

            instrs = ibuf[slot]     # [tb, planes, P]
            vals = vbuf[slot]       # [tb, P]
            xw = xwin[slot]         # [w + 1, B]; row w is the dummy row
            bw = bwin[slot]         # [w, B]
            base = g * r

            def cycle(t, c):
                x_, fb_, rf_ = c
                return _exec_cycle(instrs, vals, t, x_, fb_, rf_, bw, lanes,
                                   base, w, w, planes)

            xw, fb, rf = jax.lax.fori_loop(0, tb, cycle, (xw, fb, rf))
            xwin[slot] = xw  # publish block-g writes for the boundary DMAs

            @pl.when(g + 1 < num_blocks)
            def _boundary():
                x_flush_dma(slot, g).start()
                x_shift_dma(slot, nxt).start()

            return fb, rf

        fb0 = jnp.zeros((p, nb), jnp.float32)
        rf0 = jnp.zeros((p, num_slots, nb), jnp.float32)
        jax.lax.fori_loop(0, num_blocks, run_block, (fb0, rf0))

        # final window: every still-resident row flushed in one DMA
        fin = pltpu.make_async_copy(
            xwin.at[jax.lax.rem(num_blocks - 1, 2), pl.ds(0, w)],
            x_hbm_ref.at[pl.ds((num_blocks - 1) * r, w)],
            xfsem,
        )
        fin.start()
        fin.wait()

    pl.run_scoped(
        body,
        ibuf=pltpu.VMEM((2, tb, planes, p), jnp.int32),
        vbuf=pltpu.VMEM((2, tb, p), jnp.float32),
        xwin=pltpu.VMEM((2, w + 1, nb), jnp.float32),
        bwin=pltpu.VMEM((2, w, nb), jnp.float32),
        isem=pltpu.SemaphoreType.DMA((2,)),
        vsem=pltpu.SemaphoreType.DMA((2,)),
        bsem=pltpu.SemaphoreType.DMA((2,)),
        xrsem=pltpu.SemaphoreType.DMA((2,)),
        xssem=pltpu.SemaphoreType.DMA,
        xfsem=pltpu.SemaphoreType.DMA,
    )


@functools.partial(
    jax.jit,
    static_argnames=("cycles_per_block", "num_slots", "window", "stride",
                     "interpret"),
)
def sptrsv_pallas_blocked(
    instr: jnp.ndarray,    # [T, planes, P] packed int32 (T padded to block multiple)
    values: jnp.ndarray,   # [T, P] f32 (pre-gathered stream values)
    b: jnp.ndarray,        # [n_hbm, B] f32 (padded to the window sweep)
    *,
    window: int,
    stride: int,
    cycles_per_block: int = 128,
    num_slots: int = 12,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Row-blocked HBM-resident solve (large n; see `ops.plan_window`).

    ``b`` must be padded to ``n_hbm = (num_blocks - 1) * stride + window``
    rows so every window position is in bounds; `ops.build_solver_cols`
    does this and derives a feasible (window, stride) pair from the
    program's row-range metadata.
    """
    interpret = resolve_interpret(interpret)
    t, planes, p = instr.shape
    assert planes in (1, 2), f"expected packed 1- or 2-plane words, got {planes}"
    assert t % cycles_per_block == 0, "pad the instruction stream first"
    num_blocks = t // cycles_per_block
    n_hbm, nb = b.shape
    assert stride >= 1 and window >= 2 * stride, (window, stride)
    assert n_hbm == (num_blocks - 1) * stride + window, \
        f"b rows {n_hbm} != window sweep {(num_blocks - 1) * stride + window}"

    kernel = functools.partial(
        _blocked_kernel,
        cycles_per_block=cycles_per_block,
        num_blocks=num_blocks,
        num_slots=num_slots,
        window=window,
        stride=stride,
        planes=planes,
    )
    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),   # instr stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # values stay in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # b stays in HBM
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),  # x stays in HBM
        out_shape=jax.ShapeDtypeStruct((n_hbm, nb), jnp.float32),
        interpret=interpret,
    )(instr, values, b)
