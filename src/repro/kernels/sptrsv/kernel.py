"""Pallas TPU kernel executing a compiled SpTRSV VLIW instruction stream.

TPU adaptation of the paper's accelerator (DESIGN.md §1):
  * the 64 CUs map onto a 64-wide vector lane dimension;
  * the x_i / psum register files and the solution vector live in VMEM
    scratch (the software-managed scratchpads of the paper);
  * the instruction stream stays in HBM (`pltpu.ANY`) and is streamed into
    VMEM in cycle blocks by explicit async DMA ("data in the instruction
    memory ... is accessed sequentially", §III-B);
  * stream-memory values are pre-gathered per instruction word by the
    compiler wrapper (ops.py), so the kernel reads them sequentially too.

Double-buffered cycle-block streaming: the kernel owns two VMEM instruction
buffers and, while executing cycle block g out of one buffer, prefetches
block g+1 into the other (`pltpu.make_async_copy` + per-slot DMA
semaphores).  Instruction HBM->VMEM traffic thus overlaps compute — the
software realization of the paper's sequential stream-memory pipeline.  The
RHS matrix b is a plain VMEM input loaded ONCE per solve (it used to ride a
grid BlockSpec that re-fetched the full [n_pad, B] matrix every cycle
block); the solve state (x, feedback, psum register file) is carried as
loop state across all blocks in a single kernel invocation.

The kernel is branch-free: every cycle executes the same gather/FMA/select/
scatter pattern for all lanes, with opcodes selecting behaviour via
`jnp.where` — the VLIW philosophy carried into the VPU.

Multi-RHS batching: the solve state carries a trailing batch axis
(`x[n_pad, B]`, `feedback[P, B]`, `rf[P, S, B]`), so one pass over the
instruction stream solves B right-hand sides — the instruction words
broadcast over the batch axis, amortizing instruction traffic exactly as
the VLIW program amortizes scheduling across CUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.program import (
    OP_EDGE,
    OP_FINAL,
    PS_LOAD,
    PS_RESET,
    PS_STORE_RESET,
    PS_SWAP,
)
from repro.kernels.common import default_interpret, resolve_interpret

__all__ = ["sptrsv_pallas", "default_interpret", "N_FIELDS",
           "F_OP", "F_SRC", "F_OUT", "F_CTL", "F_SLT"]

# int32 planes of the stacked instruction tensor [T, N_FIELDS, P]
F_OP, F_SRC, F_OUT, F_CTL, F_SLT = range(5)
N_FIELDS = 5


def _kernel(
    # inputs
    instr_ref,  # [T, N_FIELDS, P] int32, HBM-resident (streamed by DMA)
    val_ref,    # [T, P]           f32,   HBM-resident (pre-gathered values)
    b_ref,      # [n_pad, B]       f32,   VMEM — loaded once per solve
    # outputs
    x_out_ref,  # [n_pad, B]       f32
    *,
    cycles_per_block: int,
    num_blocks: int,
    num_slots: int,
):
    tb = cycles_per_block
    p = instr_ref.shape[-1]
    n_pad, nb = b_ref.shape
    lanes = jax.lax.iota(jnp.int32, p)
    b = b_ref[...]

    def body(ibuf, vbuf, isem, vsem):
        # ibuf/vbuf: [2, tb, ...] double buffers; one DMA semaphore per slot.
        def instr_dma(slot, g):
            return pltpu.make_async_copy(
                instr_ref.at[pl.ds(g * tb, tb)], ibuf.at[slot], isem.at[slot]
            )

        def val_dma(slot, g):
            return pltpu.make_async_copy(
                val_ref.at[pl.ds(g * tb, tb)], vbuf.at[slot], vsem.at[slot]
            )

        # warm-up: block 0 in flight before the block loop starts
        instr_dma(0, 0).start()
        val_dma(0, 0).start()

        def run_block(g, carry):
            slot = jax.lax.rem(g, 2)
            nxt = jax.lax.rem(g + 1, 2)

            # prefetch block g+1 into the other buffer while g executes
            @pl.when(g + 1 < num_blocks)
            def _prefetch():
                instr_dma(nxt, g + 1).start()
                val_dma(nxt, g + 1).start()

            instr_dma(slot, g).wait()
            val_dma(slot, g).wait()
            instrs = ibuf[slot]     # [tb, N_FIELDS, P]
            vals = vbuf[slot]       # [tb, P]

            def cycle(t, c):
                x, fb, rf = c
                op = instrs[t, F_OP]
                si = instrs[t, F_SRC]
                oi = instrs[t, F_OUT]
                ct = instrs[t, F_CTL][:, None]
                sl = instrs[t, F_SLT]
                v = vals[t][:, None]            # [P, 1] broadcast over batch

                pv = fb
                slot_val = rf[lanes, sl]        # [P, B]
                pv = jnp.where(ct == PS_RESET, 0.0, pv)
                pv = jnp.where(ct == PS_LOAD, slot_val, pv)
                store_val = jnp.where(
                    (ct == PS_STORE_RESET) | (ct == PS_SWAP), fb, slot_val
                )
                rf = rf.at[lanes, sl].set(store_val)
                pv = jnp.where(ct == PS_STORE_RESET, 0.0, pv)
                pv = jnp.where(ct == PS_SWAP, slot_val, pv)

                fin = (op == OP_FINAL)[:, None]
                pv = jnp.where(
                    (op == OP_EDGE)[:, None], pv + v * jnp.take(x, si, axis=0), pv
                )
                outv = (jnp.take(b, si, axis=0) - pv) * v
                widx = jnp.where(op == OP_FINAL, oi, n_pad - 1)  # dummy tail row
                x = x.at[widx].set(jnp.where(fin, outv, jnp.take(x, widx, axis=0)))
                return x, pv, rf

            return jax.lax.fori_loop(0, tb, cycle, carry)

        x0 = jnp.zeros((n_pad, nb), jnp.float32)
        fb0 = jnp.zeros((p, nb), jnp.float32)
        rf0 = jnp.zeros((p, num_slots, nb), jnp.float32)
        x, _, _ = jax.lax.fori_loop(0, num_blocks, run_block, (x0, fb0, rf0))
        x_out_ref[...] = x

    pl.run_scoped(
        body,
        ibuf=pltpu.VMEM((2, tb, N_FIELDS, p), jnp.int32),
        vbuf=pltpu.VMEM((2, tb, p), jnp.float32),
        isem=pltpu.SemaphoreType.DMA((2,)),
        vsem=pltpu.SemaphoreType.DMA((2,)),
    )


@functools.partial(
    jax.jit,
    static_argnames=("cycles_per_block", "num_slots", "interpret"),
)
def sptrsv_pallas(
    instr: jnp.ndarray,    # [T, N_FIELDS, P] int32 (T padded to block multiple)
    values: jnp.ndarray,   # [T, P] f32 (pre-gathered stream values)
    b: jnp.ndarray,        # [n_pad, B] f32 (n + 1 dummy tail row)
    *,
    cycles_per_block: int = 128,
    num_slots: int = 12,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)
    t, nf, p = instr.shape
    assert nf == N_FIELDS, f"expected {N_FIELDS} instruction fields, got {nf}"
    assert t % cycles_per_block == 0, "pad the instruction stream first"
    num_blocks = t // cycles_per_block
    n_pad, nb = b.shape

    kernel = functools.partial(
        _kernel,
        cycles_per_block=cycles_per_block,
        num_blocks=num_blocks,
        num_slots=num_slots,
    )
    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),   # instr stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # values stay in HBM
            pl.BlockSpec(memory_space=pltpu.VMEM),  # b loaded once
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((n_pad, nb), jnp.float32),
        interpret=interpret,
    )(instr, values, b)
