"""Pallas TPU kernel executing a compiled SpTRSV VLIW instruction stream.

TPU adaptation of the paper's accelerator (DESIGN.md §1):
  * the 64 CUs map onto a 64-wide vector lane dimension;
  * the x_i / psum register files and the solution vector live in VMEM
    scratch (the software-managed scratchpads of the paper);
  * the instruction stream is tiled HBM->VMEM in cycle blocks via BlockSpec
    ("data in the instruction memory ... is accessed sequentially", §III-B);
  * stream-memory values are pre-gathered per instruction word by the
    compiler wrapper (ops.py), so the kernel reads them sequentially too.

Grid: one dimension over cycle blocks; the solve state (x, feedback, psum
register file) is carried across grid steps in VMEM scratch, and x is
written to the output on the last step.

The kernel is branch-free: every cycle executes the same gather/FMA/select/
scatter pattern for all lanes, with opcodes selecting behaviour via
`jnp.where` — the VLIW philosophy carried into the VPU.

Multi-RHS batching: the solve state carries a trailing batch axis
(`x[n_pad, B]`, `feedback[P, B]`, `rf[P, S, B]`), so one pass over the
instruction stream solves B right-hand sides — the instruction words
broadcast over the batch axis, amortizing instruction traffic exactly as
the VLIW program amortizes scheduling across CUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.program import (
    OP_EDGE,
    OP_FINAL,
    PS_LOAD,
    PS_RESET,
    PS_STORE_RESET,
    PS_SWAP,
)

__all__ = ["sptrsv_pallas", "default_interpret"]


def default_interpret() -> bool:
    """Auto-detect: compile natively on TPU, interpret elsewhere."""
    return jax.default_backend() != "tpu"


def _kernel(
    # inputs (blocked over cycles)
    op_ref,     # [TB, P] int32
    val_ref,    # [TB, P] f32   (pre-gathered stream values)
    src_ref,    # [TB, P] int32
    out_ref,    # [TB, P] int32
    ctl_ref,    # [TB, P] int32
    slt_ref,    # [TB, P] int32
    b_ref,      # [n_pad, B]  f32  (whole matrix each step)
    # outputs
    x_out_ref,  # [n_pad, B]  f32
    # scratch
    x_ref,      # [n_pad, B]  f32
    fb_ref,     # [P, B]      f32
    rf_ref,     # [P, S, B]   f32
    *,
    cycles_per_block: int,
    num_blocks: int,
):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        x_ref[...] = jnp.zeros_like(x_ref)
        fb_ref[...] = jnp.zeros_like(fb_ref)
        rf_ref[...] = jnp.zeros_like(rf_ref)

    lanes = jax.lax.iota(jnp.int32, fb_ref.shape[0])
    b = b_ref[...]

    def cycle(t, carry):
        x, fb, rf = carry
        op = op_ref[t, :]
        v = val_ref[t, :][:, None]      # [P, 1] broadcast over batch
        si = src_ref[t, :]
        oi = out_ref[t, :]
        ct = ctl_ref[t, :][:, None]
        sl = slt_ref[t, :]

        pv = fb
        slot_val = rf[lanes, sl]        # [P, B]
        pv = jnp.where(ct == PS_RESET, 0.0, pv)
        pv = jnp.where(ct == PS_LOAD, slot_val, pv)
        store_val = jnp.where((ct == PS_STORE_RESET) | (ct == PS_SWAP), fb, slot_val)
        rf = rf.at[lanes, sl].set(store_val)
        pv = jnp.where(ct == PS_STORE_RESET, 0.0, pv)
        pv = jnp.where(ct == PS_SWAP, slot_val, pv)

        fin = (op == OP_FINAL)[:, None]
        pv = jnp.where((op == OP_EDGE)[:, None], pv + v * jnp.take(x, si, axis=0), pv)
        outv = (jnp.take(b, si, axis=0) - pv) * v
        widx = jnp.where(op == OP_FINAL, oi, x.shape[0] - 1)  # dummy tail row
        x = x.at[widx].set(jnp.where(fin, outv, jnp.take(x, widx, axis=0)))
        return x, pv, rf

    x, fb, rf = jax.lax.fori_loop(
        0, cycles_per_block, cycle, (x_ref[...], fb_ref[...], rf_ref[...])
    )
    x_ref[...] = x
    fb_ref[...] = fb
    rf_ref[...] = rf

    @pl.when(g == num_blocks - 1)
    def _emit():
        x_out_ref[...] = x


@functools.partial(
    jax.jit,
    static_argnames=("cycles_per_block", "num_slots", "interpret"),
)
def sptrsv_pallas(
    opcode: jnp.ndarray,   # [T, P] int32 (T padded to a block multiple)
    values: jnp.ndarray,   # [T, P] f32
    src_idx: jnp.ndarray,  # [T, P] int32
    out_idx: jnp.ndarray,  # [T, P] int32
    ctrl: jnp.ndarray,     # [T, P] int32
    slot: jnp.ndarray,     # [T, P] int32
    b: jnp.ndarray,        # [n_pad, B] f32 (n + 1 dummy tail row)
    *,
    cycles_per_block: int = 128,
    num_slots: int = 12,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    t, p = opcode.shape
    assert t % cycles_per_block == 0, "pad the instruction stream first"
    num_blocks = t // cycles_per_block
    n_pad, nb = b.shape

    instr_spec = pl.BlockSpec((cycles_per_block, p), lambda g: (g, 0))
    full_spec = pl.BlockSpec((n_pad, nb), lambda g: (0, 0))

    kernel = functools.partial(
        _kernel, cycles_per_block=cycles_per_block, num_blocks=num_blocks
    )
    return pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[instr_spec] * 6 + [full_spec],
        out_specs=full_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, nb), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((n_pad, nb), jnp.float32),
            pltpu.VMEM((p, nb), jnp.float32),
            pltpu.VMEM((p, num_slots, nb), jnp.float32),
        ],
        interpret=interpret,
    )(opcode, values, src_idx, out_idx, ctrl, slot, b)
