"""Pure-jnp sequential oracle for the chunked linear recurrence.

This is the "coarse dataflow" execution of the same recurrence: a plain
`lax.scan` carrying the [K, V] state one step at a time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["scan_ref"]


def scan_ref(
    q: jnp.ndarray,   # [BH, L, K]
    k: jnp.ndarray,   # [BH, L, K]
    v: jnp.ndarray,   # [BH, L, V]
    w: jnp.ndarray,   # [BH, L, K] log-decay
    s0: jnp.ndarray,  # [BH, K, V]
    *,
    inclusive: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    def one(s0_bh, qkvw):
        q_b, k_b, v_b, w_b = qkvw

        def step(s, inp):
            qt, kt, vt, wt = inp
            s_new = s * jnp.exp(wt)[:, None] + jnp.outer(kt, vt)
            y = (qt @ s_new) if inclusive else (qt @ s)
            return s_new, y

        s_fin, y = jax.lax.scan(step, s0_bh, (q_b, k_b, v_b, w_b))
        return y, s_fin

    f32 = jnp.float32
    y, sf = jax.vmap(one)(
        s0.astype(f32),
        (q.astype(f32), k.astype(f32), v.astype(f32), w.astype(f32)),
    )
    return y.astype(q.dtype), sf
