"""Public chunked-scan op: shape handling, decay clamping, RWKV u-bonus.

`linear_recurrence` is the single entry point used by the Mamba2 and RWKV6
blocks (repro.models).  It accepts [B, L, H, D]-shaped tensors, merges
batch/head dims, pads the sequence to the chunk size, and dispatches to the
Pallas kernel (TPU production path / interpret validation) or the chunked
pure-jnp path (`use_pallas=False`, used on CPU and in the distributed
dry-run — identical math, same chunking, no pallas_call).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import chunked_scan_pallas
from .ref import scan_ref

__all__ = ["linear_recurrence", "MIN_LOG_DECAY"]

# exp(-MIN_LOG_DECAY * chunk) must stay inside f32: 64 * 0.25 = 16 -> e^16 ~ 9e6.
MIN_LOG_DECAY = -0.25


def _chunked_jnp(q, k, v, w, s0, *, chunk: int, inclusive: bool):
    """Same medium-granularity algorithm as the kernel, in plain jnp."""
    bh, seq, kdim = q.shape
    vdim = v.shape[-1]
    nc = seq // chunk
    shp = lambda x, d: x.reshape(bh, nc, chunk, d)
    q, k, w = shp(q, kdim), shp(k, kdim), shp(w, kdim)
    v = shp(v, vdim)

    cums = jnp.cumsum(w, axis=2)
    total = cums[:, :, -1:, :]
    cums_q = cums if inclusive else cums - w
    qd = q * jnp.exp(cums_q)
    kd_neg = k * jnp.exp(-cums)
    kd_end = k * jnp.exp(total - cums)

    row = jnp.arange(chunk)[:, None]
    col = jnp.arange(chunk)[None, :]
    mask = (row >= col) if inclusive else (row > col)
    attn = jnp.einsum("bntk,bnsk->bnts", qd, kd_neg) * mask
    y_intra = jnp.einsum("bnts,bnsv->bntv", attn, v)

    def chunk_step(s, inp):
        qd_c, kd_c, v_c, tot_c = inp
        y_inter = qd_c @ s
        s_new = s * jnp.exp(tot_c).reshape(kdim, 1) + kd_c.T @ v_c
        return s_new, y_inter

    def per_bh(s0_b, qd_b, kd_b, v_b, tot_b):
        return jax.lax.scan(chunk_step, s0_b, (qd_b, kd_b, v_b, tot_b))

    sf, y_inter = jax.vmap(per_bh)(s0, qd, kd_end, v, total[:, :, 0, :])
    y = (y_intra + y_inter).reshape(bh, seq, vdim)
    return y, sf


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "inclusive", "use_pallas", "interpret", "flags"),
)
def linear_recurrence(
    q: jnp.ndarray,           # [B, L, H, K]
    k: jnp.ndarray,           # [B, L, H, K]
    v: jnp.ndarray,           # [B, L, H, V]
    log_decay: jnp.ndarray,   # [B, L, H, K], clamped to [MIN_LOG_DECAY, 0]
    s0: jnp.ndarray | None = None,   # [B, H, K, V]
    u_bonus: jnp.ndarray | None = None,  # [H, K] (RWKV exclusive mode)
    *,
    chunk: int = 64,
    inclusive: bool = True,
    use_pallas: bool = False,
    interpret: bool | None = None,
    flags=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, seq, h, kdim = q.shape
    vdim = v.shape[-1]
    in_dtype = q.dtype
    w = jnp.clip(log_decay, MIN_LOG_DECAY, 0.0)

    if seq <= 4:
        # decode fast path: direct recurrence steps — padding a 1-token
        # decode to a full chunk would waste chunk/seq x compute+memory
        f32 = jnp.float32
        s = (jnp.zeros((b, h, kdim, vdim), f32) if s0 is None
             else s0.astype(f32))
        ys = []
        for tstep in range(seq):
            qt, kt, vt, wt = (a[:, tstep].astype(f32) for a in (q, k, v, w))
            if not inclusive:
                y = jnp.einsum("bhk,bhkv->bhv", qt, s)
            s = s * jnp.exp(wt)[..., None] + jnp.einsum(
                "bhk,bhv->bhkv", kt, vt)
            if inclusive:
                y = jnp.einsum("bhk,bhkv->bhv", qt, s)
            ys.append(y)
        y = jnp.stack(ys, axis=1)                       # [B, seq, H, V]
        if u_bonus is not None:
            gate = jnp.einsum("blhk,hk,blhk->blh", q.astype(f32),
                              u_bonus.astype(f32), k.astype(f32))
            y = y + gate[..., None] * v.astype(f32)
        return y.astype(in_dtype), s

    pad = (-seq) % chunk
    if pad:
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v, w = zpad(q), zpad(k), zpad(v), zpad(w)
    seq_p = seq + pad

    from repro.kernels.flash_attention.ops import merged_bh_constraint

    merge = lambda x, d: merged_bh_constraint(
        x.transpose(0, 2, 1, 3).reshape(b * h, seq_p, d), flags, b * h
    )
    qm, km, wm = merge(q, kdim), merge(k, kdim), merge(w, kdim)
    vm = merge(v, vdim)
    s0m = (
        jnp.zeros((b * h, kdim, vdim), jnp.float32)
        if s0 is None
        else s0.reshape(b * h, kdim, vdim).astype(jnp.float32)
    )
    s0m = merged_bh_constraint(s0m, flags, b * h)

    f32 = jnp.float32
    if use_pallas:
        y, sf = chunked_scan_pallas(
            qm.astype(f32), km.astype(f32), vm.astype(f32), wm.astype(f32),
            s0m, chunk=chunk, inclusive=inclusive, interpret=interpret,
        )
    else:
        y, sf = _chunked_jnp(
            qm.astype(f32), km.astype(f32), vm.astype(f32), wm.astype(f32),
            s0m, chunk=chunk, inclusive=inclusive,
        )

    y = y.reshape(b, h, seq_p, vdim).transpose(0, 2, 1, 3)[:, :seq]
    if u_bonus is not None:
        # RWKV diagonal bonus: y_t += (q_t . (u ⊙ k_t)) v_t
        gate = jnp.einsum("blhk,hk,blhk->blh", q.astype(f32)[:, :seq],
                          u_bonus.astype(f32), k.astype(f32)[:, :seq])
        y = y + gate[..., None] * v.astype(f32)[:, :seq]
    return y.astype(in_dtype), sf.reshape(b, h, kdim, vdim)
