"""Chunked linear-recurrence (SSD/GLA/WKV) Pallas kernel.

This is the paper's medium-granularity dataflow instantiated for sequence
models (DESIGN.md §1): a gated linear recurrence

    S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T          (w_t <= 0: log-decay)
    y_t = S_t^T q_t            (inclusive — Mamba2/GLA convention)
    y_t = S_{t-1}^T q_t        (exclusive — RWKV convention; the u-bonus
                                diagonal term is added by ops.py)

is a unit-lower-bidiagonal SpTRSV in S.  The three dataflow granularities
map to: sequential scan (coarse), parallel prefix scan (fine, 2x ops), and
THIS kernel (medium): chunks of length Q are the "coarse allocation" — the
intra-chunk work is computed in parallel with MXU matmuls (fine edge
computation) while the inter-chunk state S is the psum feedback register
carried across grid steps in VMEM scratch.

Numerics: all exponentials are of non-positive arguments except the
intra-chunk `exp(-cums)` factor, which is bounded by exp(-Q * min w) —
ops.py clamps per-step log-decay so this stays within f32 (documented).

Grid: (batch*heads, num_chunks); TPU iterates the trailing axis fastest, so
for each (b,h) the chunks run sequentially and the state scratch carries.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import resolve_interpret

__all__ = ["chunked_scan_pallas"]


def _kernel(
    q_ref,   # [1, Q, K]
    k_ref,   # [1, Q, K]
    v_ref,   # [1, Q, V]
    w_ref,   # [1, Q, K]  log-decay (<= 0)
    s0_ref,  # [1, K, V]  initial state for this (b,h)
    y_ref,   # [1, Q, V]  output block
    sf_ref,  # [1, K, V]  final state output
    s_ref,   # scratch [K, V] f32
    *,
    num_chunks: int,
    inclusive: bool,
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = s0_ref[0]

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    s = s_ref[...]

    cums = jnp.cumsum(w, axis=0)            # [Q, K], inclusive
    total = cums[-1:, :]                    # [1, K]
    cums_q = cums if inclusive else cums - w

    qd = q * jnp.exp(cums_q)                # decay-from-chunk-start applied
    kd_neg = k * jnp.exp(-cums)             # bounded by ops.py decay clamp
    kd_end = k * jnp.exp(total - cums)      # decay-to-chunk-end (<= 1)

    qlen = q.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (qlen, qlen), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (qlen, qlen), 1)
    mask = (row >= col) if inclusive else (row > col)

    attn = jnp.dot(qd, kd_neg.T, preferred_element_type=jnp.float32)
    attn = jnp.where(mask, attn, 0.0)
    y = jnp.dot(attn, v, preferred_element_type=jnp.float32)       # intra-chunk
    y = y + jnp.dot(qd, s, preferred_element_type=jnp.float32)     # inter-chunk

    s_ref[...] = s * jnp.exp(total).T + jnp.dot(
        kd_end.T, v, preferred_element_type=jnp.float32
    )
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(c == num_chunks - 1)
    def _final():
        sf_ref[0] = s_ref[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "inclusive", "interpret")
)
def chunked_scan_pallas(
    q: jnp.ndarray,   # [BH, L, K]
    k: jnp.ndarray,   # [BH, L, K]
    v: jnp.ndarray,   # [BH, L, V]
    w: jnp.ndarray,   # [BH, L, K] log-decay
    s0: jnp.ndarray,  # [BH, K, V]
    *,
    chunk: int = 64,
    inclusive: bool = True,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    interpret = resolve_interpret(interpret)
    bh, seq, kdim = q.shape
    vdim = v.shape[-1]
    assert seq % chunk == 0, "pad sequence to a chunk multiple"
    nc = seq // chunk

    blk = lambda d: pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0))
    state_spec = pl.BlockSpec((1, kdim, vdim), lambda b, c: (b, 0, 0))

    kernel = functools.partial(_kernel, num_chunks=nc, inclusive=inclusive)
    y, sf = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[blk(kdim), blk(kdim), blk(vdim), blk(kdim), state_spec],
        out_specs=[blk(vdim), state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seq, vdim), q.dtype),
            jax.ShapeDtypeStruct((bh, kdim, vdim), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kdim, vdim), jnp.float32)],
        interpret=interpret,
    )(q, k, v, w, s0)
    return y, sf
