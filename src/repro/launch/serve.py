"""Serving launcher: batched prefill + decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --requests 8 --prefill-len 64 --decode-steps 32

Implements the production serving loop shape: a request queue, batched
prefill (padded to bucket sizes for compile-cache hits), then step-synced
batched decode against a pre-allocated KV cache with slot reuse.  On real
pods the same loop runs under the production mesh with the cache shardings
from repro.distributed (sequence-split KV — see sharding.py).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.distributed.sharding import dp_axes
from repro.launch.mesh import make_local_mesh
from repro.models import (
    RuntimeFlags,
    decode_step,
    init_cache,
    init_params,
    prefill,
)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prefill-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_local_mesh()
    flags = RuntimeFlags(use_pallas=False, interpret=False, remat=False,
                         mesh=mesh, dp=dp_axes(mesh))
    max_seq = args.max_seq or (args.prefill_len + args.decode_steps)

    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab, (args.requests, args.prefill_len))
    extra = {}
    if cfg.family == "vlm":
        extra["vision"] = jnp.zeros(
            (args.requests, cfg.vision_tokens, cfg.vision_dim), jnp.float32)
    if cfg.family == "encdec":
        extra["frames"] = jnp.zeros(
            (args.requests, cfg.enc_frames, cfg.d_model), jnp.float32)

    prefill_fn = jax.jit(
        lambda p, t: prefill(p, t, cfg, flags, extra, pad_to=max_seq)
    )
    decode_fn = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg, flags))

    t0 = time.perf_counter()
    logits, cache = prefill_fn(params, jnp.asarray(tokens, jnp.int32))
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.decode_steps):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = decode_fn(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out_tokens, axis=1)
    result = {
        "requests": args.requests,
        "prefill_tokens_per_s": args.requests * args.prefill_len / t_prefill,
        "decode_tokens_per_s": args.requests * args.decode_steps / t_decode,
        "sample_output": gen[0][:8].tolist(),
    }
    print(result)
    return result


if __name__ == "__main__":
    main()
