"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the appropriate step (train_step / prefill_step / serve_step)
     with ShapeDtypeStruct inputs and explicit NamedShardings,
  3. compiles, prints memory_analysis() (proves it fits) and
     cost_analysis() (flops/bytes),
  4. runs the trip-count-aware HLO analyzer for collective bytes,
  5. writes results/dryrun/<arch>__<shape>__<mesh>.json for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all          # driver: subprocess per cell
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any further jax import: jax locks the
# device count at first backend initialization (see the dry-run spec).


import argparse
import json
import subprocess
import sys
import time

import jax

from repro.configs import ASSIGNED_ARCHS, get_config, list_archs
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_skipped
from repro.launch.steps import build_cell
from repro.models import RuntimeFlags

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             remat: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_skipped(cfg, shape)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if skip:
        record["skipped"] = skip
        return record

    from repro.distributed.sharding import dp_axes

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    flags = RuntimeFlags(
        use_pallas=False, interpret=False,
        remat=(remat and shape.kind == "train"),
        mesh=mesh, dp=dp_axes(mesh),
    )
    fn, args, in_shardings, out_shardings = build_cell(cfg, shape, mesh, flags)

    t0 = time.perf_counter()
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_shardings,
                         out_shardings=out_shardings)
        lowered = jitted.lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()

    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(mem, attr):
                mem_rec[attr] = int(getattr(mem, attr))
    print(f"[{arch} x {shape_name} x {mesh_kind}] memory_analysis:", mem_rec)

    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else (ca or {})
    ca_rec = {k: float(v) for k, v in ca.items()
              if k in ("flops", "bytes accessed", "transcendentals")}
    print(f"[{arch} x {shape_name} x {mesh_kind}] cost_analysis:", ca_rec)

    hlo = hlo_analysis.analyze_hlo(compiled.as_text())
    record.update({
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory_analysis": mem_rec,
        "cost_analysis": ca_rec,
        "hlo": {k: float(v) for k, v in hlo.items()},
        "collective_bytes": float(hlo.collective_bytes),
        "devices": int(len(mesh.devices.reshape(-1))),
    })
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--all", action="store_true",
                    help="run every cell in a subprocess each")
    ap.add_argument("--meshes", default="pod,multipod")
    ap.add_argument("--out", default=os.path.normpath(RESULTS_DIR))
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch in ASSIGNED_ARCHS:
            for shape_name in SHAPES:
                for mesh_kind in args.meshes.split(","):
                    path = os.path.join(
                        args.out, f"{arch}__{shape_name}__{mesh_kind}.json"
                    )
                    if args.skip_existing and os.path.exists(path):
                        print("skip existing", path)
                        continue
                    cfg = get_config(arch)
                    if cell_skipped(cfg, SHAPES[shape_name]):
                        os.makedirs(args.out, exist_ok=True)
                        with open(path, "w") as f:
                            json.dump(run_cell(arch, shape_name, mesh_kind,
                                               args.out), f, indent=1)
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape_name,
                           "--mesh", mesh_kind, "--out", args.out]
                    print(">>", " ".join(cmd), flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append((arch, shape_name, mesh_kind))
        if failures:
            print("FAILED cells:", failures)
            sys.exit(1)
        print("all cells OK")
        return

    assert args.arch and args.shape
    rec = run_cell(args.arch, args.shape, args.mesh, args.out,
                   remat=not args.no_remat)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("hlo",)}, indent=1))


if __name__ == "__main__":
    main()
