"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first
device initialization.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods when multi_pod (512 chips total)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (smoke tests / single host)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
