"""Training launcher: end-to-end driver with checkpoint/restart + fault
tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

Production path (real TPU pods): the same loop runs under
`make_production_mesh()` with jax.distributed initialization per host; on
this CPU container it runs on a local mesh with reduced configs.

Fault-tolerance wiring (exercised by tests/test_fault_tolerance.py):
  * CheckpointManager saves asynchronously every --ckpt-every steps;
  * on startup the latest COMMITTED checkpoint is restored and the
    step-indexed data pipeline resumes exactly where it left off;
  * HeartbeatMonitor + StragglerPolicy watch simulated host heartbeats
    (single-host here); a detected failure triggers plan_remesh() and a
    restore-restart cycle (`--inject-failure` demonstrates it).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, list_archs
from repro.data import SyntheticLMDataset
from repro.distributed import HeartbeatMonitor, StragglerPolicy, plan_remesh
from repro.distributed.sharding import batch_sharding, dp_axes, param_shardings
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import abstract_params, make_train_step
from repro.models import RuntimeFlags, init_params
from repro.optim import adamw_init


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--inject-failure", type=int, default=0,
                    help="simulate a host failure at this step (demo/test)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (
        make_production_mesh() if args.production_mesh
        else make_local_mesh(args.model_axis)
    )
    flags = RuntimeFlags(
        use_pallas=False, interpret=False, remat=True,
        mesh=mesh, dp=dp_axes(mesh),
    )

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params)
    start_step = 0

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None:
        (restored, step) = ckpt.restore({"params": params, "opt": opt_state})
        if step is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = step
            print(f"restored checkpoint at step {step}")

    dataset = SyntheticLMDataset(cfg.vocab, args.seq, args.batch)
    monitor = HeartbeatMonitor(hosts=[0], timeout_s=300.0)
    stragglers = StragglerPolicy()

    p_shard = param_shardings(mesh, jax.eval_shape(lambda: params))
    train_step = jax.jit(
        make_train_step(cfg, flags, lr=args.lr, warmup=20, total=args.steps),
        in_shardings=(p_shard, None, None),
        out_shardings=(p_shard, None, None),
        donate_argnums=(0, 1),
    )

    losses = []
    step = start_step
    while step < args.steps:
        batch = dataset.batch(step)
        if cfg.family == "vlm":
            batch["vision"] = np.zeros(
                (args.batch, cfg.vision_tokens, cfg.vision_dim), np.float32
            )
        if cfg.family == "encdec":
            batch["frames"] = np.zeros(
                (args.batch, cfg.enc_frames, cfg.d_model), np.float32
            )
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        monitor.beat(0)
        stragglers.record_step({0: dt})

        if args.inject_failure and step == args.inject_failure:
            print(f"[FT] injected failure at step {step}")
            plan = plan_remesh(
                healthy_chips=max(1, len(jax.devices()) - 1),
                model_axis=args.model_axis, chips_per_pod=len(jax.devices()),
                per_replica_batch=args.batch,
            )
            print(f"[FT] re-mesh plan: {plan}")
            if ckpt is not None:
                ckpt.wait()
                (restored, rstep) = ckpt.restore(
                    {"params": params, "opt": opt_state}
                )
                if rstep is not None:
                    params, opt_state = restored["params"], restored["opt"]
                    step = rstep
                    print(f"[FT] rolled back to step {rstep}")
                    args.inject_failure = 0
                    continue
            args.inject_failure = 0

        step += 1
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"ppl {float(metrics['ppl']):.1f} {dt*1e3:.0f} ms")
        if ckpt is not None and step % args.ckpt_every == 0:
            ckpt.save_async(step, {"params": params, "opt": opt_state},
                            meta={"loss": loss})

    if ckpt is not None:
        ckpt.wait()
    result = {"first_loss": losses[0], "last_loss": losses[-1],
              "steps": len(losses)}
    print(result)
    return result


if __name__ == "__main__":
    main()
