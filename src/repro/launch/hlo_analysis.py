"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

XLA's builtin `cost_analysis()` counts `while` bodies ONCE (verified
empirically — a 10-step scan reports 1/10 of the true flops), which makes
it useless for scan-over-layers models.  This parser walks the computation
call graph with loop-trip multipliers and produces:

  * `collective_bytes` — per-device bytes moved by all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (result-shape bytes,
    async -start variants included, tuple shapes summed);
  * `dot_flops`       — 2 * prod(result_dims) * contraction_size for every
    dot, multiplied through loops;
  * `hbm_bytes`       — HBM-traffic proxy: result+operand bytes at fusion
    boundaries (fusion internals stay in registers/VMEM and are not
    counted), excluding pure control ops.

Trip counts are extracted from each while's condition computation (the
`constant(N)` compared against the induction variable); dynamic bounds
default to 1 with a warning flag.

Shapes are the PER-DEVICE (partitioned) shapes, so roofline terms divide
by per-chip peak rates directly (the global chips factor cancels).
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["analyze_hlo", "HloSummary"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_CALL_ATTRS = ("calls=", "body=", "to_apply=", "condition=")

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_CONTROL_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "copy", "after-all", "partition-id", "replica-id",
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


class HloSummary(dict):
    @property
    def collective_bytes(self) -> float:
        return sum(v for k, v in self.items() if k.startswith("coll/"))


def _parse_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m and ("->" in line):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _instr_parts(line: str):
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, result_type, opcode = m.groups()
    return name, result_type, opcode


def _operands(line: str) -> list[str]:
    m = re.search(r"\b[\w\-]+\((.*)$", line)
    if not m:
        return []
    body = m.group(1)
    return re.findall(r"%([\w\.\-]+)", body.split("),")[0] + ")")


def _called(line: str) -> list[tuple[str, str]]:
    out = []
    for attr in _CALL_ATTRS:
        for m in re.finditer(re.escape(attr) + r"%?([\w\.\-]+)", line):
            out.append((attr[:-1], m.group(1)))
    for m in re.finditer(r"branch_computations=\{([^}]*)\}", line):
        for name in re.findall(r"%?([\w\.\-]+)", m.group(1)):
            out.append(("branch", name))
    return out


def _trip_count(cond_lines: list[str]) -> int:
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def analyze_hlo(text: str) -> HloSummary:
    comps = _parse_computations(text)
    # shape map per computation: instr name -> result type text
    shapes: dict[str, dict[str, str]] = {}
    for cname, lines in comps.items():
        smap = {}
        for line in lines:
            p = _instr_parts(line)
            if p:
                smap[p[0]] = p[1]
        shapes[cname] = smap

    summary = HloSummary()
    summary.update({f"coll/{op}": 0.0 for op in COLLECTIVE_OPS})
    summary["dot_flops"] = 0.0
    summary["hbm_bytes"] = 0.0
    summary["dynamic_trip_warnings"] = 0.0
    counted_comm: set[str] = set()

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            entry = m.group(1) if m else None
    if entry is None:  # fall back: last computation
        entry = list(comps)[-1]

    def visit(cname: str, mult: float, count_bytes: bool, depth: int = 0):
        if depth > 64 or cname not in comps:
            return
        for line in comps[cname]:
            p = _instr_parts(line)
            if not p:
                continue
            name, rtype, opcode = p
            base = opcode.replace("-start", "")
            # ---- collectives (count the -start of async pairs once)
            if base in COLLECTIVE_OPS:
                key = f"coll/{base}"
                summary[key] += mult * _shape_bytes(rtype)
            # ---- dot flops
            if opcode == "dot":
                ops = _operands(line)
                lhs_shape = shapes[cname].get(ops[0], "") if ops else ""
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                contract = 1
                if cdims and lhs_shape:
                    parsed = _shape_dims(lhs_shape)
                    if parsed:
                        dims = parsed[0][1]
                        for i in cdims.group(1).split(","):
                            if i and int(i) < len(dims):
                                contract *= dims[int(i)]
                rdims = _shape_dims(rtype)
                rsize = 1
                if rdims:
                    for d in rdims[0][1]:
                        rsize *= d
                summary["dot_flops"] += mult * 2.0 * rsize * contract
            # ---- HBM traffic proxy at fusion boundaries
            if count_bytes and opcode not in _CONTROL_OPS:
                b = _shape_bytes(rtype)
                for op_name in _operands(line):
                    b += _shape_bytes(shapes[cname].get(op_name, ""))
                summary["hbm_bytes"] += mult * b
            # ---- descend
            for kind, callee in _called(line):
                if kind == "body":
                    cond = dict(_called(line)).get("condition")
                    trips = _trip_count(comps.get(cond, [])) if cond else 1
                    if trips == 1:
                        summary["dynamic_trip_warnings"] += 1
                    visit(callee, mult * trips, count_bytes, depth + 1)
                elif kind == "condition":
                    continue  # cheap; skip
                elif kind == "calls":  # fusion: flops yes, bytes no
                    visit(callee, mult, False, depth + 1)
                else:  # to_apply / branch
                    visit(callee, mult, count_bytes, depth + 1)

    visit(entry, 1.0, True)
    return summary
