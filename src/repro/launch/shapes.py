"""Assigned input-shape sets (the 40-cell grid) + skip rules."""

from __future__ import annotations

import dataclasses

__all__ = ["ShapeSpec", "SHAPES", "cells", "cell_skipped"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_skipped(cfg, shape: ShapeSpec) -> str | None:
    """Returns a skip reason or None (DESIGN.md §3)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: O(L^2) at 512k — long_500k assigned to sub-quadratic archs only"
    return None


def cells(configs: list) -> list[tuple]:
    return [(c, s) for c in configs for s in SHAPES.values()]
