"""Jitted step factories shared by train.py / serve.py / dryrun.py.

Builds (step_fn, example_inputs, in_shardings, out_shardings) per
(arch x shape x mesh) cell; inputs are ShapeDtypeStructs (no allocation) so
the same factory serves both the real launchers and the AOT dry-run.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import (
    batch_sharding,
    cache_shardings,
    dp_axes,
    param_shardings,
)
from repro.models import (
    RuntimeFlags,
    decode_step,
    init_cache,
    init_params,
    prefill,
    train_forward,
)
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_warmup

from .shapes import ShapeSpec

__all__ = ["abstract_params", "extra_specs", "make_train_step",
           "make_prefill_step", "make_decode_step", "build_cell"]


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


def abstract_opt_state(params_shape):
    return jax.eval_shape(adamw_init, params_shape)


def extra_specs(cfg: ModelConfig, batch: int) -> dict[str, jax.ShapeDtypeStruct]:
    """Stubbed modality-frontend inputs (precomputed embeddings)."""
    if cfg.family == "vlm":
        return {
            "vision": jax.ShapeDtypeStruct(
                (batch, cfg.vision_tokens, cfg.vision_dim), jnp.float32
            )
        }
    if cfg.family == "encdec":
        return {
            "frames": jax.ShapeDtypeStruct(
                (batch, cfg.enc_frames, cfg.d_model), jnp.float32
            )
        }
    return {}


def _extra_shardings(mesh, cfg, batch):
    dp = dp_axes(mesh)
    import numpy as np

    ok = batch % int(np.prod([mesh.shape[a] for a in dp])) == 0
    spec = P(dp if ok else None, None, None)
    return {k: NamedSharding(mesh, spec) for k in extra_specs(cfg, batch)}


def make_train_step(cfg: ModelConfig, flags: RuntimeFlags, *,
                    lr: float = 3e-4, warmup: int = 100, total: int = 10000,
                    clip_norm: float = 1.0):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
            loss, metrics = train_forward(
                p, batch["tokens"], batch["labels"], cfg, flags, extra
            )
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step_lr = cosine_warmup(opt_state["step"], lr, warmup, total)
        params, opt_state = adamw_update(params, grads, opt_state, step_lr)
        out = dict(metrics)
        out.update({"loss": loss, "grad_norm": gnorm, "lr": step_lr})
        return params, opt_state, out

    return train_step


def make_prefill_step(cfg: ModelConfig, flags: RuntimeFlags, pad_to: int | None = None):
    def prefill_step(params, batch):
        extra = {k: v for k, v in batch.items() if k != "tokens"}
        return prefill(params, batch["tokens"], cfg, flags, extra, pad_to=pad_to)

    return prefill_step


def make_decode_step(cfg: ModelConfig, flags: RuntimeFlags):
    def serve_step(params, token, cache):
        return decode_step(params, token, cache, cfg, flags)

    return serve_step


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, flags: RuntimeFlags):
    """Returns (fn, args, in_shardings, out_shardings_hint) for one cell."""
    p_shape = abstract_params(cfg)
    p_shard = param_shardings(mesh, p_shape)
    b, s = shape.global_batch, shape.seq_len
    tok_shard = batch_sharding(mesh, b)

    if shape.kind == "train":
        o_shape = abstract_opt_state(p_shape)
        o_shard = param_shardings(mesh, o_shape)
        # step counter is a scalar — replicate
        o_shard["step"] = NamedSharding(mesh, P())
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            **extra_specs(cfg, b),
        }
        b_shard: dict[str, Any] = {
            "tokens": tok_shard, "labels": tok_shard,
            **_extra_shardings(mesh, cfg, b),
        }
        fn = make_train_step(cfg, flags)
        return fn, (p_shape, o_shape, batch), (p_shard, o_shard, b_shard), (
            p_shard, o_shard, None
        )

    if shape.kind == "prefill":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            **extra_specs(cfg, b),
        }
        b_shard = {"tokens": tok_shard, **_extra_shardings(mesh, cfg, b)}
        fn = make_prefill_step(cfg, flags, pad_to=s)
        return fn, (p_shape, batch), (p_shard, b_shard), None

    # decode: one new token against a seq_len-deep cache
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    c_shard = cache_shardings(mesh, cfg, cache, b)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    t_shard = batch_sharding(mesh, b)
    fn = make_decode_step(cfg, flags)
    return fn, (p_shape, token, cache), (p_shard, t_shard, c_shard), None
