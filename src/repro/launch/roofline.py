"""§Roofline: three-term roofline from the dry-run artifacts.

Reads results/dryrun/<arch>__<shape>__<mesh>.json (written by dryrun.py) and
derives, per cell:

    compute_s    = dot_flops_per_device / PEAK_FLOPS        (trip-aware HLO)
    memory_s     = hbm_bytes_per_device / HBM_BW            (fusion-boundary)
    collective_s = collective_bytes_per_device / LINK_BW

(the per-device shapes in post-SPMD HLO make the global chips factor cancel
out of the spec formulas).  Also reports MODEL_FLOPS = 6*N*D (train) or
2*N*D (inference) on ACTIVE params, the useful/compiled compute ratio, the
dominant term, and an MFU-style roofline fraction:

    roofline_fraction = (model_flops/chips/PEAK) / max(terms)

Usage:  PYTHONPATH=src python -m repro.launch.roofline [--mesh pod] [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12    # bf16 / chip (TPU v5e-class, per the assignment)
HBM_BW = 819e9         # bytes/s per chip
LINK_BW = 50e9         # bytes/s per ICI link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def model_flops(rec: dict) -> float:
    n_active = rec["active_params"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n_active * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * rec["global_batch"]


def analyze_record(rec: dict) -> dict | None:
    if "skipped" in rec:
        return None
    hlo = rec["hlo"]
    dev = rec["devices"]
    compute_s = hlo["dot_flops"] / PEAK_FLOPS
    memory_s = hlo["hbm_bytes"] / HBM_BW
    coll_s = rec["collective_bytes"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful_ratio = mf / max(1.0, hlo["dot_flops"] * dev)
    ideal_s = mf / dev / PEAK_FLOPS
    frac = ideal_s / max(terms.values()) if max(terms.values()) > 0 else 0.0
    mem_an = rec.get("memory_analysis", {})
    hbm_gb = (mem_an.get("argument_size_in_bytes", 0)
              + mem_an.get("temp_size_in_bytes", 0)
              + mem_an.get("output_size_in_bytes", 0)) / 1e9
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful_ratio,
        "roofline_fraction": frac,
        "mem_gb_per_dev": hbm_gb,
        "compile_s": rec.get("compile_s"),
    }


def load_all(mesh: str | None = None, d: str | None = None) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(d or RESULTS_DIR, "*.json"))):
        rec = json.load(open(path))
        if mesh and rec.get("mesh") != mesh:
            continue
        row = analyze_record(rec)
        if row:
            out.append(row)
    return out


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s)"
           " | dominant | useful/compiled | roofline frac | HBM GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2%} "
            f"| {r['mem_gb_per_dev']:.1f} |\n"
        )
    return hdr + body


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, choices=[None, "pod", "multipod"])
    ap.add_argument("--dir", default=os.path.normpath(RESULTS_DIR))
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load_all(args.mesh, args.dir)
    if args.md:
        print(to_markdown(rows))
    else:
        for r in rows:
            print(json.dumps(r))


if __name__ == "__main__":
    main()
