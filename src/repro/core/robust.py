"""Hardened solve path: program integrity, numerical health, degradation.

Three layers over the existing compile/execute stack (DESIGN.md §7):

  * `verify_program` — a structural validator for compiled `Program`s.
    Everything the executors *assume* about an instruction stream is
    checked explicitly: packed-field ranges, zero-word NOP lanes,
    value-index bounds, finite stream values with non-zero FINAL
    reciprocals, psum slot capacity and slot *lifetimes*, each solution
    row finalized exactly once, dependency order, and the row-envelope
    metadata (``row_lo/row_hi``) re-derived from the words it summarizes.
    Since the static-analysis subsystem landed (DESIGN.md §8) this is a
    thin wrapper over `core.analysis.program_diagnostics` — one shared
    implementation with `compile_dag(verify_ir=True)` and the linter CLI;
    messages are unchanged.  Any violation is a `ProgramCorruptionError`.
  * `RobustSolver` — a health-checked wrapper over `api.make_solver`:
    input validation (shape, dtype, NaN/Inf in b), output checks
    (non-finite x, relative residual ``max|Lx-b| / max|b|`` against the
    retained `TriCSR`), and a deterministic fallback ladder
    pallas-blocked → pallas-resident → jax → numpy → reference with
    bounded per-stage retries, an optional per-stage deadline on an
    injectable clock, and machine-readable `Incident` records of what
    degraded and why.
  * `FaultInjector` + `run_fault_injection` — a seeded fault-injection
    harness (instruction-word bit flips, value-plane and serialized-blob
    corruption, poisoned right-hand sides, psum-slot rewrites) used by
    the test suite and `benchmarks/robust_overhead.py --smoke` to prove
    every fault class is either *detected* or *safely degraded* — never
    a silent wrong answer.  `run_ir_fault_injection` extends the harness
    one layer down: it mutates each intermediate IR of the staged
    compiler post-pass and asserts the per-pass contract verifiers
    (`core/analysis/contracts.py`) catch the mutation with the expected
    diagnostic code.
"""

from __future__ import annotations

import dataclasses
import os
import time
import zlib

import numpy as np

from .analysis import SEV_ERROR, program_diagnostics
from .csr import TriCSR, serial_solve
from .errors import (
    BackendExecutionError,
    NumericalHealthError,
    ProgramCorruptionError,
    RobustnessError,
)
from .executor import as_batch, execute_numpy, make_pallas_executor, make_jax_executor
from .program import (
    OP_EDGE,
    OP_FINAL,
    PS_LOAD,
    PS_STORE_RESET,
    PS_SWAP,
    AccelConfig,
    Program,
    decode_instructions,
)

__all__ = [
    "verify_program",
    "Incident",
    "RobustSolver",
    "FaultInjector",
    "run_fault_injection",
    "run_ir_fault_injection",
    "run_service_fault_injection",
    "csr_matvec",
    "relative_residual",
    "LADDER",
    "FAULT_CLASSES",
    "IR_FAULT_CLASSES",
    "SERVICE_FAULT_CLASSES",
]

# The deterministic degradation order.  A requested backend enters the
# ladder at its own rung and degrades rightward; "reference" (a direct
# serial solve from the retained TriCSR, independent of the compiled
# program) is only available when the solver retains the matrix.
LADDER = ("pallas-blocked", "pallas-resident", "jax", "numpy", "reference")
_ENTRY = {"pallas": 0, "jax": 2, "numpy": 3}


def verify_program(prog: Program) -> None:
    """Structurally validate a compiled `Program` (see module docstring).

    Raises `ProgramCorruptionError` naming the first violated invariant;
    returns None on a clean program.  Pure numpy, no executor is touched —
    safe to run on untrusted/deserialized programs before any solve.

    Thin wrapper over the shared static analyzer
    (`core.analysis.program_diagnostics`): the hazard checks run in the
    historical order and the raised message is the first error
    diagnostic's, verbatim, so callers matching on messages are
    unaffected; the diagnostic code rides along in ``detail["code"]``.
    """
    for d in program_diagnostics(prog):
        if d.severity == SEV_ERROR:
            anchors = {k: v for k, v in
                       (("cycle", d.cycle), ("cu", d.cu), ("node", d.node))
                       if v is not None}
            raise ProgramCorruptionError(
                f"program integrity: {d.message}",
                detail={**anchors, **d.detail, "code": d.code})


# ---------------------------------------------------------------------------
# numerical health helpers
# ---------------------------------------------------------------------------
def csr_matvec(mat: TriCSR, x: np.ndarray) -> np.ndarray:
    """``L @ x`` for the retained CSR; ``x`` is ``[n]`` or ``[n, B]``."""
    prod = mat.values[:, None] * np.asarray(x, dtype=np.float64)[mat.colidx]
    return np.add.reduceat(prod, mat.rowptr[:-1].astype(np.intp), axis=0)


def _matvec_fn(mat: TriCSR):
    """``x -> L @ x`` closure: scipy's C matvec when the host has scipy
    (an order of magnitude faster on the per-solve residual check),
    `csr_matvec` otherwise."""
    try:
        import scipy.sparse as sp
    except ImportError:  # pragma: no cover - container ships scipy
        return lambda x: csr_matvec(mat, x)
    a = sp.csr_matrix((mat.values, mat.colidx, mat.rowptr),
                      shape=(mat.n, mat.n))
    return lambda x: a @ x


def _relative_residual(matvec, x: np.ndarray, b: np.ndarray) -> float:
    xm, _ = as_batch(np.asarray(x, dtype=np.float64))
    bm, _ = as_batch(np.asarray(b, dtype=np.float64))
    num = np.abs(matvec(xm) - bm).max()
    den = max(np.abs(bm).max(), np.finfo(np.float64).tiny)
    return float(num / den)


def relative_residual(mat: TriCSR, x: np.ndarray, b: np.ndarray) -> float:
    """``max|Lx - b| / max|b|`` over all RHS columns (∞-norm, relative)."""
    return _relative_residual(_matvec_fn(mat), x, b)


@dataclasses.dataclass(frozen=True)
class Incident:
    """One machine-readable degradation/detection event of a `RobustSolver`."""

    stage: str          # ladder rung ("pallas-blocked", ..., "reference")
    kind: str           # "exception" | "nonfinite-output" | "residual"
                        # | "deadline" | "build-failed" | "input"
    message: str
    error: str = ""     # exception class name, "" for health-check events
    attempt: int = 1
    elapsed_s: float = 0.0
    detail: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class RobustSolver:
    """Health-checked, gracefully degrading solve wrapper (DESIGN.md §7).

    ``prog`` is the compiled program; ``mat`` (optional but recommended)
    is the `TriCSR` it was compiled from — retaining it enables the
    relative-residual output check and the final "reference" ladder rung,
    which solves directly from the CSR and therefore returns a *correct*
    answer even when the program itself is corrupt.

    Parameters
    ----------
    backend : entry rung — "pallas" starts at pallas-blocked, "jax"
        (default) at the `lax.scan` executor, "numpy" at the oracle.
    verify : run `verify_program` once at construction (default True).
    check_inputs / check_outputs : per-solve health checks (default on).
    residual_tol : relative ∞-norm residual threshold (needs ``mat``);
        ``None`` disables the residual check.
    max_retries : extra attempts per rung after an *exception* (health
        failures are deterministic and never retried).
    stage_deadline_s : wall-clock budget per rung, measured on ``clock``;
        a rung that exceeds it is recorded and disabled for subsequent
        solves.  ``None`` (default) disables deadlines.
    clock : injectable monotonic clock (seconds), for deterministic tests.
    backend_opts : forwarded to the Pallas rungs (``cycles_per_block``,
        ``vmem_limit_bytes``, ``interpret``, ...).

    Solves accept ``b`` of shape ``[n]`` or ``[n, B]``.  Every detection
    and degradation appends an `Incident` to ``last_incidents`` (per
    solve) and ``incidents`` (lifetime); a solve that exhausts the ladder
    raises the classified exception with the incident trail attached to
    ``.detail["incidents"]``.
    """

    def __init__(self, prog: Program, mat: TriCSR | None = None, *,
                 backend: str = "jax", verify: bool = True,
                 check_inputs: bool = True, check_outputs: bool = True,
                 residual_tol: float | None = 1e-3, max_retries: int = 1,
                 stage_deadline_s: float | None = None,
                 clock=time.perf_counter, ladder: tuple[str, ...] | None = None,
                 **backend_opts):
        if backend not in _ENTRY:
            from .errors import UnknownBackendError

            raise UnknownBackendError(
                f"unknown backend {backend!r} (choose from "
                f"{sorted(_ENTRY)})")
        if verify:
            verify_program(prog)
        self.prog = prog
        self.mat = mat
        self.check_inputs = check_inputs
        self.check_outputs = check_outputs
        self.residual_tol = residual_tol if mat is not None else None
        self.max_retries = max(0, int(max_retries))
        self.stage_deadline_s = stage_deadline_s
        self.clock = clock
        self.backend_opts = dict(backend_opts)
        stages = ladder if ladder is not None else LADDER[_ENTRY[backend]:]
        if mat is None:
            stages = tuple(s for s in stages if s != "reference")
        self.ladder = tuple(stages)
        self._matvec = None if mat is None else _matvec_fn(mat)
        self._disabled: set[str] = set()
        self._solvers: dict[tuple, object] = {}
        self.incidents: list[Incident] = []
        self.last_incidents: list[Incident] = []
        self.last_stage: str = ""  # rung that produced the last answer

    # -- stage plumbing ----------------------------------------------------
    def _solver_for(self, stage: str, batch: int | None):
        key = (stage, batch)
        fn = self._solvers.get(key)
        if fn is not None:
            return fn
        if stage == "pallas-blocked":
            fn = make_pallas_executor(self.prog, batch=batch,
                                      placement="blocked",
                                      **self.backend_opts)
        elif stage == "pallas-resident":
            fn = make_pallas_executor(self.prog, batch=batch,
                                      placement="resident",
                                      **self.backend_opts)
        elif stage == "jax":
            fn = make_jax_executor(self.prog, batch=batch)
        elif stage == "numpy":
            fn = lambda b: execute_numpy(self.prog, b)  # noqa: E731
        elif stage == "reference":
            mat = self.mat

            def fn(b):
                bm, single = as_batch(np.asarray(b, dtype=np.float64))
                x = np.stack([serial_solve(mat, bm[:, j])
                              for j in range(bm.shape[1])], axis=1)
                return x[:, 0] if single else x
        else:
            raise ValueError(f"unknown ladder stage {stage!r}")
        self._solvers[key] = fn
        return fn

    def _record(self, stage: str, kind: str, message: str, *, error: str = "",
                attempt: int = 1, elapsed_s: float = 0.0,
                detail: dict | None = None) -> Incident:
        inc = Incident(stage=stage, kind=kind, message=message, error=error,
                       attempt=attempt, elapsed_s=float(elapsed_s),
                       detail=dict(detail or {}))
        self.last_incidents.append(inc)
        self.incidents.append(inc)
        return inc

    # -- health checks -----------------------------------------------------
    def residual(self, x: np.ndarray, b: np.ndarray) -> float:
        """Relative ∞-norm residual via the solver's cached CSR matvec."""
        if self._matvec is None:
            raise ValueError("residual check needs the retained TriCSR "
                             "(construct with mat=...)")
        return _relative_residual(self._matvec, x, b)

    def _check_input(self, b: np.ndarray) -> np.ndarray:
        try:
            b = np.asarray(b, dtype=np.float64)
        except (TypeError, ValueError) as e:
            raise NumericalHealthError(
                f"right-hand side not numeric: {e}") from e
        if b.ndim not in (1, 2) or b.shape[0] != self.prog.n:
            raise NumericalHealthError(
                f"right-hand side must be [n] or [n, B] with n={self.prog.n},"
                f" got shape {b.shape}", detail={"shape": list(b.shape)})
        bad = ~np.isfinite(b)
        if bad.any():
            idx = np.argwhere(bad)[0]
            raise NumericalHealthError(
                f"right-hand side carries {int(bad.sum())} non-finite "
                f"entr{'y' if bad.sum() == 1 else 'ies'} (first at "
                f"index {tuple(int(i) for i in idx)})",
                detail={"non_finite": int(bad.sum())})
        return b

    def _check_output(self, x: np.ndarray, b: np.ndarray, stage: str,
                      elapsed: float) -> bool:
        xa = np.asarray(x)
        if not np.isfinite(xa).all():
            self._record(stage, "nonfinite-output",
                         f"{int(np.count_nonzero(~np.isfinite(xa)))} "
                         f"non-finite solution component(s)",
                         elapsed_s=elapsed)
            return False
        if self.check_outputs and self.residual_tol is not None:
            rel = self.residual(xa, b)
            if not rel <= self.residual_tol:
                self._record(stage, "residual",
                             f"relative residual {rel:.3e} exceeds "
                             f"tolerance {self.residual_tol:.1e}",
                             elapsed_s=elapsed, detail={"residual": rel})
                return False
        return True

    # -- the solve ---------------------------------------------------------
    def solve(self, b: np.ndarray) -> np.ndarray:
        """Solve Lx=b through the ladder; see class docstring."""
        self.last_incidents = []
        if self.check_inputs:
            b = self._check_input(b)
        else:
            b = np.asarray(b, dtype=np.float64)
        single = b.ndim == 1
        batch = None if single else b.shape[1]

        for stage in self.ladder:
            if stage in self._disabled:
                continue
            try:
                solver = self._solver_for(stage, batch)
            except Exception as e:  # placement infeasible, build failure
                self._record(stage, "build-failed", str(e),
                             error=type(e).__name__)
                self._disabled.add(stage)
                continue
            for attempt in range(1, self.max_retries + 2):
                t0 = self.clock()
                try:
                    x = np.asarray(solver(b.astype(np.float64)
                                          if stage in ("numpy", "reference")
                                          else b))
                except Exception as e:
                    self._record(stage, "exception", str(e),
                                 error=type(e).__name__, attempt=attempt,
                                 elapsed_s=self.clock() - t0)
                    continue  # bounded retry of the same rung
                elapsed = self.clock() - t0
                if (self.stage_deadline_s is not None
                        and elapsed > self.stage_deadline_s):
                    self._record(stage, "deadline",
                                 f"stage took {elapsed:.3f}s > deadline "
                                 f"{self.stage_deadline_s:.3f}s",
                                 attempt=attempt, elapsed_s=elapsed)
                    self._disabled.add(stage)
                    break  # degrade; do not trust an over-deadline rung
                if not self.check_outputs:
                    self.last_stage = stage
                    return x
                if self._check_output(x, b, stage, elapsed):
                    self.last_stage = stage
                    return x
                break  # health failures are deterministic: degrade

        trail = [i.to_dict() for i in self.last_incidents]
        kinds = {i.kind for i in self.last_incidents}
        msg = (f"all ladder stages failed for n={self.prog.n} solve "
               f"({len(trail)} incident(s); stages {list(self.ladder)})")
        if kinds & {"nonfinite-output", "residual"}:
            raise NumericalHealthError(msg, detail={"incidents": trail})
        raise BackendExecutionError(msg, detail={"incidents": trail})

    __call__ = solve


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------
FAULT_CLASSES = ("instr_bit_flip", "psum_slot", "value_plane_nan",
                 "value_plane_scale", "blob", "rhs_nan", "rhs_inf")


def _copy_program(prog: Program) -> Program:
    return dataclasses.replace(
        prog,
        instr=prog.instr.copy(),
        val_idx=prog.val_idx.copy(),
        stream=prog.stream.copy(),
        row_lo=None if prog.row_lo is None else prog.row_lo.copy(),
        row_hi=None if prog.row_hi is None else prog.row_hi.copy(),
    )


class FaultInjector:
    """Seeded fault source for the robustness test suite (DESIGN.md §7).

    Every method returns a *new* corrupted object; the input is never
    mutated.  The generator is owned by the injector, so a fixed seed
    yields a reproducible fault sequence across runs.
    """

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def flip_instr_bits(self, prog: Program, flips: int = 1) -> Program:
        """Flip ``flips`` random bits in the packed instruction words."""
        out = _copy_program(prog)
        flat = out.instr.reshape(-1)
        for _ in range(flips):
            i = int(self.rng.integers(flat.size))
            bit = int(self.rng.integers(31))  # the packed fields' bits
            flat[i] = np.int32(int(flat[i]) ^ (1 << bit))
        return out

    def corrupt_slots(self, prog: Program, k: int = 1) -> Program:
        """Rewrite the psum-slot field of ``k`` random slot-using lanes.

        Targets lanes whose control actually reads or writes the slot
        (LOAD / STORE_RESET / SWAP — a RESET lane's slot field is dead);
        programs with no such traffic are returned unchanged.
        """
        out = _copy_program(prog)
        op, src, ctl, slot = decode_instructions(out.instr, out.planes)
        ev = np.argwhere((ctl == PS_LOAD) | (ctl == PS_STORE_RESET)
                         | (ctl == PS_SWAP))
        if not ev.size:
            return out
        from .program import pack_instructions

        slot = slot.copy()
        for _ in range(k):
            t, p = ev[int(self.rng.integers(len(ev)))]
            slot[t, p] = int(self.rng.integers(256))
        out.instr = pack_instructions(op, src, ctl, slot, planes=out.planes)
        return out

    def corrupt_stream(self, prog: Program, k: int = 1,
                       mode: str = "nan") -> Program:
        """Corrupt ``k`` entries of the value plane (``mode``: nan|scale)."""
        out = _copy_program(prog)
        idx = self.rng.integers(out.stream.size, size=k)
        if mode == "nan":
            out.stream[idx] = np.nan
        elif mode == "scale":
            out.stream[idx] = out.stream[idx] * 64.0 + 1.5
        else:
            raise ValueError(f"unknown stream corruption mode {mode!r}")
        return out

    def corrupt_blob(self, blob: bytes, k: int = 1) -> bytes:
        """XOR ``k`` random bytes of a serialized blob with non-zero junk."""
        buf = bytearray(blob)
        for _ in range(k):
            i = int(self.rng.integers(len(buf)))
            buf[i] ^= int(self.rng.integers(1, 256))
        return bytes(buf)

    def poison_rhs(self, b: np.ndarray, k: int = 1,
                   value: float = np.nan) -> np.ndarray:
        """Plant ``k`` non-finite entries in a right-hand side."""
        out = np.array(b, dtype=np.float64, copy=True)
        flat = out.reshape(-1)
        flat[self.rng.integers(flat.size, size=k)] = value
        return out

    # -- IR-level mutation faults (caught by analysis.contracts) -----------
    # Each returns a corrupted *copy* of one intermediate IR of the staged
    # compiler, or None when the fault does not apply to this workload
    # (e.g. no edges, no psum traffic).  `run_ir_fault_injection` drives
    # the pipeline, mutates each IR post-pass, and asserts the matching
    # per-pass verifier fires the expected diagnostic code.

    def corrupt_dag(self, dag):
        """Rewrite one edge source onto its own consumer (topo break)."""
        if dag.n_edges == 0:
            return None
        src = dag.src.copy()
        owner_row = np.repeat(np.arange(dag.n), np.diff(dag.ptr))
        k = int(self.rng.integers(dag.n_edges))
        src[k] = owner_row[k]  # sources must be strictly smaller node ids
        return dataclasses.replace(dag, src=src)

    def corrupt_partition(self, pir):
        """Drop one consumer edge from the wake-up adjacency."""
        cands = [j for j in range(pir.dag.n) if pir.consumers[j]]
        if not cands:
            return None
        j = cands[int(self.rng.integers(len(cands)))]
        consumers = [list(c) for c in pir.consumers]
        consumers[j] = consumers[j][:-1]
        return dataclasses.replace(pir, consumers=consumers)

    def corrupt_assign(self, air):
        """Flip one node's owner without touching the task lists."""
        if len(air.task_lists) < 2:
            return None
        owner = np.asarray(air.owner).copy()
        i = int(self.rng.integers(owner.size))
        owner[i] = (owner[i] + 1) % len(air.task_lists)
        return dataclasses.replace(air, owner=owner)

    def corrupt_schedule(self, sir, mode: str):
        """Mutate the dense cycle trace (``mode``: raw | dup_final |
        slot_cap | use_before_def)."""
        ops = sir.ops.copy()
        src = sir.src.copy()
        ctl = sir.ctl.copy()
        slot = sir.slot.copy()
        if mode == "raw":
            edges = np.argwhere(ops == OP_EDGE)
            finals = np.argwhere(ops == OP_FINAL)
            if not edges.size or not finals.size:
                return None
            # retarget an early EDGE at the row finalized last
            t_last = int(finals[:, 0].max())
            lt, lp = finals[finals[:, 0] == t_last][0]
            early = edges[edges[:, 0] <= t_last]
            if not early.size:
                return None
            t, p = early[int(self.rng.integers(len(early)))]
            src[t, p] = src[lt, lp]
        elif mode == "dup_final":
            edges = np.argwhere(ops == OP_EDGE)
            if not edges.size:
                return None
            t, p = edges[int(self.rng.integers(len(edges)))]
            ops[t, p] = OP_FINAL  # its src row is already finalized once
        elif mode == "slot_cap":
            ev = np.argwhere((ctl == PS_LOAD) | (ctl == PS_STORE_RESET)
                             | (ctl == PS_SWAP))
            if not ev.size:
                return None
            t, p = ev[int(self.rng.integers(len(ev)))]
            slot[t, p] = 255  # beyond any configured register file
        elif mode == "use_before_def":
            ev = np.argwhere(ctl == PS_STORE_RESET)
            if not ev.size:
                return None
            t, p = ev[int(self.rng.integers(len(ev)))]
            ctl[t, p] = PS_LOAD  # the slot was free here: read-before-store
        else:
            raise ValueError(f"unknown schedule corruption mode {mode!r}")
        return dataclasses.replace(sir, ops=ops, src=src, ctl=ctl, slot=slot)

    def corrupt_emit(self, eir, mode: str):
        """Mutate the emitted trace (``mode``: envelope | stall_row)."""
        if mode == "envelope":
            row_lo = eir.row_lo.copy()
            t = int(self.rng.integers(row_lo.size))
            row_lo[t] += 1
            return dataclasses.replace(eir, row_lo=row_lo)
        if mode == "stall_row":
            t = int(self.rng.integers(eir.ops.shape[0] + 1))
            ins = {f: np.insert(getattr(eir, f), t, 0, axis=0)
                   for f in ("ops", "src", "ctl", "slot", "val_idx")}
            return dataclasses.replace(
                eir,
                row_lo=np.insert(eir.row_lo, t, eir.n),
                row_hi=np.insert(eir.row_hi, t, -1),
                **ins)
        raise ValueError(f"unknown emit corruption mode {mode!r}")


def run_fault_injection(mat: TriCSR, prog: Program | None = None, *,
                        trials_per_class: int = 3, seed: int = 0,
                        residual_tol: float = 1e-3,
                        classes: tuple[str, ...] = FAULT_CLASSES) -> list[dict]:
    """Inject every fault class and record how the stack responds.

    Returns one dict per trial: ``fault``, ``trial``, ``detected`` (which
    layer caught it: "verify" / "load" / "input" / "health" / "none"),
    ``degraded_to`` (the ladder rung that produced the returned answer,
    "" when the solve raised), and ``silent_wrong`` — True only when
    nothing detected anything AND the returned answer fails the residual
    check.  The acceptance bar is ``not any(r["silent_wrong"])``.
    """
    from . import serialize
    from .schedule import compile_program

    if prog is None:
        prog = compile_program(mat)
    inj = FaultInjector(seed)
    rng = np.random.default_rng(seed + 1)
    results = []

    def solve_outcome(bad_prog, b):
        """Solve a (possibly corrupt) program under full health checks."""
        rs = RobustSolver(bad_prog, mat, backend="jax", verify=False,
                          residual_tol=residual_tol)
        try:
            x = rs.solve(b)
        except RobustnessError:
            return "health", "", True  # detected by raising: not silent
        degraded = rs.last_stage if rs.last_incidents else ""
        detected = "health" if rs.last_incidents else "none"
        ok = relative_residual(mat, x, b) <= residual_tol
        return detected, degraded, ok

    for fault in classes:
        for trial in range(trials_per_class):
            b = rng.standard_normal(mat.n)
            detected, degraded, ok = "none", "", True
            if fault in ("instr_bit_flip", "psum_slot"):
                bad = (inj.flip_instr_bits(prog, flips=1)
                       if fault == "instr_bit_flip"
                       else inj.corrupt_slots(prog, k=1))
                try:
                    verify_program(bad)
                except ProgramCorruptionError:
                    detected = "verify"
                else:
                    detected, degraded, ok = solve_outcome(bad, b)
            elif fault in ("value_plane_nan", "value_plane_scale"):
                mode = "nan" if fault.endswith("nan") else "scale"
                bad = inj.corrupt_stream(prog, k=2, mode=mode)
                try:
                    verify_program(bad)
                except ProgramCorruptionError:
                    detected = "verify"
                else:
                    detected, degraded, ok = solve_outcome(bad, b)
            elif fault == "blob":
                blob = serialize.dumps_program(prog)
                try:
                    serialize.loads_program(inj.corrupt_blob(blob, k=3))
                except ProgramCorruptionError:
                    detected = "load"
                else:  # pragma: no cover - CRC collision would be news
                    detected = "none"
            elif fault in ("rhs_nan", "rhs_inf"):
                val = np.nan if fault == "rhs_nan" else np.inf
                rs = RobustSolver(prog, mat, backend="jax", verify=False,
                                  residual_tol=residual_tol)
                try:
                    rs.solve(inj.poison_rhs(b, k=2, value=val))
                except NumericalHealthError:
                    detected = "input"
            else:  # pragma: no cover
                raise ValueError(f"unknown fault class {fault!r}")
            results.append({
                "fault": fault,
                "trial": trial,
                "detected": detected,
                "degraded_to": degraded,
                "silent_wrong": bool(detected == "none" and not ok),
            })
    return results


# ---------------------------------------------------------------------------
# IR-level fault injection (the per-pass verifiers' acceptance harness)
# ---------------------------------------------------------------------------
IR_FAULT_CLASSES = (
    "dag_self_edge",
    "partition_drop_consumer",
    "assign_owner_swap",
    "sched_raw",
    "sched_dup_final",
    "sched_slot_cap",
    "sched_use_before_def",
    "emit_envelope",
    "emit_stall_row",
    "pack_val_idx_oob",
)

# fault class -> the diagnostic code the matching verifier must fire
_IR_EXPECTED = {
    "dag_self_edge": "SPT118",
    "partition_drop_consumer": "SPT119",
    "assign_owner_swap": "SPT120",
    "sched_raw": "SPT111",
    "sched_dup_final": "SPT110",
    "sched_slot_cap": "SPT113",
    "sched_use_before_def": "SPT112",
    "emit_envelope": "SPT114",
    "emit_stall_row": "SPT121",
    "pack_val_idx_oob": "SPT106",
}


def run_ir_fault_injection(mat: TriCSR, cfg: AccelConfig | None = None, *,
                           seed: int = 0,
                           classes: tuple[str, ...] = IR_FAULT_CLASSES) -> list[dict]:
    """Mutate every intermediate IR post-pass; assert the verifiers catch it.

    Runs the staged pipeline once, then for each fault class corrupts the
    relevant IR (`FaultInjector.corrupt_*`) and runs *only* that stage's
    contract verifier (`core/analysis/contracts.py`).  Returns one dict
    per class: ``fault``, ``applicable`` (False when the workload has no
    site for this fault — e.g. no psum traffic), ``expected_code``,
    ``fired_codes`` (error-severity codes the verifier reported) and
    ``caught``.  The acceptance bar is ``caught`` for every applicable
    class — a mutation the verifiers miss would otherwise surface only as
    a generic corrupt-program failure after packing, unattributed.
    """
    from .analysis import contracts
    from .compiler import assign, elide, emit, partition, sched
    from .frontends.sptrsv import lower_tri

    cfg = cfg or AccelConfig()
    dag = lower_tri(mat)
    pir = partition.run(dag)
    air = assign.run(pir, cfg)
    sir = sched.run(air, cfg)
    eir = elide.run(sir)
    prog = emit.run(eir, cfg, planes=None)

    inj = FaultInjector(seed)
    results = []
    for fault in classes:
        expected = _IR_EXPECTED[fault]
        bad, diags = None, None
        if fault == "dag_self_edge":
            bad = inj.corrupt_dag(dag)
            if bad is not None:
                diags = contracts.verify_frontend(bad)
        elif fault == "partition_drop_consumer":
            bad = inj.corrupt_partition(pir)
            if bad is not None:
                diags = contracts.verify_partition(bad)
        elif fault == "assign_owner_swap":
            bad = inj.corrupt_assign(air)
            if bad is not None:
                diags = contracts.verify_assign(bad, cfg)
        elif fault.startswith("sched_"):
            bad = inj.corrupt_schedule(sir, fault[len("sched_"):])
            if bad is not None:
                diags = contracts.verify_schedule(bad, air, cfg)
        elif fault.startswith("emit_"):
            bad = inj.corrupt_emit(eir, fault[len("emit_"):])
            if bad is not None:
                diags = contracts.verify_emit(bad, sir)
        elif fault == "pack_val_idx_oob":
            bad = _copy_program(prog)
            bad.val_idx[0, 0] = np.int32(bad.stream.size + 7)
            diags = contracts.verify_packed_program(bad, eir, cfg)
        else:
            raise ValueError(f"unknown IR fault class {fault!r}")
        fired = sorted({d.code for d in diags
                        if d.severity == SEV_ERROR}) if diags is not None \
            else []
        results.append({
            "fault": fault,
            "applicable": bad is not None,
            "expected_code": expected,
            "fired_codes": fired,
            "caught": expected in fired,
        })
    return results


# ---------------------------------------------------------------------------
# service-level chaos harness (the resilient serving acceptance bar)
# ---------------------------------------------------------------------------
SERVICE_FAULT_CLASSES = (
    "backend_exception",   # entry rung raises; retry/backoff then degrade
    "backend_hang",        # entry rung stalls past flush_timeout_s
    "backend_nonfinite",   # entry rung returns NaN; health check degrades
    "disk_corrupt",        # program-cache disk blob corrupted between gets
    "rhs_poison",          # non-finite b: every rung unhealthy, typed fail
    "overload_burst",      # admission budgets exceeded: typed load sheds
    "expired_deadline",    # requests expire before / while queued
)


def run_service_fault_injection(mats=None, *, seed: int = 0,
                                requests: int = 24,
                                classes: tuple[str, ...] = SERVICE_FAULT_CLASSES,
                                residual_tol: float = 1e-3) -> list[dict]:
    """Drive a resilient `serve.SolveService` through fault schedules.

    For each fault class a fresh two-tenant service (numpy entry rung,
    `serve.ManualClock`, full resilience config) takes ``requests``
    submits while the class's faults fire through an injected
    stage-solver wrapper (exceptions / hangs / non-finite outputs on the
    entry rung), corrupted disk blobs, poisoned right-hand sides,
    overload bursts, or expiring deadlines — all seeded, all on virtual
    time.  Returns one dict per class::

        fault, tickets, completed, failed_typed, shed,
        silent_wrong, deadlocked, incidents

    where ``completed`` tickets were checked against the bit-exact
    stage-matched oracle (`executor.execute_numpy` for the entry rung,
    `csr.serial_solve` for the reference rung; residual fallback when a
    wide ticket mixed rungs), failed tickets must raise a typed
    `errors.RobustnessError`, and ``deadlocked`` is True if drain left
    pending columns behind.  The acceptance bar is zero ``silent_wrong``
    and zero ``deadlocked`` across every class and seed
    (`tests/test_resilience.py`, `benchmarks/serve_chaos.py --smoke`).
    """
    from .matrices import banded
    from .resilience import AdmissionConfig, BreakerConfig, ResilienceConfig, RetryPolicy
    from .schedule import compile_program
    from .serve import ManualClock, ProgramCache, SolveService

    if mats is None:
        mats = {"a": banded(96, 6, 0.5, seed=3, name="chaos-a"),
                "b": banded(80, 4, 0.6, seed=4, name="chaos-b")}
    mids = sorted(mats)
    oracle_progs = {mid: compile_program(m) for mid, m in mats.items()}

    def oracle_for(mid, b, stages):
        mat = mats[mid]
        bm = np.asarray(b, dtype=np.float64)
        bm2 = bm[:, None] if bm.ndim == 1 else bm
        if stages == {"reference"}:
            x = np.stack([serial_solve(mat, bm2[:, j])
                          for j in range(bm2.shape[1])], axis=1)
            return x[:, 0] if bm.ndim == 1 else x
        if stages == {"numpy"}:
            return np.asarray(execute_numpy(oracle_progs[mid], b))
        return None  # mixed rungs: residual check instead

    results = []
    for fault in classes:
        rng = np.random.default_rng(
            (seed * 1009 + zlib.crc32(fault.encode())) % 2 ** 31)
        clock = ManualClock()
        flush_timeout = 0.25
        res = ResilienceConfig(
            retry=RetryPolicy(max_retries=1, base_delay_s=0.01, seed=seed),
            breaker=BreakerConfig(window_s=50.0, min_samples=4,
                                  failure_threshold=0.75, cooldown_s=5.0),
            admission=AdmissionConfig(
                max_pending_per_matrix=6 if fault == "overload_burst"
                else None,
                max_pending_total=10 if fault == "overload_burst" else None),
            flush_timeout_s=flush_timeout)
        tmp = None
        cache_kw = {}
        if fault == "disk_corrupt":
            import tempfile

            tmp = tempfile.TemporaryDirectory()
            # capacity 1 with two tenants: every other get goes to disk
            cache_kw = {"capacity": 1, "disk_dir": tmp.name}
        svc = SolveService(ProgramCache(**cache_kw), max_batch=4,
                           max_delay=0.5, clock=clock, backend="numpy",
                           resilience=res)
        for mid, m in mats.items():
            svc.register(mid, m)

        # wrap the stage-solver factory with the fault plan: solver-level
        # faults fire on the entry rung only, so the reference rung keeps
        # the always-answers guarantee testable
        inj = FaultInjector(seed + 17)
        orig_stage_solver = svc._stage_solver
        solver_fault = {"backend_exception": "exception",
                        "backend_hang": "hang",
                        "backend_nonfinite": "nonfinite"}.get(fault)

        def chaotic(stage, prog, k, mat,
                    _orig=orig_stage_solver, _fault=solver_fault):
            fn = _orig(stage, prog, k, mat)
            if _fault is None or stage != "numpy":
                return fn

            def wrapped(bmat):
                if rng.random() < 0.5:
                    if _fault == "exception":
                        raise RuntimeError("injected backend fault")
                    if _fault == "hang":
                        clock.advance(flush_timeout * 2)
                        return fn(bmat)
                    x = np.asarray(fn(bmat)).copy()
                    x.reshape(-1)[int(rng.integers(x.size))] = np.nan
                    return x
                return fn(bmat)
            return wrapped

        svc._stage_solver = chaotic

        tickets = []
        for i in range(requests):
            mid = mids[int(rng.integers(len(mids)))]
            n = mats[mid].n
            # overload bursts need wide requests so the pending budgets
            # actually bind (narrow ones flush full before they pile up)
            k = int(rng.integers(1, 9 if fault == "overload_burst" else 4))
            b = rng.standard_normal((n, k)) if k > 1 \
                else rng.standard_normal(n)
            kw = {}
            if fault == "rhs_poison" and rng.random() < 0.4:
                b = inj.poison_rhs(b, k=1)
            if fault == "expired_deadline":
                # half the stream: deadlines that expire in the queue or
                # already lie in the past
                r = rng.random()
                if r < 0.25:
                    kw["timeout"] = -0.1          # expired before submit
                elif r < 0.5:
                    kw["timeout"] = 0.05          # expires while queued
            ticket = svc.submit(mid, b, **kw)
            tickets.append((ticket, b))
            if fault == "disk_corrupt" and i % 5 == 2 and tmp is not None:
                # corrupt every .prog blob currently on disk
                import glob as _glob

                for path in _glob.glob(os.path.join(tmp.name, "*.prog")):
                    with open(path, "rb") as f:
                        blob = f.read()
                    with open(path, "wb") as f:
                        f.write(inj.corrupt_blob(blob, k=3))
            clock.advance(float(rng.uniform(0.0, 0.3)))
            svc.pump()
        clock.advance(1.0)
        svc.pump()
        svc.drain()

        flush_by_index = {r.index: r for r in svc.stats.flushes
                          if r.index >= 0}
        completed = failed_typed = shed = 0
        silent_wrong = False
        for ticket, b in tickets:
            if not ticket.done:
                silent_wrong = True  # a lost ticket is as bad as a wrong one
                continue
            if ticket.shed:
                shed += 1
                continue
            if ticket.failed:
                failed_typed += isinstance(ticket.error, RobustnessError)
                silent_wrong |= not isinstance(ticket.error, RobustnessError)
                continue
            completed += 1
            x = ticket.result()
            stages = {flush_by_index[i].stage
                      for i in ticket.flush_indices if i in flush_by_index}
            want = oracle_for(ticket.matrix_id, b, stages)
            if want is not None:
                ok = np.array_equal(np.asarray(x, dtype=np.float64),
                                    np.asarray(want, dtype=np.float64))
            else:
                ok = relative_residual(mats[ticket.matrix_id], x, b) \
                    <= residual_tol
            silent_wrong |= not ok
        deadlocked = svc.pending_columns() > 0 or \
            any(not t.done for t, _ in tickets)
        results.append({
            "fault": fault,
            "tickets": len(tickets),
            "completed": completed,
            "failed_typed": failed_typed,
            "shed": shed,
            "silent_wrong": bool(silent_wrong),
            "deadlocked": bool(deadlocked),
            "incidents": len(svc.incidents),
        })
        if tmp is not None:
            tmp.cleanup()
    return results
