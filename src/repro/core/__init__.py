"""Core library: the paper's medium-granularity SpTRSV dataflow in JAX.

Contains the custom compiler (node allocation + edge-granular scheduling +
psum caching + ICR + bank model), the coarse/fine baseline dataflows, the
branch-free VLIW executors, and the benchmark-matrix suite.
"""

from . import api, compiler, dag, frontends, matrices, serve  # noqa: F401
from .compiler import ComputeDag, compile_dag  # noqa: F401
from .csr import TriCSR, UpperCSR, serial_solve, serial_solve_upper  # noqa: F401
from .program import AccelConfig, Program, ScheduleStats  # noqa: F401
from .schedule import compile_program  # noqa: F401
from .executor import (  # noqa: F401
    execute_jax,
    execute_numpy,
    make_jax_executor,
    pad_batch,
)
from .fine import FineConfig, schedule_fine  # noqa: F401
