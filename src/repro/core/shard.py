"""Multi-device batched SpTRSV: shard the RHS batch axis over a device mesh.

The compiled VLIW instruction stream depends only on L, so the B columns of
a batched solve are embarrassingly parallel: each device runs the identical
instruction-stream pass over its own block of right-hand sides.  This
module places `solve_batch`'s work on a `jax.sharding.Mesh`:

  * instruction-stream constants are closed over by the per-device solve
    function and therefore replicated to every device;
  * the RHS matrix ``b[n, B]`` is sharded over B (all mesh axes flattened,
    see `repro.distributed.sharding.rhs_sharding`) and each device solves
    its local ``[n, B/ndev]`` block under `shard_map` — no collective ever
    runs, the only cross-device traffic is the initial column placement.

Batch widths are padded to ``ndev * pad_batch(ceil(B / ndev))`` so every
device carries the same lane-friendly block; executors are cached per
(program identity, padded per-device width, mesh), so repeated solves —
including nearby batch sizes on the same mesh — never retrace (shared
`executor.trace_count` observability).

    from repro.core import api, shard
    mesh = shard.batch_mesh()                  # 1-D mesh over local devices
    x = api.solve_batch(prog, b, mesh=mesh)    # b[n, B], B over devices
    solver = api.make_solver(prog, batch=B, mesh=mesh)   # cached closure

Tests force a multi-device CPU host via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

from __future__ import annotations

import weakref

import numpy as np

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import rhs_sharding

from .executor import batched_entry, build_solve_cols, pad_batch, validate_backend
from .program import Program

__all__ = ["batch_mesh", "make_sharded_solver", "sharded_widths"]

# prog -> {(per-device width, mesh) -> jitted shard_map solve}
_SHARD_CACHE: "weakref.WeakKeyDictionary[Program, dict]" = weakref.WeakKeyDictionary()


def batch_mesh(num_devices: int | None = None, axis: str = "batch") -> Mesh:
    """A 1-D mesh over the first ``num_devices`` local devices (default all).

    The axis name is cosmetic — the solver shards the RHS columns over every
    axis of whatever mesh it is given.
    """
    devs = jax.devices()
    if num_devices is not None:
        devs = devs[:num_devices]
    return Mesh(np.asarray(devs), (axis,))


def sharded_widths(batch: int, mesh: Mesh) -> tuple[int, int]:
    """(per-device padded width, global padded width) for a batch size."""
    ndev = mesh.size
    w_local = pad_batch(-(-batch // ndev))
    return w_local, w_local * ndev


def _build_sharded_executor(prog: Program, w_local: int, mesh: Mesh,
                            backend: str, backend_opts: dict):
    """Jitted `solve(b[n, w_local * ndev]) -> x` mapped over the mesh.

    Each device traces the per-device solver once at the per-device width.
    ``backend="jax"`` maps `executor.build_solve_cols` (instruction
    constants fold into the replicated jaxpr); ``backend="pallas"`` maps
    `repro.kernels.sptrsv.ops.build_solver_cols`, so the kernel's memory
    placements — including the HBM-resident row-blocked large-n regime —
    compose with mesh sharding.  `shard_map` has no replication rule for
    `pallas_call`, so the pallas path disables the static replication
    check; that is sound here because in/out specs are fully sharded over
    the batch axis and the solve never communicates across devices.
    """
    if backend == "pallas":
        from repro.kernels.sptrsv import ops as sptrsv_ops

        solve_local = sptrsv_ops.build_solver_cols(prog, w_local,
                                                   **backend_opts)
        check = {"check_rep": False}
    else:
        solve_local = build_solve_cols(prog, w_local)
        check = {}
    spec = P(None, mesh.axis_names)
    return jax.jit(
        shard_map(solve_local, mesh=mesh, in_specs=(spec,), out_specs=spec,
                  **check)
    )


def _cached_sharded_executor(prog: Program, w_local: int, mesh: Mesh,
                             backend: str, backend_opts: dict):
    per_prog = _SHARD_CACHE.get(prog)
    if per_prog is None:
        per_prog = {}
        _SHARD_CACHE[prog] = per_prog
    key = (w_local, mesh, backend, tuple(sorted(backend_opts.items())))
    fn = per_prog.get(key)
    if fn is None:
        fn = _build_sharded_executor(prog, w_local, mesh, backend,
                                     backend_opts)
        per_prog[key] = fn
    return fn


def make_sharded_solver(prog: Program, batch: int, mesh: Mesh,
                        backend: str = "jax", **backend_opts):
    """Cached `solver(b[n, batch]) -> x[n, batch]` sharded over ``mesh``.

    Pads the batch axis to ``ndev * pad_batch(ceil(batch / ndev))``, places
    the columns with `rhs_sharding`, and runs the per-device executor under
    `shard_map`.  Reuses one trace per (program, per-device width, mesh,
    backend knobs).  ``backend="pallas"`` runs the TPU kernel per device
    (knobs as in `executor.make_pallas_executor`).
    """
    if batch < 0:
        raise ValueError(f"batch must be non-negative, got {batch}")
    validate_backend(backend, backend_opts)
    w_local, width = sharded_widths(max(batch, 1), mesh)
    core = _cached_sharded_executor(prog, w_local, mesh, backend,
                                    backend_opts)
    placement = rhs_sharding(mesh)
    return batched_entry(core, prog.n, batch, width,
                         place=lambda b: jax.device_put(b, placement))
