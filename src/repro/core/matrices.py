"""Synthetic benchmark-matrix suite.

The paper evaluates 245 SuiteSparse matrices; this container is offline, so we
generate matrices spanning the same *structural archetypes* as the paper's
Table III (FEM bands, circuit Jacobians, power networks, chemical-process
chains, near-empty wide DAGs).  Every generator produces a well-conditioned
lower-triangular system (unit-ish diagonal, bounded off-diagonals) so the
f32 executor comparison against the f64 oracle stays tight.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from .csr import TriCSR, from_coo

__all__ = ["SUITE", "generate", "suite_names", "paper_like_suite"]


def _finish(n, rows, cols, rng, name, scale=0.5) -> TriCSR:
    vals = rng.uniform(-scale, scale, size=len(rows))
    # diagonally dominant-ish: |diag| in [1, 2]
    diag = rng.uniform(1.0, 2.0, size=n) * rng.choice([-1.0, 1.0], size=n)
    return from_coo(n, rows, cols, vals, diag, name=name)


def banded(n: int, bandwidth: int, fill: float, seed: int, name: str) -> TriCSR:
    """FEM-style band (jagmesh / dw2048 / rdb archetype): dense-ish band,
    long dependency chains, narrow levels -> CDU-heavy."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for i in range(1, n):
        lo = max(0, i - bandwidth)
        cand = np.arange(lo, i)
        take = cand[rng.random(len(cand)) < fill]
        if len(take) == 0 and i > 0:
            take = np.array([i - 1])
        rows.extend([i] * len(take))
        cols.extend(take.tolist())
    return _finish(n, rows, cols, rng, name)


def circuit(n: int, hubs: int, avg_deg: float, seed: int, name: str) -> TriCSR:
    """Circuit-Jacobian archetype (add20 / rajat / fpga_*): a few hub columns
    consumed by many rows (power-law fan-out) + sparse random filler."""
    rng = np.random.default_rng(seed)
    hub_ids = np.sort(rng.choice(np.arange(n // 8), size=hubs, replace=False))
    rows, cols = [], []
    for i in range(1, n):
        deg = 1 + rng.poisson(max(avg_deg - 1.0, 0.1))
        picked = set()
        for _ in range(deg):
            if rng.random() < 0.45:
                h = hub_ids[rng.integers(len(hub_ids))]
                if h < i:
                    picked.add(int(h))
            else:
                span = max(1, min(i, int(n * 0.05)))
                picked.add(int(i - 1 - rng.integers(span)))
        picked.discard(i)
        for j in sorted(picked):
            rows.append(i)
            cols.append(j)
    return _finish(n, rows, cols, rng, name)


def powergrid(n: int, seed: int, name: str) -> TriCSR:
    """Power-network archetype (ACTIVSg / gemat): 2D-grid locality plus a few
    long-range ties; moderate CDU ratio."""
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(n))
    rows, cols = [], []
    for i in range(1, n):
        nbrs = [i - 1, i - side, i - side + 1, i - side - 1]
        for j in nbrs:
            if 0 <= j < i and rng.random() < 0.75:
                rows.append(i)
                cols.append(j)
        if rng.random() < 0.08:  # long-range tie line
            rows.append(i)
            cols.append(int(rng.integers(max(1, i))))
    return _finish(n, rows, cols, rng, name)


def chain_process(n: int, width: int, seed: int, name: str) -> TriCSR:
    """Chemical-process archetype (west / bp / bayer): block recycle streams —
    near-diagonal couplings with periodic long feedback edges -> long chains."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for i in range(1, n):
        k = 1 + rng.integers(3)
        for _ in range(k):
            j = i - 1 - rng.integers(min(i, width))
            rows.append(i)
            cols.append(int(j))
        if i % 37 == 0 and i > width * 2:
            rows.append(i)
            cols.append(int(rng.integers(i - width)))
    return _finish(n, rows, cols, rng, name)


def sparse_wide(n: int, seed: int, name: str) -> TriCSR:
    """c-36 archetype: ~0.6 off-diag nnz/row, very wide levels — the coarse
    dataflow's best case (CDU ratio ~0)."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for i in range(1, n):
        if rng.random() < 0.6:
            rows.append(i)
            cols.append(int(rng.integers(i)))
    return _finish(n, rows, cols, rng, name)


def serial_chain(n: int, extra: int, seed: int, name: str) -> TriCSR:
    """Bidiagonal + a few extras: the fully-serial worst case; also the exact
    structure of a linear SSM recurrence (see DESIGN.md §1)."""
    rng = np.random.default_rng(seed)
    rows = list(range(1, n))
    cols = list(range(0, n - 1))
    for _ in range(extra):
        i = int(rng.integers(2, n))
        rows.append(i)
        cols.append(int(rng.integers(i - 1)))
    return _finish(n, rows, cols, rng, name)


def hub_wall(n_src: int, n_hubs: int, hub_deg: int, seed: int,
             name: str) -> TriCSR:
    """Pure load-imbalance stressor: n_src independent source rows followed
    by n_hubs rows each consuming hub_deg of them.  All hub inputs become
    ready simultaneously, so a coarse/medium CU must grind hub_deg serial
    MACs while most CUs idle — the case the paper's §V-E leaves open and
    `transform.split_heavy_nodes` addresses."""
    rng = np.random.default_rng(seed)
    n = n_src + n_hubs
    rows, cols = [], []
    for h in range(n_hubs):
        i = n_src + h
        take = rng.choice(np.arange(n_src), size=min(hub_deg, n_src),
                          replace=False)
        rows.extend([i] * len(take))
        cols.extend(sorted(take.tolist()))
    return _finish(n, rows, cols, rng, name)


def heavy_hub(n: int, hub_deg: int, seed: int, name: str) -> TriCSR:
    """Load-imbalance stressor (bp_200 / rajat04 archetype): a handful of rows
    carry 10-100x the average in-degree -> medium dataflow's known weak spot
    (paper §V-B), used to reproduce that negative result too."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for i in range(1, n):
        rows.append(i)
        cols.append(i - 1)
    for h in range(6):
        i = int(n * (0.35 + 0.1 * h))
        take = rng.choice(np.arange(i - 1), size=min(hub_deg, i - 1), replace=False)
        rows.extend([i] * len(take))
        cols.extend(take.tolist())
    return _finish(n, rows, cols, rng, name)


# ---------------------------------------------------------------------------
# Registry.  Sizes bracket the paper's Table III (n = 628 .. 7479) plus larger
# entries toward the 85k upper end of the 245-matrix sweep.
# ---------------------------------------------------------------------------
SUITE: dict[str, Callable[[], TriCSR]] = {}


def _reg(name: str, fn: Callable[[], TriCSR]) -> None:
    SUITE[name] = fn


def _build_suite() -> None:
    # FEM band archetypes (jagmesh4, rdb968, dw2048, bcsstm10, nnc1374, cz628)
    _reg("band_jagmesh", lambda: banded(1440, 24, 0.55, 1, "band_jagmesh"))
    _reg("band_rdb", lambda: banded(968, 28, 0.6, 2, "band_rdb"))
    _reg("band_dw2048", lambda: banded(2048, 26, 0.55, 3, "band_dw2048"))
    _reg("band_bcsstm", lambda: banded(1086, 22, 0.6, 4, "band_bcsstm"))
    _reg("band_nnc", lambda: banded(1374, 22, 0.55, 5, "band_nnc"))
    _reg("band_cz", lambda: banded(628, 24, 0.6, 6, "band_cz"))
    _reg("band_wide4k", lambda: banded(4096, 40, 0.35, 7, "band_wide4k"))
    _reg("band_big16k", lambda: banded(16384, 24, 0.4, 8, "band_big16k"))
    # toward the 85k upper end of the paper's sweep — the row-blocked
    # HBM-resident Pallas placement's target regime (DESIGN.md §1)
    _reg("band_huge64k", lambda: banded(65536, 16, 0.35, 9, "band_huge64k"))
    # circuit archetypes (add20, add32, rajat04, rajat19, fpga_*, circuit204)
    _reg("ckt_add20", lambda: circuit(2395, 24, 3.1, 11, "ckt_add20"))
    _reg("ckt_add32", lambda: circuit(4960, 20, 1.9, 12, "ckt_add32"))
    _reg("ckt_rajat04", lambda: circuit(1041, 30, 6.3, 13, "ckt_rajat04"))
    _reg("ckt_rajat19", lambda: circuit(1157, 28, 4.8, 14, "ckt_rajat19"))
    _reg("ckt_fpga", lambda: circuit(1220, 16, 3.4, 15, "ckt_fpga"))
    _reg("ckt_c204", lambda: circuit(1020, 18, 6.8, 16, "ckt_c204"))
    _reg("ckt_big8k", lambda: circuit(8192, 48, 4.0, 17, "ckt_big8k"))
    _reg("ckt_huge32k", lambda: circuit(32768, 96, 3.5, 18, "ckt_huge32k"))
    # power networks (ACTIVSg2000, gemat12, bips98)
    _reg("grid_activsg", lambda: powergrid(4000, 21, "grid_activsg"))
    _reg("grid_gemat", lambda: powergrid(4929, 22, "grid_gemat"))
    _reg("grid_bips", lambda: powergrid(7135, 23, "grid_bips"))
    _reg("grid_big20k", lambda: powergrid(20164, 24, "grid_big20k"))
    # chemical-process chains (west2021, bp_200, bayer07)
    _reg("chem_west", lambda: chain_process(2021, 40, 31, "chem_west"))
    _reg("chem_bp", lambda: chain_process(822, 25, 32, "chem_bp"))
    _reg("chem_bayer", lambda: chain_process(3268, 60, 33, "chem_bayer"))
    # wide sparse (c-36) — coarse dataflow's best case
    _reg("wide_c36", lambda: sparse_wide(7479, 41, "wide_c36"))
    _reg("wide_10k", lambda: sparse_wide(10240, 42, "wide_10k"))
    # serial chains — worst case / SSM analogue
    _reg("chain_1k", lambda: serial_chain(1024, 64, 51, "chain_1k"))
    _reg("chain_4k", lambda: serial_chain(4096, 256, 52, "chain_4k"))
    # load-imbalance stressors (paper's bp_200/rajat negative results)
    _reg("hub_small", lambda: heavy_hub(1200, 280, 61, "hub_small"))
    _reg("hub_mid", lambda: heavy_hub(3000, 700, 62, "hub_mid"))
    _reg("hub_wall", lambda: hub_wall(2048, 8, 512, 63, "hub_wall"))
    _reg("hub_wall_big", lambda: hub_wall(6144, 12, 1536, 64, "hub_wall_big"))


_build_suite()
_CACHE: dict[str, TriCSR] = {}


def generate(name: str) -> TriCSR:
    if name not in _CACHE:
        _CACHE[name] = SUITE[name]()
    return _CACHE[name]


def suite_names(max_n: int | None = None) -> list[str]:
    names = list(SUITE)
    if max_n is None:
        return names
    return [m for m in names if generate(m).n <= max_n]


def paper_like_suite() -> list[TriCSR]:
    return [generate(m) for m in suite_names()]
