"""Production solve service: continuous RHS micro-batching over a
multi-tenant program cache (DESIGN.md §9).

The accelerator's economics are compile-once/solve-many: a `Program` is
the expensive artifact, and production traffic (factorization loops,
preconditioner sweeps) is a *stream* of ``(matrix_id, b)`` requests
against a fleet of precompiled programs.  This module turns the batched
executors (DESIGN.md §4) into a service facing that stream:

  * `SolveService` — accepts single- or multi-column right-hand sides per
    registered matrix and micro-batches the columns per matrix into the
    padded widths the cached batched executors already key on
    (`executor.pad_batch` — the one bucketing function, shared with the
    executor cache so the two can never diverge).  A bucket flushes when
    it reaches ``max_batch`` columns or when its deadline — arrival of
    its oldest pending column plus ``max_delay`` — expires.  **Every
    scheduling decision runs on an injectable clock**: the core never
    reads wall time, so deadline-vs-full flush ordering, out-of-order
    completion and result routing are all unit-testable without sleeps
    (`tests/test_serve.py`).  Production callers get a real clock from
    `api.make_service`.
  * `ProgramCache` — a bounded LRU of compiled `Program`s keyed by
    `pattern_fingerprint` (a structure-only hash over the CSR pattern:
    two tenants registering the same sparsity pattern share one compile).
    A write-through disk tier (`serialize.save_program`) lets an evicted
    entry rehydrate through the CRC-verified `serialize.load_program`
    instead of re-running the compiler; a corrupted blob degrades to a
    recompile with a machine-readable `robust.Incident`, never a crash.
    Because the compiled value plane depends on the numeric values too,
    each entry carries a CRC of the source values — a same-pattern /
    different-values matrix is a miss (its own disk blob), never a
    silently wrong schedule reuse.
  * `ServeStats` — per-entry hit/miss/compile-time counters plus flush
    accounting (full vs deadline vs drain, batched column counts and a
    `FlushRecord` log) so load generators (`benchmarks/serve_load.py`)
    and dashboards read one record.

Request lifecycle: ``submit`` first pumps any bucket whose deadline is
already due (deadline flushes happen-before the new arrival), enqueues
the request's columns, then flushes full ``max_batch`` chunks
immediately.  ``pump(now)`` flushes due buckets in deterministic
(deadline, arrival-order) order; ``drain()`` flushes everything.  A
`SolveTicket` completes when its last column's bucket flushes — tickets
of a hot matrix can complete before earlier-submitted tickets of a cold
one, and each column routes back to exactly the ticket that submitted
it.  Batched columns are bit-identical to per-request solves (no
cross-column arithmetic exists in any executor), which the property
suite (`tests/test_serve_property.py`) pins down.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import zlib
from collections import OrderedDict

import numpy as np

from .csr import TriCSR
from .errors import ProgramCorruptionError
from .executor import execute_numpy, pad_batch, validate_backend
from .program import AccelConfig, Program
from .robust import Incident
from .schedule import compile_program

__all__ = [
    "FLUSH_DEADLINE",
    "FLUSH_DRAIN",
    "FLUSH_FULL",
    "CacheEntryStats",
    "FlushRecord",
    "ManualClock",
    "ProgramCache",
    "ServeStats",
    "SolveService",
    "SolveTicket",
    "pattern_fingerprint",
]

FLUSH_FULL = "full"          # bucket reached max_batch columns
FLUSH_DEADLINE = "deadline"  # oldest pending column aged past max_delay
FLUSH_DRAIN = "drain"        # explicit drain() regardless of deadline

_FP_TAG = b"sptrsv-pattern-v1"


def pattern_fingerprint(mat: TriCSR) -> str:
    """Structure-only fingerprint of a CSR sparsity pattern (hex, 16 chars).

    Hashes ``(n, rowptr, colidx)`` and nothing else — numeric values do
    not participate, so a factorization loop re-solving one pattern with
    fresh values maps to one fingerprint (the cache guards value changes
    separately with a values CRC).  Two same-shape matrices with
    different patterns fingerprint differently.
    """
    h = hashlib.sha256(_FP_TAG)
    h.update(int(mat.n).to_bytes(8, "little"))
    h.update(np.ascontiguousarray(mat.rowptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(mat.colidx, dtype=np.int64).tobytes())
    return h.hexdigest()[:16]


def _values_crc(mat: TriCSR) -> int:
    return zlib.crc32(np.ascontiguousarray(mat.values,
                                           dtype=np.float64).tobytes())


@dataclasses.dataclass
class CacheEntryStats:
    """Per-fingerprint counters of one `ProgramCache` entry."""

    fingerprint: str
    name: str = ""
    hits: int = 0            # served from the in-memory LRU
    disk_hits: int = 0       # rehydrated from the disk tier (no compile)
    compiles: int = 0        # compiler runs (cold miss or corrupt blob)
    disk_corrupt: int = 0    # disk blobs rejected by CRC/structural verify
    compile_seconds: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class ProgramCache:
    """Bounded LRU of compiled `Program`s with a CRC-verified disk tier.

    ``capacity`` bounds the in-memory tier (LRU eviction).  ``disk_dir``
    (optional) enables the disk tier: every compile is written through
    (`serialize.save_program`), so an evicted entry rehydrates via the
    checksummed `serialize.load_program` instead of re-running the
    compiler.  A corrupt blob is removed, recorded as a
    `robust.Incident` (``kind="disk-corrupt"``) in ``incidents``, and
    the entry recompiles — corruption can degrade performance, never
    correctness.  ``get`` is keyed by `pattern_fingerprint`; a values
    CRC rides along so same-pattern/different-values matrices never
    share a program (they do share a fingerprint and get distinct disk
    blobs).
    """

    def __init__(self, capacity: int = 32, disk_dir=None,
                 cfg: AccelConfig | None = None, compile_fn=None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.disk_dir = os.fspath(disk_dir) if disk_dir is not None else None
        self._cfg = cfg
        self._compile = compile_fn or (lambda m: compile_program(m, cfg))
        self._mem: "OrderedDict[str, tuple[Program, int]]" = OrderedDict()
        self.entries: dict[str, CacheEntryStats] = {}
        self.incidents: list[Incident] = []
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._mem)

    def fingerprints(self) -> list[str]:
        """In-memory fingerprints, least- to most-recently used."""
        return list(self._mem)

    def _path(self, fp: str, vcrc: int) -> str | None:
        if self.disk_dir is None:
            return None
        return os.path.join(self.disk_dir, f"{fp}.{vcrc:08x}.prog")

    def _entry(self, fp: str, name: str) -> CacheEntryStats:
        ent = self.entries.get(fp)
        if ent is None:
            ent = CacheEntryStats(fingerprint=fp, name=name)
            self.entries[fp] = ent
        return ent

    # ------------------------------------------------------------------
    def get(self, mat: TriCSR) -> Program:
        """The compiled program for ``mat``'s pattern+values, through the
        tiers: memory LRU -> disk rehydrate -> compile (write-through)."""
        fp = pattern_fingerprint(mat)
        vcrc = _values_crc(mat)
        ent = self._entry(fp, mat.name)
        cached = self._mem.get(fp)
        if cached is not None:
            prog, crc = cached
            if crc == vcrc:
                self._mem.move_to_end(fp)
                ent.hits += 1
                self.hits += 1
                return prog
            # same pattern, new numeric values: the schedule would be
            # reusable (ROADMAP: recompile_values) but today the whole
            # program re-emits; the stale entry is replaced below.
            del self._mem[fp]
        self.misses += 1
        prog = self._rehydrate(fp, vcrc, ent)
        if prog is None:
            prog = self._compile(mat)
            ent.compiles += 1
            ent.compile_seconds += float(prog.stats.compile_seconds or 0.0)
            self._write_through(fp, vcrc, prog)
        self._insert(fp, vcrc, prog)
        return prog

    def _rehydrate(self, fp: str, vcrc: int,
                   ent: CacheEntryStats) -> Program | None:
        path = self._path(fp, vcrc)
        if path is None or not os.path.exists(path):
            return None
        from .serialize import load_program

        try:
            prog = load_program(path)  # CRC + structural verify
        except ProgramCorruptionError as e:
            ent.disk_corrupt += 1
            self.incidents.append(Incident(
                stage="program-cache", kind="disk-corrupt",
                message=f"disk entry for {fp} rejected, recompiling: {e}",
                error=type(e).__name__,
                detail={"fingerprint": fp, "path": path}))
            os.remove(path)
            return None
        ent.disk_hits += 1
        return prog

    def _write_through(self, fp: str, vcrc: int, prog: Program) -> None:
        path = self._path(fp, vcrc)
        if path is None:
            return
        from .serialize import save_program

        os.makedirs(self.disk_dir, exist_ok=True)
        save_program(prog, path)

    def _insert(self, fp: str, vcrc: int, prog: Program) -> None:
        self._mem[fp] = (prog, vcrc)
        self._mem.move_to_end(fp)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self.evictions += 1

    def stats_dict(self) -> dict:
        return {
            "hits": self.hits, "misses": self.misses,
            "evictions": self.evictions, "resident": len(self._mem),
            "capacity": self.capacity,
            "incidents": len(self.incidents),
            "entries": {fp: e.to_dict() for fp, e in self.entries.items()},
        }


# ---------------------------------------------------------------------------
# service
# ---------------------------------------------------------------------------
class ManualClock:
    """Deterministic injectable clock: returns ``now`` until advanced."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        self.now += float(dt)
        return self.now


class SolveTicket:
    """Routing handle for one submitted request.

    Completes when the last of its columns has been solved (columns of a
    wide request can span several flushes).  ``result()`` returns ``[n]``
    for a 1-D submit and ``[n, k]`` for a 2-D one; calling it before the
    ticket is done raises (pump or drain the service first).
    """

    def __init__(self, matrix_id: str, n: int, k: int, single: bool,
                 submitted_at: float):
        self.matrix_id = matrix_id
        self.columns = k
        self.submitted_at = submitted_at
        self.completed_at: float | None = None
        self.flush_indices: list[int] = []
        self._single = single
        self._x: np.ndarray | None = None
        self._n = n
        self._remaining = k
        if k == 0:  # degenerate [n, 0] request: nothing to solve
            self._x = np.zeros((n, 0), dtype=np.float32)
            self.completed_at = submitted_at

    @property
    def done(self) -> bool:
        return self._remaining == 0

    def _deliver(self, j: int, col: np.ndarray, flush_index: int,
                 at: float) -> None:
        if self._x is None:
            self._x = np.empty((self._n, self.columns), dtype=col.dtype)
        self._x[:, j] = col
        self._remaining -= 1
        if flush_index not in self.flush_indices:
            self.flush_indices.append(flush_index)
        if self._remaining == 0:
            self.completed_at = at

    def result(self) -> np.ndarray:
        if not self.done:
            raise RuntimeError(
                f"ticket for {self.matrix_id!r} not complete "
                f"({self._remaining}/{self.columns} columns pending) — "
                f"pump() or drain() the service")
        return self._x[:, 0] if self._single else self._x


@dataclasses.dataclass
class FlushRecord:
    """One executed micro-batch (the unit `benchmarks/serve_load.py`
    replays for its queueing model)."""

    index: int
    matrix_id: str
    reason: str        # FLUSH_FULL | FLUSH_DEADLINE | FLUSH_DRAIN
    columns: int       # real RHS columns solved
    padded: int        # executor batch width (pad_batch of columns)
    at: float          # injectable-clock time the flush ran
    service_s: float   # measured solve wall time (0.0 without a timer)


@dataclasses.dataclass
class ServeStats:
    """Aggregate service counters + the per-entry cache counters."""

    requests: int = 0
    columns: int = 0
    completed_columns: int = 0
    solver_calls: int = 0
    batched_columns: int = 0   # columns solved in flushes of >1 column
    flushes_full: int = 0
    flushes_deadline: int = 0
    flushes_drain: int = 0
    flushes: list = dataclasses.field(default_factory=list)
    cache: dict = dataclasses.field(default_factory=dict)

    def flush_count(self) -> int:
        return self.flushes_full + self.flushes_deadline + self.flushes_drain

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["flushes"] = [dataclasses.asdict(f) if dataclasses.is_dataclass(f)
                        else f for f in self.flushes]
        return d


class SolveService:
    """Continuous micro-batching front end over a `ProgramCache`.

    ``clock`` is any ``() -> float`` callable; the default is a
    `ManualClock` at 0.0 so the core is deterministic out of the box
    (production passes ``time.monotonic`` via `api.make_service`).
    ``timer`` (optional ``() -> float``) measures solve wall time for
    `FlushRecord.service_s` — left unset, records carry 0.0 and the core
    stays wall-clock-free.  ``backend`` is "numpy", "jax" or "pallas"
    (+ ``mesh=`` and the `api.make_solver` knobs); bucketing uses
    `executor.pad_batch`, the same rounding the executor cache keys on,
    so a service never provokes more than one trace per (program, padded
    width, backend knobs).
    """

    def __init__(self, cache: ProgramCache | None = None, *,
                 max_batch: int = 16, max_delay: float = 1e-3,
                 clock=None, timer=None, backend: str = "jax", mesh=None,
                 **backend_opts):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay < 0:
            raise ValueError(f"max_delay must be >= 0, got {max_delay}")
        if backend == "numpy":
            if mesh is not None or backend_opts:
                raise ValueError("backend='numpy' takes no mesh/extra options")
        else:
            validate_backend(backend, {} if backend == "jax"
                             else backend_opts)
        self.cache = cache if cache is not None else ProgramCache()
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay)
        self.backend = backend
        self.mesh = mesh
        self.backend_opts = backend_opts
        self._clock = clock if clock is not None else ManualClock()
        self._timer = timer
        self._mats: dict[str, TriCSR] = {}
        # matrix_id -> list of (seq, arrival, ticket, column_index, column)
        self._pending: dict[str, list] = {}
        self._seq = 0
        self.stats = ServeStats()

    # ------------------------------------------------------------------
    def register(self, matrix_id: str, mat: TriCSR) -> str:
        """Register a tenant matrix; returns its pattern fingerprint.

        Registration only records the matrix — compilation happens on
        the first flush, through the cache tiers (so two ids sharing one
        pattern+values compile once)."""
        if matrix_id in self._mats:
            raise ValueError(f"matrix_id {matrix_id!r} already registered")
        self._mats[matrix_id] = mat
        return pattern_fingerprint(mat)

    def matrix_ids(self) -> list[str]:
        return list(self._mats)

    def pending_columns(self, matrix_id: str | None = None) -> int:
        if matrix_id is not None:
            return len(self._pending.get(matrix_id, ()))
        return sum(len(v) for v in self._pending.values())

    # ------------------------------------------------------------------
    def submit(self, matrix_id: str, b: np.ndarray, *,
               now: float | None = None) -> SolveTicket:
        """Enqueue a right-hand side; returns its `SolveTicket`.

        Order of effects: (1) pump every bucket whose deadline is already
        due — deadline flushes happen-before the new arrival; (2) enqueue
        the request's columns; (3) flush full ``max_batch`` chunks of
        this bucket immediately (a wide request can trigger several)."""
        mat = self._mats.get(matrix_id)
        if mat is None:
            raise KeyError(f"unknown matrix_id {matrix_id!r} "
                           f"(registered: {sorted(self._mats)})")
        b = np.asarray(b)
        single = b.ndim == 1
        bmat = b[:, None] if single else b
        if bmat.ndim != 2 or bmat.shape[0] != mat.n:
            raise ValueError(
                f"expected b of shape ({mat.n},) or ({mat.n}, k) for "
                f"{matrix_id!r}, got {b.shape}")
        t = self._clock() if now is None else float(now)
        self.pump(now=t)
        k = bmat.shape[1]
        ticket = SolveTicket(matrix_id, mat.n, k, single, t)
        self.stats.requests += 1
        self.stats.columns += k
        if k == 0:
            return ticket
        bucket = self._pending.setdefault(matrix_id, [])
        for j in range(k):
            bucket.append((self._seq, t, ticket, j, bmat[:, j]))
            self._seq += 1
        # _flush replaces the pending list, so re-read it each iteration
        while len(self._pending.get(matrix_id, ())) >= self.max_batch:
            self._flush(matrix_id, t, FLUSH_FULL, count=self.max_batch)
        return ticket

    def pump(self, now: float | None = None) -> int:
        """Flush every bucket whose deadline has expired at ``now``
        (default: the injected clock).  Buckets flush in deterministic
        (deadline, arrival-order) order; returns the number of flushes."""
        t = self._clock() if now is None else float(now)
        n_flushed = 0
        while True:
            due = [(arr + self.max_delay, bucket[0][0], mid)
                   for mid, bucket in self._pending.items()
                   for arr in (bucket[0][1],)
                   if arr + self.max_delay <= t]
            if not due:
                return n_flushed
            _, _, mid = min(due)
            self._flush(mid, t, FLUSH_DEADLINE)
            n_flushed += 1

    def drain(self, now: float | None = None) -> int:
        """Flush everything pending regardless of deadline (shutdown /
        end-of-stream); returns the number of flushes."""
        t = self._clock() if now is None else float(now)
        n_flushed = 0
        while self._pending:
            mid = min(self._pending, key=lambda m: self._pending[m][0][0])
            self._flush(mid, t, FLUSH_DRAIN)
            n_flushed += 1
        return n_flushed

    # ------------------------------------------------------------------
    def _solver(self, prog: Program, k: int):
        if self.backend == "numpy":
            return lambda bmat: execute_numpy(prog, bmat)
        from .api import make_solver

        return make_solver(prog, batch=k, mesh=self.mesh,
                           backend=self.backend, **self.backend_opts)

    def _flush(self, matrix_id: str, now: float, reason: str,
               count: int | None = None) -> None:
        bucket = self._pending[matrix_id]
        if count is None:
            take, rest = bucket, []
        else:
            take, rest = bucket[:count], bucket[count:]
        if rest:
            self._pending[matrix_id] = rest
        else:
            del self._pending[matrix_id]
        k = len(take)
        prog = self.cache.get(self._mats[matrix_id])
        bmat = np.stack([col for (_, _, _, _, col) in take], axis=1)
        solve = self._solver(prog, k)
        t0 = self._timer() if self._timer is not None else 0.0
        x = np.asarray(solve(bmat))
        dt = (self._timer() - t0) if self._timer is not None else 0.0
        st = self.stats
        index = st.flush_count()
        if reason == FLUSH_FULL:
            st.flushes_full += 1
        elif reason == FLUSH_DEADLINE:
            st.flushes_deadline += 1
        else:
            st.flushes_drain += 1
        st.solver_calls += 1
        st.completed_columns += k
        if k > 1:
            st.batched_columns += k
        st.flushes.append(FlushRecord(
            index=index, matrix_id=matrix_id, reason=reason, columns=k,
            padded=pad_batch(k), at=now, service_s=dt))
        for i, (_, _, ticket, j, _) in enumerate(take):
            ticket._deliver(j, x[:, i], index, now)
        st.cache = self.cache.stats_dict()
