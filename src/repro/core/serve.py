"""Production solve service: continuous RHS micro-batching over a
multi-tenant program cache (DESIGN.md §9).

The accelerator's economics are compile-once/solve-many: a `Program` is
the expensive artifact, and production traffic (factorization loops,
preconditioner sweeps) is a *stream* of ``(matrix_id, b)`` requests
against a fleet of precompiled programs.  This module turns the batched
executors (DESIGN.md §4) into a service facing that stream:

  * `SolveService` — accepts single- or multi-column right-hand sides per
    registered matrix and micro-batches the columns per matrix into the
    padded widths the cached batched executors already key on
    (`executor.pad_batch` — the one bucketing function, shared with the
    executor cache so the two can never diverge).  A bucket flushes when
    it reaches ``max_batch`` columns or when its deadline — arrival of
    its oldest pending column plus ``max_delay`` — expires.  **Every
    scheduling decision runs on an injectable clock**: the core never
    reads wall time, so deadline-vs-full flush ordering, out-of-order
    completion and result routing are all unit-testable without sleeps
    (`tests/test_serve.py`).  Production callers get a real clock from
    `api.make_service`.
  * `ProgramCache` — a bounded LRU of compiled `Program`s keyed by
    `pattern_fingerprint` (a structure-only hash over the CSR pattern:
    two tenants registering the same sparsity pattern share one compile).
    A write-through disk tier (`serialize.save_program`) lets an evicted
    entry rehydrate through the CRC-verified `serialize.load_program`
    instead of re-running the compiler; a corrupted blob degrades to a
    recompile with a machine-readable `robust.Incident`, never a crash.
    Because the compiled value plane depends on the numeric values too,
    each entry carries a CRC of the source values — a same-pattern /
    different-values matrix is a miss (its own disk blob), never a
    silently wrong schedule reuse.
  * `ServeStats` — per-entry hit/miss/compile-time counters plus flush
    accounting (full vs deadline vs drain, batched column counts and a
    `FlushRecord` log) so load generators (`benchmarks/serve_load.py`)
    and dashboards read one record.

Request lifecycle: ``submit`` first pumps any bucket whose deadline is
already due (deadline flushes happen-before the new arrival), enqueues
the request's columns, then flushes full ``max_batch`` chunks
immediately.  ``pump(now)`` flushes due buckets in deterministic
(deadline, arrival-order) order; ``drain()`` flushes everything.  A
`SolveTicket` completes when its last column's bucket flushes — tickets
of a hot matrix can complete before earlier-submitted tickets of a cold
one, and each column routes back to exactly the ticket that submitted
it.  Batched columns are bit-identical to per-request solves (no
cross-column arithmetic exists in any executor), which the property
suite (`tests/test_serve_property.py`) pins down.

Resilient serving (DESIGN.md §10, ``resilience=`` on the service): each
request may carry a deadline — a bucket flushes *early* when waiting the
full ``max_delay`` would miss its tightest deadline, and an
already-expired ticket fails fast with a typed
`errors.DeadlineExceededError` instead of consuming solve width.  Each
flush solves through the PR-6 backend ladder (`robust.LADDER` from the
service's entry rung down to the CSR "reference" solve) with bounded
retry + deterministic-jitter backoff (`resilience.RetryPolicy`) per
rung, a per-(matrix, rung) circuit breaker (`resilience.BreakerBoard`)
gating rungs that keep failing, a per-attempt hang bound
(``flush_timeout_s``), and a non-finite output check — a flush either
delivers healthy numbers or fails its tickets with a typed error carrying
the incident trail, never silently wrong answers.  Admission control
(`resilience.AdmissionConfig`) bounds pending columns per matrix and
globally; an over-budget ``submit`` returns a typed `ShedTicket`.  Every
degradation event lands in ONE bounded `resilience.IncidentLog` shared
with the program cache's disk tier, rendered by ``report()`` through the
stable SPT3xx diagnostic codes.  All of it runs on the injectable clock —
the chaos harness (`robust.run_service_fault_injection`) replays fault
schedules deterministically.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import zlib
from collections import OrderedDict

import numpy as np

from .csr import TriCSR, serial_solve
from .errors import (
    BackendExecutionError,
    DeadlineExceededError,
    LoadShedError,
    ProgramCorruptionError,
)
from .executor import execute_numpy, pad_batch, validate_backend
from .program import AccelConfig, Program
from .resilience import BreakerBoard, IncidentLog, ResilienceConfig
from .robust import LADDER, _ENTRY, Incident
from .schedule import compile_program, recompile_values

__all__ = [
    "FLUSH_DEADLINE",
    "FLUSH_DRAIN",
    "FLUSH_FULL",
    "FLUSH_SHED",
    "CacheEntryStats",
    "FlushRecord",
    "ManualClock",
    "ProgramCache",
    "ServeStats",
    "ShedTicket",
    "SolveService",
    "SolveTicket",
    "pattern_fingerprint",
]

FLUSH_FULL = "full"          # bucket reached max_batch columns
FLUSH_DEADLINE = "deadline"  # oldest pending column aged past max_delay,
                             # or a request deadline forced an early flush
FLUSH_DRAIN = "drain"        # explicit drain() regardless of deadline
FLUSH_SHED = "shed"          # admission control rejected a submit (the
                             # record consumes no flush index: index=-1)

_FP_TAG = b"sptrsv-pattern-v1"


def pattern_fingerprint(mat: TriCSR, schedule: str = "paper") -> str:
    """Structure-only fingerprint of a CSR sparsity pattern (hex, 16 chars).

    Hashes ``(n, rowptr, colidx)`` and nothing else — numeric values do
    not participate, so a factorization loop re-solving one pattern with
    fresh values maps to one fingerprint (the cache guards value changes
    separately with a values CRC).  Two same-shape matrices with
    different patterns fingerprint differently.

    ``schedule`` is the scheduler-strategy the program is compiled with
    (DESIGN.md §11): a non-default strategy participates in the hash, so
    one pattern compiled under two strategies occupies two cache entries
    — no silent reuse of the wrong schedule.  The default ``"paper"``
    hashes exactly as before, keeping pre-frontier disk tiers valid.
    """
    h = hashlib.sha256(_FP_TAG)
    h.update(int(mat.n).to_bytes(8, "little"))
    h.update(np.ascontiguousarray(mat.rowptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(mat.colidx, dtype=np.int64).tobytes())
    if schedule != "paper":
        h.update(b"|schedule=" + schedule.encode())
    return h.hexdigest()[:16]


def _values_crc(mat: TriCSR) -> int:
    return zlib.crc32(np.ascontiguousarray(mat.values,
                                           dtype=np.float64).tobytes())


@dataclasses.dataclass
class CacheEntryStats:
    """Per-fingerprint counters of one `ProgramCache` entry."""

    fingerprint: str
    name: str = ""
    hits: int = 0            # served from the in-memory LRU
    disk_hits: int = 0       # rehydrated from the disk tier (no compile)
    compiles: int = 0        # compiler runs (cold miss or corrupt blob)
    value_refreshes: int = 0  # same-pattern/new-values misses served by
                              # `recompile_values` (schedule reused)
    disk_corrupt: int = 0    # disk blobs rejected by CRC/structural verify
    compile_seconds: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class ProgramCache:
    """Bounded LRU of compiled `Program`s with a CRC-verified disk tier.

    ``capacity`` bounds the in-memory tier (LRU eviction).  ``disk_dir``
    (optional) enables the disk tier: every compile is written through
    (`serialize.save_program`), so an evicted entry rehydrates via the
    checksummed `serialize.load_program` instead of re-running the
    compiler.  A corrupt blob is removed, recorded as a
    `robust.Incident` (``kind="disk-corrupt"``) in ``incidents``, and
    the entry recompiles — corruption can degrade performance, never
    correctness.  ``get`` is keyed by `pattern_fingerprint`; a values
    CRC rides along so same-pattern/different-values matrices never
    share a program (they do share a fingerprint and get distinct disk
    blobs).
    """

    def __init__(self, capacity: int = 32, disk_dir=None,
                 cfg: AccelConfig | None = None, compile_fn=None,
                 schedule: str = "paper", incident_cap: int = 1024):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.disk_dir = os.fspath(disk_dir) if disk_dir is not None else None
        self._cfg = cfg
        # the strategy keys the fingerprint (same pattern under two
        # strategies -> two entries) and parameterizes the default compile
        self.schedule = schedule
        self._compile = compile_fn or (
            lambda m: compile_program(m, cfg, schedule=schedule))
        self._mem: "OrderedDict[str, tuple[Program, int]]" = OrderedDict()
        self.entries: dict[str, CacheEntryStats] = {}
        # ONE bounded incident log for the whole serving layer: the
        # service that wraps this cache shares the same object, so disk
        # corruption, retries, breaker flips and sheds interleave in one
        # capped record instead of fragmenting across components.
        self.incidents = IncidentLog(incident_cap)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.value_refreshes = 0

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._mem)

    def fingerprints(self) -> list[str]:
        """In-memory fingerprints, least- to most-recently used."""
        return list(self._mem)

    def _path(self, fp: str, vcrc: int) -> str | None:
        if self.disk_dir is None:
            return None
        return os.path.join(self.disk_dir, f"{fp}.{vcrc:08x}.prog")

    def _entry(self, fp: str, name: str) -> CacheEntryStats:
        ent = self.entries.get(fp)
        if ent is None:
            ent = CacheEntryStats(fingerprint=fp, name=name)
            self.entries[fp] = ent
        return ent

    # ------------------------------------------------------------------
    def get(self, mat: TriCSR) -> Program:
        """The compiled program for ``mat``'s pattern+values, through the
        tiers: memory LRU -> disk rehydrate -> compile (write-through)."""
        fp = pattern_fingerprint(mat, self.schedule)
        vcrc = _values_crc(mat)
        ent = self._entry(fp, mat.name)
        cached = self._mem.get(fp)
        stale: Program | None = None
        if cached is not None:
            prog, crc = cached
            if crc == vcrc:
                self._mem.move_to_end(fp)
                ent.hits += 1
                self.hits += 1
                return prog
            # same pattern, new numeric values: a guarded miss, but the
            # schedule depends only on the pattern — when the program
            # carries its value-provenance plane the stream is regathered
            # through `recompile_values` instead of re-running the
            # pipeline (the factorization-loop fast path).
            stale = prog
            del self._mem[fp]
        self.misses += 1
        prog = self._refresh(stale, mat, fp, vcrc, ent)
        if prog is None:
            prog = self._rehydrate(fp, vcrc, ent)
        if prog is None:
            prog = self._compile(mat)
            ent.compiles += 1
            ent.compile_seconds += float(prog.stats.compile_seconds or 0.0)
            self._write_through(fp, vcrc, prog)
        self._insert(fp, vcrc, prog)
        return prog

    def _refresh(self, stale: Program | None, mat: TriCSR, fp: str,
                 vcrc: int, ent: CacheEntryStats) -> Program | None:
        """Values-only refresh of a same-pattern stale entry, when its
        provenance plane allows; the refreshed program gets its own disk
        blob (the disk tier is keyed by values CRC too)."""
        if stale is None or stale.stream_src is None:
            return None
        try:
            prog = recompile_values(stale, mat)
        except ValueError:
            return None  # defensive: fingerprint collision / stale plane
        ent.value_refreshes += 1
        self.value_refreshes += 1
        self._write_through(fp, vcrc, prog)
        return prog

    def _rehydrate(self, fp: str, vcrc: int,
                   ent: CacheEntryStats) -> Program | None:
        path = self._path(fp, vcrc)
        if path is None or not os.path.exists(path):
            return None
        from .serialize import load_program

        try:
            prog = load_program(path)  # CRC + structural verify
        except ProgramCorruptionError as e:
            ent.disk_corrupt += 1
            self.incidents.append(Incident(
                stage="program-cache", kind="disk-corrupt",
                message=f"disk entry for {fp} rejected, recompiling: {e}",
                error=type(e).__name__,
                detail={"fingerprint": fp, "path": path}))
            os.remove(path)
            return None
        ent.disk_hits += 1
        return prog

    def _write_through(self, fp: str, vcrc: int, prog: Program) -> None:
        path = self._path(fp, vcrc)
        if path is None:
            return
        from .serialize import save_program

        os.makedirs(self.disk_dir, exist_ok=True)
        save_program(prog, path)

    def _insert(self, fp: str, vcrc: int, prog: Program) -> None:
        self._mem[fp] = (prog, vcrc)
        self._mem.move_to_end(fp)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self.evictions += 1

    def stats_dict(self) -> dict:
        return {
            "hits": self.hits, "misses": self.misses,
            "evictions": self.evictions, "resident": len(self._mem),
            "capacity": self.capacity,
            "value_refreshes": self.value_refreshes,
            "incidents": len(self.incidents),
            "incidents_dropped": self.incidents.dropped,
            "entries": {fp: e.to_dict() for fp, e in self.entries.items()},
        }


# ---------------------------------------------------------------------------
# service
# ---------------------------------------------------------------------------
class ManualClock:
    """Deterministic injectable clock: returns ``now`` until advanced."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        self.now += float(dt)
        return self.now


class SolveTicket:
    """Routing handle for one submitted request.

    Completes when the last of its columns has been solved (columns of a
    wide request can span several flushes).  ``result()`` returns ``[n]``
    for a 1-D submit and ``[n, k]`` for a 2-D one; calling it before the
    ticket is done raises (pump or drain the service first).

    A ticket can also complete by *failing*: an expired request deadline
    or an exhausted backend ladder marks the whole ticket failed
    (``failed``, with the typed `errors.RobustnessError` in ``error``)
    and ``result()`` re-raises it — a wide ticket fails whole, partial
    column sets are never returned.  ``deadline`` (optional, on the
    service clock) is the latest time delivery still counts.
    """

    shed = False  # `ShedTicket` overrides; uniform check for callers

    def __init__(self, matrix_id: str, n: int, k: int, single: bool,
                 submitted_at: float, deadline: float | None = None):
        self.matrix_id = matrix_id
        self.columns = k
        self.submitted_at = submitted_at
        self.deadline = deadline
        self.completed_at: float | None = None
        self.flush_indices: list[int] = []
        self._single = single
        self._x: np.ndarray | None = None
        self._n = n
        self._remaining = k
        self._error: Exception | None = None
        if k == 0:  # degenerate [n, 0] request: nothing to solve
            self._x = np.zeros((n, 0), dtype=np.float32)
            self.completed_at = submitted_at

    @property
    def done(self) -> bool:
        return self._remaining == 0

    @property
    def failed(self) -> bool:
        return self._error is not None

    @property
    def error(self) -> Exception | None:
        return self._error

    def _deliver(self, j: int, col: np.ndarray, flush_index: int,
                 at: float) -> None:
        if self._error is not None:
            return  # ticket already failed whole; drop the late column
        if self._x is None:
            self._x = np.empty((self._n, self.columns), dtype=col.dtype)
        self._x[:, j] = col
        self._remaining -= 1
        if flush_index not in self.flush_indices:
            self.flush_indices.append(flush_index)
        if self._remaining == 0:
            self.completed_at = at

    def _fail(self, exc: Exception, at: float) -> None:
        if self.done:
            return
        self._error = exc
        self._remaining = 0
        self.completed_at = at

    def result(self) -> np.ndarray:
        if not self.done:
            raise RuntimeError(
                f"ticket for {self.matrix_id!r} not complete "
                f"({self._remaining}/{self.columns} columns pending) — "
                f"pump() or drain() the service")
        if self._error is not None:
            raise self._error
        return self._x[:, 0] if self._single else self._x


class ShedTicket(SolveTicket):
    """Typed admission-control rejection; quacks like a completed ticket.

    Returned by ``submit`` when the request's columns would exceed a
    pending budget (`resilience.AdmissionConfig`).  ``done`` is True
    immediately, ``shed`` marks the rejection, and ``result()`` raises
    the `errors.LoadShedError` carrying the violated budget in
    ``.detail`` — callers retry later or route elsewhere.
    """

    shed = True

    def __init__(self, matrix_id: str, n: int, k: int, single: bool,
                 at: float, error: LoadShedError):
        super().__init__(matrix_id, n, k, single, at)
        self._error = error
        self._remaining = 0
        self.completed_at = at


@dataclasses.dataclass
class FlushRecord:
    """One executed micro-batch (the unit `benchmarks/serve_load.py`
    replays for its queueing model)."""

    index: int         # -1 for FLUSH_SHED records (no solver ran)
    matrix_id: str
    reason: str        # FLUSH_FULL | FLUSH_DEADLINE | FLUSH_DRAIN | FLUSH_SHED
    columns: int       # real RHS columns solved (or shed)
    padded: int        # executor batch width (pad_batch of columns)
    at: float          # injectable-clock time the flush ran
    service_s: float   # measured solve wall time (0.0 without a timer)
    stage: str = ""    # ladder rung that answered ("" on the legacy path
                       # and on failed/shed records)


@dataclasses.dataclass
class ServeStats:
    """Aggregate service counters + the per-entry cache counters."""

    requests: int = 0
    columns: int = 0
    completed_columns: int = 0
    solver_calls: int = 0
    batched_columns: int = 0   # columns solved in flushes of >1 column
    flushes_full: int = 0
    flushes_deadline: int = 0
    flushes_drain: int = 0
    # resilience accounting (DESIGN.md §10); all zero on the legacy path
    requests_shed: int = 0          # submits rejected by admission control
    columns_shed: int = 0
    deadline_failed_columns: int = 0  # columns failed fast, deadline expired
    retries: int = 0                # backend attempts retried with backoff
    degraded_flushes: int = 0       # flushes answered below the entry rung
    failed_flushes: int = 0         # flushes that exhausted the ladder
    flushes: list = dataclasses.field(default_factory=list)
    cache: dict = dataclasses.field(default_factory=dict)

    def flush_count(self) -> int:
        """Solver flushes (shed records carry index=-1 and do not count)."""
        return self.flushes_full + self.flushes_deadline + self.flushes_drain

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["flushes"] = [dataclasses.asdict(f) if dataclasses.is_dataclass(f)
                        else f for f in self.flushes]
        return d


class SolveService:
    """Continuous micro-batching front end over a `ProgramCache`.

    ``clock`` is any ``() -> float`` callable; the default is a
    `ManualClock` at 0.0 so the core is deterministic out of the box
    (production passes ``time.monotonic`` via `api.make_service`).
    ``timer`` (optional ``() -> float``) measures solve wall time for
    `FlushRecord.service_s` — left unset, records carry 0.0 and the core
    stays wall-clock-free.  ``backend`` is "numpy", "jax" or "pallas"
    (+ ``mesh=`` and the `api.make_solver` knobs); bucketing uses
    `executor.pad_batch`, the same rounding the executor cache keys on,
    so a service never provokes more than one trace per (program, padded
    width, backend knobs).
    """

    def __init__(self, cache: ProgramCache | None = None, *,
                 max_batch: int = 16, max_delay: float = 1e-3,
                 clock=None, timer=None, backend: str = "jax", mesh=None,
                 resilience: ResilienceConfig | None = None,
                 **backend_opts):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay < 0:
            raise ValueError(f"max_delay must be >= 0, got {max_delay}")
        if backend == "numpy":
            if mesh is not None or backend_opts:
                raise ValueError("backend='numpy' takes no mesh/extra options")
        else:
            validate_backend(backend, {} if backend == "jax"
                             else backend_opts)
        self.cache = cache if cache is not None else ProgramCache()
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay)
        self.backend = backend
        self.mesh = mesh
        self.backend_opts = backend_opts
        self._clock = clock if clock is not None else ManualClock()
        self._timer = timer
        self._mats: dict[str, TriCSR] = {}
        # matrix_id -> list of (seq, arrival, ticket, column_index, column)
        self._pending: dict[str, list] = {}
        self._seq = 0
        self.stats = ServeStats()
        # one shared, bounded incident log for cache + service events
        self.incidents = self.cache.incidents
        self.resilience = resilience
        self._breakers: BreakerBoard | None = None
        if resilience is not None:
            self.incidents.set_cap(resilience.incident_cap)
            self._breakers = BreakerBoard(resilience.breaker,
                                          sink=self.incidents)
            # degradation order from the service's entry rung down to the
            # CSR reference solve (always available: tenants are retained)
            self._ladder = LADDER[_ENTRY[backend]:]

    # ------------------------------------------------------------------
    def register(self, matrix_id: str, mat: TriCSR) -> str:
        """Register a tenant matrix; returns its pattern fingerprint.

        Registration only records the matrix — compilation happens on
        the first flush, through the cache tiers (so two ids sharing one
        pattern+values compile once)."""
        if matrix_id in self._mats:
            raise ValueError(f"matrix_id {matrix_id!r} already registered")
        self._mats[matrix_id] = mat
        return pattern_fingerprint(mat, getattr(self.cache, "schedule",
                                                "paper"))

    def matrix_ids(self) -> list[str]:
        return list(self._mats)

    def pending_columns(self, matrix_id: str | None = None) -> int:
        if matrix_id is not None:
            return len(self._pending.get(matrix_id, ()))
        return sum(len(v) for v in self._pending.values())

    # ------------------------------------------------------------------
    def submit(self, matrix_id: str, b: np.ndarray, *,
               now: float | None = None, deadline: float | None = None,
               timeout: float | None = None) -> SolveTicket:
        """Enqueue a right-hand side; returns its `SolveTicket`.

        ``deadline`` (absolute, on the service clock) or ``timeout``
        (relative to now; at most one of the two) bounds the request:
        its bucket flushes early rather than miss it, and an
        already-expired request fails fast with a typed
        `errors.DeadlineExceededError` instead of consuming a solve.
        Under admission control (``resilience=``), a request whose
        columns would exceed a pending budget returns a `ShedTicket`
        without enqueueing anything.

        Order of effects: (1) pump every bucket that is already due —
        those flushes happen-before the new arrival (and free budget);
        (2) fail-fast / admission checks; (3) enqueue the request's
        columns; (4) flush full ``max_batch`` chunks of this bucket
        immediately (a wide request can trigger several)."""
        mat = self._mats.get(matrix_id)
        if mat is None:
            raise KeyError(f"unknown matrix_id {matrix_id!r} "
                           f"(registered: {sorted(self._mats)})")
        b = np.asarray(b)
        single = b.ndim == 1
        bmat = b[:, None] if single else b
        if bmat.ndim != 2 or bmat.shape[0] != mat.n:
            raise ValueError(
                f"expected b of shape ({mat.n},) or ({mat.n}, k) for "
                f"{matrix_id!r}, got {b.shape}")
        t = self._clock() if now is None else float(now)
        if deadline is not None and timeout is not None:
            raise ValueError("pass deadline= or timeout=, not both")
        if timeout is not None:
            deadline = t + float(timeout)
        self.pump(now=t)
        k = bmat.shape[1]
        self.stats.requests += 1
        self.stats.columns += k
        if k == 0:
            return SolveTicket(matrix_id, mat.n, 0, single, t, deadline)
        if deadline is not None and deadline < t:
            # already expired: fail fast, consume nothing
            ticket = SolveTicket(matrix_id, mat.n, k, single, t, deadline)
            err = DeadlineExceededError(
                f"request for {matrix_id!r} expired before submit "
                f"(deadline {deadline:.6f} < now {t:.6f})",
                detail={"matrix_id": matrix_id, "deadline": float(deadline),
                        "now": t, "columns": k})
            ticket._fail(err, t)
            self.stats.deadline_failed_columns += k
            self.incidents.append(Incident(
                stage="serve", kind="deadline-expired", message=str(err),
                error=type(err).__name__, detail=dict(err.detail)))
            return ticket
        shed = self._admit(matrix_id, k, single, mat.n, t)
        if shed is not None:
            return shed
        ticket = SolveTicket(matrix_id, mat.n, k, single, t, deadline)
        bucket = self._pending.setdefault(matrix_id, [])
        for j in range(k):
            bucket.append((self._seq, t, ticket, j, bmat[:, j]))
            self._seq += 1
        # _flush replaces the pending list, so re-read it each iteration
        while len(self._pending.get(matrix_id, ())) >= self.max_batch:
            self._flush(matrix_id, t, FLUSH_FULL, count=self.max_batch)
        return ticket

    def _admit(self, matrix_id: str, k: int, single: bool, n: int,
               t: float) -> ShedTicket | None:
        """Admission check; a `ShedTicket` when a budget would overflow."""
        if self.resilience is None:
            return None
        adm = self.resilience.admission
        over = None
        per = adm.max_pending_per_matrix
        if per is not None and \
                len(self._pending.get(matrix_id, ())) + k > per:
            over = ("max_pending_per_matrix", per,
                    len(self._pending.get(matrix_id, ())))
        tot = adm.max_pending_total
        if over is None and tot is not None and \
                self.pending_columns() + k > tot:
            over = ("max_pending_total", tot, self.pending_columns())
        if over is None:
            return None
        budget, limit, pending = over
        err = LoadShedError(
            f"request for {matrix_id!r} shed: {k} column(s) would "
            f"exceed {budget}={limit} ({pending} pending)",
            detail={"matrix_id": matrix_id, "budget": budget,
                    "limit": int(limit), "pending": int(pending),
                    "columns": k})
        st = self.stats
        st.requests_shed += 1
        st.columns_shed += k
        st.flushes.append(FlushRecord(
            index=-1, matrix_id=matrix_id, reason=FLUSH_SHED, columns=k,
            padded=0, at=t, service_s=0.0))
        self.incidents.append(Incident(
            stage="serve", kind="shed", message=str(err),
            error=type(err).__name__, detail=dict(err.detail)))
        return ShedTicket(matrix_id, n, k, single, t, err)

    def _due_time(self, bucket: list) -> float:
        """When this bucket must flush: oldest arrival + ``max_delay``,
        tightened by the tightest request deadline among its columns (a
        bucket flushes early rather than miss a deadline it could meet)."""
        due = bucket[0][1] + self.max_delay
        for (_, _, ticket, _, _) in bucket:
            d = ticket.deadline
            if d is not None and d < due:
                due = d
        return due

    def pump(self, now: float | None = None) -> int:
        """Flush every bucket that is due at ``now`` (default: the
        injected clock) — its oldest column aged past ``max_delay``, or
        a request deadline would otherwise be missed.  Buckets flush in
        deterministic (due-time, arrival-order) order; returns the
        number of flushes."""
        t = self._clock() if now is None else float(now)
        n_flushed = 0
        while True:
            due = [(due_t, bucket[0][0], mid)
                   for mid, bucket in self._pending.items()
                   for due_t in (self._due_time(bucket),)
                   if due_t <= t]
            if not due:
                return n_flushed
            _, _, mid = min(due)
            self._flush(mid, t, FLUSH_DEADLINE)
            n_flushed += 1

    def drain(self, now: float | None = None) -> int:
        """Flush everything pending regardless of deadline (shutdown /
        end-of-stream); returns the number of flushes."""
        t = self._clock() if now is None else float(now)
        n_flushed = 0
        while self._pending:
            mid = min(self._pending, key=lambda m: self._pending[m][0][0])
            self._flush(mid, t, FLUSH_DRAIN)
            n_flushed += 1
        return n_flushed

    # ------------------------------------------------------------------
    def _solver(self, prog: Program, k: int):
        if self.backend == "numpy":
            return lambda bmat: execute_numpy(prog, bmat)
        from .api import make_solver

        return make_solver(prog, batch=k, mesh=self.mesh,
                           backend=self.backend, **self.backend_opts)

    def _flush(self, matrix_id: str, now: float, reason: str,
               count: int | None = None) -> None:
        bucket = self._pending[matrix_id]
        if count is None:
            take, rest = bucket, []
        else:
            take, rest = bucket[:count], bucket[count:]
        if rest:
            self._pending[matrix_id] = rest
        else:
            del self._pending[matrix_id]
        take = self._expire(take, matrix_id, now)
        k = len(take)
        if k == 0:
            self.stats.cache = self.cache.stats_dict()
            return
        prog = self.cache.get(self._mats[matrix_id])
        bmat = np.stack([col for (_, _, _, _, col) in take], axis=1)
        st = self.stats
        t0 = self._timer() if self._timer is not None else 0.0
        err: Exception | None = None
        stage = ""
        if self.resilience is None:
            solve = self._solver(prog, k)
            x = np.asarray(solve(bmat))
        else:
            try:
                x, stage = self._resilient_solve(matrix_id, prog, bmat, k)
            except BackendExecutionError as e:
                err, x = e, None
        dt = (self._timer() - t0) if self._timer is not None else 0.0
        index = st.flush_count()
        if reason == FLUSH_FULL:
            st.flushes_full += 1
        elif reason == FLUSH_DEADLINE:
            st.flushes_deadline += 1
        else:
            st.flushes_drain += 1
        st.solver_calls += 1
        st.flushes.append(FlushRecord(
            index=index, matrix_id=matrix_id, reason=reason, columns=k,
            padded=pad_batch(k), at=now, service_s=dt, stage=stage))
        if err is not None:
            st.failed_flushes += 1
            for (_, _, ticket, _, _) in take:
                ticket._fail(err, now)
        else:
            st.completed_columns += k
            if k > 1:
                st.batched_columns += k
            if self.resilience is not None and stage != self._ladder[0]:
                st.degraded_flushes += 1
            for i, (_, _, ticket, j, _) in enumerate(take):
                ticket._deliver(j, x[:, i], index, now)
        st.cache = self.cache.stats_dict()

    def _expire(self, take: list, matrix_id: str, now: float) -> list:
        """Fail expired entries fast (typed, no solve consumed) and drop
        columns of tickets that already failed; returns the live rest."""
        live = []
        for entry in take:
            ticket = entry[2]
            if ticket.failed:
                continue  # failed whole earlier (deadline / prior flush)
            d = ticket.deadline
            if d is not None and d < now:
                err = DeadlineExceededError(
                    f"request for {matrix_id!r} missed its deadline "
                    f"(deadline {d:.6f} < now {now:.6f})",
                    detail={"matrix_id": matrix_id, "deadline": float(d),
                            "now": float(now),
                            "columns": ticket.columns})
                ticket._fail(err, now)
                self.stats.deadline_failed_columns += ticket.columns
                self.incidents.append(Incident(
                    stage="serve", kind="deadline-expired",
                    message=str(err), error=type(err).__name__,
                    detail=dict(err.detail)))
                continue
            live.append(entry)
        return live

    # -- resilient solve path (DESIGN.md §10) --------------------------
    def _stage_solver(self, stage: str, prog: Program, k: int,
                      mat: TriCSR):
        """Build the solve closure of one ladder rung (executor caches
        make repeated construction cheap — keyed on program identity)."""
        if stage == "numpy":
            return lambda bmat: execute_numpy(prog, bmat)
        if stage == "reference":
            def fn(bmat):
                bm = np.asarray(bmat, dtype=np.float64)
                return np.stack([serial_solve(mat, bm[:, j])
                                 for j in range(bm.shape[1])], axis=1)
            return fn
        from .api import make_solver

        if stage == "jax":
            return make_solver(prog, batch=k, backend="jax")
        placement = ("blocked" if stage == "pallas-blocked" else "resident")
        opts = {kk: v for kk, v in self.backend_opts.items()
                if kk != "placement"}
        return make_solver(prog, batch=k, mesh=self.mesh, backend="pallas",
                           placement=placement, **opts)

    def _resilient_solve(self, matrix_id: str, prog: Program,
                         bmat: np.ndarray, k: int):
        """One flush through the backend ladder under the resilience
        policy; returns ``(x, stage)`` or raises `BackendExecutionError`
        with the flush's incident trail in ``.detail["incidents"]``.

        Per rung: breaker gate (open rungs are skipped; if *every* rung
        is gated the terminal rung runs anyway — the service always
        answers), bounded retry with deterministic backoff on
        exceptions, a hang bound (``flush_timeout_s``) and a non-finite
        output check — health failures are deterministic, so they
        degrade immediately instead of retrying.
        """
        res = self.resilience
        mat = self._mats[matrix_id]
        trail: list[Incident] = []

        def record(stage, kind, message, *, error="", attempt=1,
                   elapsed_s=0.0, detail=None):
            inc = Incident(stage=stage, kind=kind, message=message,
                           error=error, attempt=attempt,
                           elapsed_s=float(elapsed_s),
                           detail={"matrix_id": matrix_id,
                                   **(detail or {})})
            trail.append(inc)
            self.incidents.append(inc)

        t_gate = self._clock()
        stages = [s for s in self._ladder
                  if self._breakers.allow((matrix_id, s), t_gate)]
        if not stages:
            stages = [self._ladder[-1]]
        for stage in stages:
            key = (matrix_id, stage)
            try:
                fn = self._stage_solver(stage, prog, k, mat)
            except Exception as e:  # placement infeasible, build failure
                record(stage, "build-failed", str(e),
                       error=type(e).__name__)
                self._breakers.record(key, self._clock(), False)
                continue
            for attempt in range(1, res.retry.max_retries + 2):
                t0 = self._clock()
                try:
                    x = np.asarray(fn(bmat))
                except Exception as e:
                    t1 = self._clock()
                    record(stage, "exception", str(e),
                           error=type(e).__name__, attempt=attempt,
                           elapsed_s=t1 - t0)
                    self._breakers.record(key, t1, False)
                    if attempt <= res.retry.max_retries:
                        d = res.retry.delay(attempt,
                                            key=f"{matrix_id}:{stage}")
                        record(stage, "backoff",
                               f"retrying {stage} after {d:.4f}s backoff",
                               attempt=attempt,
                               detail={"backoff_s": d})
                        self.stats.retries += 1
                        if res.sleep is not None:
                            res.sleep(d)
                        continue
                    break  # rung exhausted its retries: degrade
                elapsed = self._clock() - t0
                if res.flush_timeout_s is not None \
                        and elapsed > res.flush_timeout_s:
                    record(stage, "hang",
                           f"{stage} attempt took {elapsed:.4f}s > flush "
                           f"timeout {res.flush_timeout_s:.4f}s",
                           attempt=attempt, elapsed_s=elapsed)
                    self._breakers.record(key, self._clock(), False)
                    break  # never retry a hung rung within the flush
                if not np.isfinite(x).all():
                    record(stage, "nonfinite-output",
                           f"{int(np.count_nonzero(~np.isfinite(x)))} "
                           f"non-finite solution component(s)",
                           attempt=attempt, elapsed_s=elapsed)
                    self._breakers.record(key, self._clock(), False)
                    break  # deterministic health failure: degrade
                self._breakers.record(key, self._clock(), True)
                return x, stage
        msg = (f"flush for {matrix_id!r} exhausted the backend ladder "
               f"({len(trail)} incident(s); stages tried {stages})")
        record("serve", "ladder-exhausted", msg)
        raise BackendExecutionError(
            msg, detail={"matrix_id": matrix_id,
                         "incidents": [i.to_dict() for i in trail]})

    # ------------------------------------------------------------------
    def report(self):
        """The service's health record as an `analysis.AnalysisReport`.

        Every incident of the shared log (cache disk tier + resilient
        flush path) renders as a stable SPT3xx `analysis.Diagnostic`
        (`resilience.incident_to_diagnostic`); log saturation surfaces
        as SPT309.  ``report().to_json()`` / ``report().render()`` are
        the same two renderers the static-analysis CLI uses — one
        machine-readable incident surface across the repo.
        """
        from .analysis.diagnostics import AnalysisReport, Diagnostic
        from .resilience import incident_to_diagnostic

        st = self.stats
        meta = {
            "backend": self.backend,
            "tenants": len(self._mats),
            "requests": st.requests,
            "columns": st.columns,
            "completed_columns": st.completed_columns,
            "flushes": st.flush_count(),
            "requests_shed": st.requests_shed,
            "deadline_failed_columns": st.deadline_failed_columns,
            "retries": st.retries,
            "degraded_flushes": st.degraded_flushes,
            "failed_flushes": st.failed_flushes,
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "value_refreshes": self.cache.value_refreshes,
        }
        if self._breakers is not None:
            meta["breakers"] = self._breakers.states()
        rep = AnalysisReport(name=f"serve[{self.backend}]", meta=meta)
        rep.extend(incident_to_diagnostic(i) for i in self.incidents)
        if self.incidents.dropped:
            rep.diagnostics.append(Diagnostic(
                code="SPT309", severity="warn", pass_name="serve",
                message=f"incident log saturated: {self.incidents.dropped} "
                        f"oldest record(s) dropped (cap "
                        f"{self.incidents.cap})",
                detail={"dropped": self.incidents.dropped,
                        "cap": self.incidents.cap}))
        return rep
