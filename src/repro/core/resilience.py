"""Resilience primitives for the serving layer (DESIGN.md §10).

The solve service (DESIGN.md §9) turns a fleet of compiled programs into
a request stream; this module supplies the control-plane machinery that
keeps that stream healthy under faults and overload, all of it
deterministic and wall-clock-free so every policy is unit-testable on a
`serve.ManualClock`:

  * `IncidentLog` — ONE bounded, indexable log of `robust.Incident`
    records shared by every serving-layer producer (the program cache's
    disk-tier corruption events, retry/backoff, breaker transitions,
    deadline failures, load sheds).  Saturation drops the oldest records
    and counts them (``dropped``) instead of growing without bound; the
    service report surfaces saturation as an SPT309 diagnostic.
  * `RetryPolicy` — exponential backoff with *deterministic* jitter: the
    delay for (key, attempt) is a pure function of the policy seed, so a
    replayed fault schedule yields a bit-identical backoff schedule.  No
    randomness source is consulted at solve time and the core never
    sleeps itself — the computed delay goes to an injectable sleeper.
  * `CircuitBreaker` / `BreakerBoard` — a closed → open → half-open
    state machine per (matrix, backend-rung) key over a sliding
    failure-rate window.  Pure state + an explicit ``now`` argument on
    every operation: the breaker holds no clock.  Every transition is
    recorded as a `robust.Incident` (kind ``breaker-*``) in the shared
    log.
  * `AdmissionConfig` / `ResilienceConfig` — the aggregate knob surface
    `serve.SolveService` consumes: per-matrix and global pending-column
    budgets (admission control / load shedding), the retry policy, the
    breaker config, and the per-stage flush timeout that classifies a
    hung backend.
  * `incident_to_diagnostic` — renders any serving-layer incident as an
    `analysis.Diagnostic` under the stable SPT3xx code block, so
    `SolveService.report()` speaks the same machine-readable JSON as the
    static analyzer (DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import deque

from .analysis.diagnostics import SEV_ERROR, SEV_INFO, SEV_WARN, Diagnostic
from .robust import Incident

__all__ = [
    "BREAKER_CLOSED",
    "BREAKER_OPEN",
    "BREAKER_HALF_OPEN",
    "AdmissionConfig",
    "BreakerBoard",
    "BreakerConfig",
    "CircuitBreaker",
    "IncidentLog",
    "ResilienceConfig",
    "RetryPolicy",
    "incident_to_diagnostic",
]


# ---------------------------------------------------------------------------
# incident log
# ---------------------------------------------------------------------------
class IncidentLog:
    """Bounded append-only log of `robust.Incident` records.

    List-like for the read paths the serving tests already use
    (``log[-1]``, ``len(log)``, iteration, slicing) but capped: past
    ``cap`` records the oldest are dropped and counted in ``dropped``
    rather than growing the log without bound — an incident *storm*
    (flapping breaker, corrupt disk tier) must not turn into a memory
    leak on a long-lived service.  The service report renders a non-zero
    ``dropped`` as an SPT309 diagnostic so saturation itself is visible.
    """

    def __init__(self, cap: int = 1024):
        if cap < 1:
            raise ValueError(f"cap must be >= 1, got {cap}")
        self.cap = int(cap)
        self.dropped = 0
        self._items: list[Incident] = []

    def set_cap(self, cap: int) -> None:
        if cap < 1:
            raise ValueError(f"cap must be >= 1, got {cap}")
        self.cap = int(cap)
        self._trim()

    def _trim(self) -> None:
        excess = len(self._items) - self.cap
        if excess > 0:
            del self._items[:excess]
            self.dropped += excess

    def append(self, inc: Incident) -> Incident:
        self._items.append(inc)
        self._trim()
        return inc

    def extend(self, incs) -> None:
        for inc in incs:
            self.append(inc)

    def __len__(self) -> int:
        return len(self._items)

    def __getitem__(self, i):
        return self._items[i]

    def __iter__(self):
        return iter(self._items)

    def by_kind(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for inc in self._items:
            out[inc.kind] = out.get(inc.kind, 0) + 1
        return out

    def to_list(self) -> list[dict]:
        return [inc.to_dict() for inc in self._items]


# ---------------------------------------------------------------------------
# retry / backoff
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic, seeded jitter.

    ``delay(attempt, key)`` is a pure function: exponential growth from
    ``base_delay_s`` capped at ``max_delay_s``, then shrunk by up to
    ``jitter`` (a fraction in [0, 1]) using a uniform deviate derived by
    hashing ``(seed, key, attempt)`` — no RNG state, no wall clock, so a
    fixed seed replays the exact backoff schedule and two keys (say two
    matrices retrying the same rung) desynchronize instead of
    thundering-herding.  ``max_retries`` counts *extra* attempts after
    the first failure of one ladder rung.
    """

    max_retries: int = 1
    base_delay_s: float = 0.01
    max_delay_s: float = 1.0
    multiplier: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")
        if self.base_delay_s < 0 or self.max_delay_s < 0:
            raise ValueError("delays must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError(f"multiplier must be >= 1, got {self.multiplier}")

    def delay(self, attempt: int, key: str = "") -> float:
        """Backoff before retry number ``attempt`` (1-based) of ``key``."""
        if attempt < 1:
            raise ValueError(f"attempt is 1-based, got {attempt}")
        raw = min(self.base_delay_s * self.multiplier ** (attempt - 1),
                  self.max_delay_s)
        if not self.jitter or raw == 0.0:
            return raw
        h = hashlib.sha256(
            f"retry:{self.seed}:{key}:{attempt}".encode()).digest()
        u = int.from_bytes(h[:8], "little") / 2.0 ** 64  # uniform [0, 1)
        return raw * (1.0 - self.jitter * u)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------
BREAKER_CLOSED = "closed"        # normal operation, outcomes windowed
BREAKER_OPEN = "open"            # rung gated; cooldown running
BREAKER_HALF_OPEN = "half-open"  # probing: limited traffic allowed


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Knobs of one `CircuitBreaker` (shared across a `BreakerBoard`)."""

    window_s: float = 30.0          # sliding outcome window
    min_samples: int = 4            # outcomes needed before judging
    failure_threshold: float = 0.5  # open at >= this failure fraction
    cooldown_s: float = 10.0        # open -> half-open probe delay
    half_open_probes: int = 1       # consecutive successes to close

    def __post_init__(self):
        if self.window_s <= 0 or self.cooldown_s < 0:
            raise ValueError("window_s must be > 0 and cooldown_s >= 0")
        if self.min_samples < 1 or self.half_open_probes < 1:
            raise ValueError("min_samples and half_open_probes must be >= 1")
        if not 0.0 < self.failure_threshold <= 1.0:
            raise ValueError(
                f"failure_threshold must be in (0, 1], got "
                f"{self.failure_threshold}")


class CircuitBreaker:
    """closed → open → half-open breaker over a sliding failure window.

    Pure state machine: every operation takes an explicit ``now`` (the
    caller's injectable clock) and the breaker never reads time itself.
    While CLOSED, outcomes within ``window_s`` are counted; once at
    least ``min_samples`` are present and the failure fraction reaches
    ``failure_threshold`` the breaker OPENs.  ``allow(now)`` gates
    traffic: False while OPEN until ``cooldown_s`` elapses, then the
    breaker turns HALF_OPEN and admits probes — ``half_open_probes``
    consecutive successes close it (window cleared), any failure
    re-opens it and re-arms the cooldown.  ``on_transition`` (set by the
    `BreakerBoard`) observes every state change.
    """

    def __init__(self, key, cfg: BreakerConfig, on_transition=None):
        self.key = key
        self.cfg = cfg
        self.state = BREAKER_CLOSED
        self.opened_at: float | None = None
        self.transitions = 0
        self._events: deque = deque()   # (now, ok) within window_s
        self._probe_successes = 0
        self._on_transition = on_transition

    def _trim(self, now: float) -> None:
        horizon = now - self.cfg.window_s
        while self._events and self._events[0][0] <= horizon:
            self._events.popleft()

    def _move(self, new: str, now: float, reason: str) -> None:
        old, self.state = self.state, new
        self.transitions += 1
        if new == BREAKER_OPEN:
            self.opened_at = now
            self._probe_successes = 0
        elif new == BREAKER_CLOSED:
            self.opened_at = None
            self._events.clear()
            self._probe_successes = 0
        if self._on_transition is not None:
            self._on_transition(self, old, new, now, reason)

    # ------------------------------------------------------------------
    def allow(self, now: float) -> bool:
        """May the protected rung take traffic at ``now``?"""
        if self.state == BREAKER_OPEN:
            if now >= self.opened_at + self.cfg.cooldown_s:
                self._move(BREAKER_HALF_OPEN, now, "cooldown elapsed")
                return True
            return False
        return True

    def record(self, now: float, ok: bool) -> None:
        """Report one outcome of the protected rung."""
        if self.state == BREAKER_HALF_OPEN:
            if not ok:
                self._move(BREAKER_OPEN, now, "probe failed")
                return
            self._probe_successes += 1
            if self._probe_successes >= self.cfg.half_open_probes:
                self._move(BREAKER_CLOSED, now,
                           f"{self._probe_successes} probe(s) succeeded")
            return
        if self.state == BREAKER_OPEN:
            return  # outcome of a call admitted before opening: stale
        self._events.append((now, ok))
        self._trim(now)
        n = len(self._events)
        fails = sum(1 for _, k in self._events if not k)
        if n >= self.cfg.min_samples and \
                fails / n >= self.cfg.failure_threshold:
            self._move(BREAKER_OPEN, now,
                       f"failure rate {fails}/{n} in window")

    def record_success(self, now: float) -> None:
        self.record(now, True)

    def record_failure(self, now: float) -> None:
        self.record(now, False)


class BreakerBoard:
    """Lazily-created `CircuitBreaker` per key, one shared config.

    Keys are ``(matrix_id, ladder_rung)`` in the serving layer.  Every
    transition of every breaker is appended to ``sink`` (an
    `IncidentLog`) as a `robust.Incident` with kind ``breaker-open`` /
    ``breaker-half-open`` / ``breaker-closed`` — the report layer maps
    them to SPT304.
    """

    def __init__(self, cfg: BreakerConfig | None = None, sink=None):
        self.cfg = cfg or BreakerConfig()
        self.sink = sink
        self._breakers: dict = {}

    def _on_transition(self, brk: CircuitBreaker, old: str, new: str,
                       now: float, reason: str) -> None:
        if self.sink is None:
            return
        mid, stage = (brk.key if isinstance(brk.key, tuple) and
                      len(brk.key) == 2 else ("", str(brk.key)))
        self.sink.append(Incident(
            stage=str(stage), kind=f"breaker-{new}",
            message=f"breaker {brk.key} {old} -> {new}: {reason}",
            detail={"matrix_id": str(mid), "from": old, "to": new,
                    "at": float(now), "reason": reason}))

    def breaker(self, key) -> CircuitBreaker:
        brk = self._breakers.get(key)
        if brk is None:
            brk = CircuitBreaker(key, self.cfg,
                                 on_transition=self._on_transition)
            self._breakers[key] = brk
        return brk

    def allow(self, key, now: float) -> bool:
        return self.breaker(key).allow(now)

    def record(self, key, now: float, ok: bool) -> None:
        self.breaker(key).record(now, ok)

    def state(self, key) -> str:
        brk = self._breakers.get(key)
        return BREAKER_CLOSED if brk is None else brk.state

    def states(self) -> dict[str, str]:
        return {"/".join(map(str, k)) if isinstance(k, tuple) else str(k):
                b.state for k, b in self._breakers.items()}


# ---------------------------------------------------------------------------
# admission / aggregate config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Pending-column budgets for load shedding (``None`` = unbounded).

    Budgets are checked at ``submit`` time *after* due deadline flushes
    ran (so a due bucket frees its budget before the new arrival is
    judged); a request whose columns would exceed either budget is shed
    whole — a typed `serve.ShedTicket`, never a partial enqueue.
    """

    max_pending_per_matrix: int | None = None
    max_pending_total: int | None = None

    def __post_init__(self):
        for name in ("max_pending_per_matrix", "max_pending_total"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"{name} must be >= 1 or None, got {v}")


class ResilienceConfig:
    """Aggregate resilience surface consumed by `serve.SolveService`.

    ``flush_timeout_s`` bounds one backend attempt (measured on the
    service's injectable clock); an attempt exceeding it is classified a
    hang (SPT308), fails the rung's breaker, and degrades — the stage is
    never retried within the flush.  ``sleep`` is the injected backoff
    sleeper (``seconds -> None``); the default ``None`` makes backoff a
    pure accounting event, which is exactly right for virtual-clock
    serving — production may pass ``time.sleep``.  ``incident_cap``
    re-caps the shared `IncidentLog`.
    """

    def __init__(self, retry: RetryPolicy | None = None,
                 breaker: BreakerConfig | None = None,
                 admission: AdmissionConfig | None = None,
                 flush_timeout_s: float | None = None,
                 sleep=None, incident_cap: int = 1024):
        self.retry = retry or RetryPolicy()
        self.breaker = breaker or BreakerConfig()
        self.admission = admission or AdmissionConfig()
        self.flush_timeout_s = flush_timeout_s
        self.sleep = sleep
        if incident_cap < 1:
            raise ValueError(f"incident_cap must be >= 1, got {incident_cap}")
        self.incident_cap = int(incident_cap)


# ---------------------------------------------------------------------------
# incident -> diagnostic (the SPT3xx block)
# ---------------------------------------------------------------------------
# incident kind -> (code, severity).  Kinds not listed render as SPT301
# at warn severity — an unknown failure is still a backend failure.
_KIND_TO_CODE: dict[str, tuple[str, str]] = {
    "exception": ("SPT301", SEV_WARN),
    "build-failed": ("SPT301", SEV_WARN),
    "ladder-exhausted": ("SPT301", SEV_ERROR),
    "nonfinite-output": ("SPT302", SEV_WARN),
    "residual": ("SPT302", SEV_WARN),
    "deadline": ("SPT303", SEV_WARN),
    "deadline-expired": ("SPT303", SEV_WARN),
    "breaker-open": ("SPT304", SEV_WARN),
    "breaker-half-open": ("SPT304", SEV_INFO),
    "breaker-closed": ("SPT304", SEV_INFO),
    "shed": ("SPT305", SEV_WARN),
    "disk-corrupt": ("SPT306", SEV_WARN),
    "backoff": ("SPT307", SEV_INFO),
    "hang": ("SPT308", SEV_WARN),
    "log-saturated": ("SPT309", SEV_WARN),
}


def incident_to_diagnostic(inc: Incident) -> Diagnostic:
    """Render a serving-layer `robust.Incident` as an SPT3xx `Diagnostic`.

    The incident's free-form fields ride along in ``detail`` (stage,
    error class, attempt, elapsed seconds, plus whatever the producer
    attached), so the JSON report loses nothing relative to
    ``Incident.to_dict`` while gaining the stable code + severity the
    analysis tooling keys on.
    """
    code, severity = _KIND_TO_CODE.get(inc.kind, ("SPT301", SEV_WARN))
    detail = {"kind": inc.kind, "stage": inc.stage, "error": inc.error,
              "attempt": inc.attempt, "elapsed_s": inc.elapsed_s,
              **inc.detail}
    return Diagnostic(code=code, severity=severity, message=inc.message,
                      pass_name="serve", detail=detail)
