"""DAG view of a sparse triangular system + the paper's structural statistics.

Nodes = matrix rows, edges = off-diagonal non-zeros (j -> i for L[i, j]).
Since the matrix is lower triangular, row order IS a topological order.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .csr import TriCSR

__all__ = ["DagInfo", "analyze", "out_adjacency"]


def out_adjacency(mat: TriCSR) -> tuple[np.ndarray, np.ndarray]:
    """CSC-style adjacency: for each node j, the consumers i with edge j->i.

    Returns (outptr [n+1], outidx [n_edges]) sorted by consumer id.
    """
    n = mat.n
    srcs = []
    dsts = []
    for i in range(n):
        cols, _ = mat.row(i)
        for j in cols[:-1]:
            srcs.append(j)
            dsts.append(i)
    srcs = np.asarray(srcs, dtype=np.int64)
    dsts = np.asarray(dsts, dtype=np.int64)
    order = np.lexsort((dsts, srcs))
    srcs, dsts = srcs[order], dsts[order]
    outptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(srcs, minlength=n), out=outptr[1:])
    return outptr, dsts


@dataclasses.dataclass(frozen=True)
class DagInfo:
    """Table III statistics for one benchmark DAG."""

    name: str
    n: int
    nnz: int
    binary_nodes: int
    levels: np.ndarray            # level (longest-path depth) per node
    n_levels: int
    level_width: np.ndarray       # nodes per level
    cdu_threshold: int
    cdu_node_ratio: float         # % of nodes that are CDU
    cdu_edge_ratio: float         # % of input edges landing on CDU nodes
    cdu_level_ratio: float        # % of levels that contain CDU nodes
    cdu_edges_per_node: float     # average in-degree of CDU nodes
    max_in_degree: int

    def row(self) -> dict:
        return {
            "name": self.name,
            "n": self.n,
            "nnz": self.nnz,
            "binary_nodes": self.binary_nodes,
            "levels": self.n_levels,
            "cdu_nodes_pct": round(self.cdu_node_ratio * 100, 1),
            "cdu_edges_pct": round(self.cdu_edge_ratio * 100, 1),
            "cdu_levels_pct": round(self.cdu_level_ratio * 100, 1),
            "cdu_edges_per_node": round(self.cdu_edges_per_node, 1),
            "max_in_degree": self.max_in_degree,
        }


def compute_levels(mat: TriCSR) -> np.ndarray:
    """Longest-path level per node (level-scheduling / Fig. 1c)."""
    n = mat.n
    level = np.zeros(n, dtype=np.int64)
    for i in range(n):
        cols, _ = mat.row(i)
        off = cols[:-1]
        if len(off):
            level[i] = int(level[off].max()) + 1
    return level


def analyze(mat: TriCSR, num_cus: int = 64, cdu_fraction: float = 0.2) -> DagInfo:
    """CDU statistics exactly as defined in the paper (§II-C, Table III).

    A CDU node sits in a level whose width is below ``cdu_fraction *
    num_cus`` (the paper sets the threshold at 20% of max parallelism).
    """
    level = compute_levels(mat)
    n_levels = int(level.max()) + 1
    width = np.bincount(level, minlength=n_levels)
    threshold = max(1, int(round(cdu_fraction * num_cus)))
    cdu_level = width < threshold
    is_cdu = cdu_level[level]
    indeg = mat.in_degree()
    total_edges = max(1, int(indeg.sum()))
    cdu_nodes = int(is_cdu.sum())
    cdu_edges = int(indeg[is_cdu].sum())
    return DagInfo(
        name=mat.name,
        n=mat.n,
        nnz=mat.nnz,
        binary_nodes=mat.binary_nodes,
        levels=level,
        n_levels=n_levels,
        level_width=width,
        cdu_threshold=threshold,
        cdu_node_ratio=cdu_nodes / mat.n,
        cdu_edge_ratio=cdu_edges / total_edges,
        cdu_level_ratio=float(cdu_level.sum()) / n_levels,
        cdu_edges_per_node=(cdu_edges / cdu_nodes) if cdu_nodes else 0.0,
        max_in_degree=int(indeg.max()) if mat.n else 0,
    )
