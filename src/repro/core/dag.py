"""DAG view + the paper's structural statistics, for any workload.

Historically this module analyzed sparse triangular systems only (nodes =
matrix rows, edges = off-diagonal non-zeros).  With the staged compiler's
generic frontend boundary (DESIGN.md §6) every function here accepts
either a `TriCSR` *or* a `compiler.ComputeDag` — the workloads of the
upper/transpose/circuit frontends get the same Table III treatment as the
paper's matrices.  Node ids are a topological order in both cases.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .csr import TriCSR

__all__ = ["DagInfo", "analyze", "compute_levels", "edge_view", "out_adjacency"]


def edge_view(g) -> tuple[int, np.ndarray, np.ndarray]:
    """Normalize a workload to ``(n, ptr, src)`` edge arrays.

    Accepts a `TriCSR` (off-diagonal non-zeros are the edges) or anything
    already shaped like a `compiler.ComputeDag` (``n`` / ``ptr`` / ``src``
    attributes, e.g. a `frontends.dagcirc.DagCircuit`).
    """
    if isinstance(g, TriCSR):
        from .frontends.sptrsv import lower_tri  # lazy: avoids import cycle

        d = lower_tri(g)  # single home for the diag-last CSR convention
        return d.n, d.ptr, d.src
    return g.n, g.ptr, g.src


def out_adjacency(g) -> tuple[np.ndarray, np.ndarray]:
    """CSC-style adjacency: for each node j, the consumers i with edge j -> i.

    Returns (outptr [n+1], outidx [n_edges]) sorted by consumer id.
    """
    n, ptr, srcs = edge_view(g)
    dsts = np.repeat(np.arange(n, dtype=np.int64), np.diff(ptr))
    order = np.lexsort((dsts, srcs))
    outptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(srcs, minlength=n), out=outptr[1:])
    return outptr, dsts[order]


@dataclasses.dataclass(frozen=True)
class DagInfo:
    """Table III statistics for one benchmark DAG."""

    name: str
    n: int
    nnz: int
    binary_nodes: int
    levels: np.ndarray            # level (longest-path depth) per node
    n_levels: int
    level_width: np.ndarray       # nodes per level
    cdu_threshold: int
    cdu_node_ratio: float         # % of nodes that are CDU
    cdu_edge_ratio: float         # % of input edges landing on CDU nodes
    cdu_level_ratio: float        # % of levels that contain CDU nodes
    cdu_edges_per_node: float     # average in-degree of CDU nodes
    max_in_degree: int

    def row(self) -> dict:
        return {
            "name": self.name,
            "n": self.n,
            "nnz": self.nnz,
            "binary_nodes": self.binary_nodes,
            "levels": self.n_levels,
            "cdu_nodes_pct": round(self.cdu_node_ratio * 100, 1),
            "cdu_edges_pct": round(self.cdu_edge_ratio * 100, 1),
            "cdu_levels_pct": round(self.cdu_level_ratio * 100, 1),
            "cdu_edges_per_node": round(self.cdu_edges_per_node, 1),
            "max_in_degree": self.max_in_degree,
        }


def _levels(n: int, ptr: np.ndarray, src: np.ndarray) -> np.ndarray:
    level = np.zeros(n, dtype=np.int64)
    for i in range(n):
        off = src[ptr[i] : ptr[i + 1]]
        if len(off):
            level[i] = int(level[off].max()) + 1
    return level


def compute_levels(g) -> np.ndarray:
    """Longest-path level per node (level-scheduling / Fig. 1c)."""
    return _levels(*edge_view(g))


def analyze(g, num_cus: int = 64, cdu_fraction: float = 0.2) -> DagInfo:
    """CDU statistics exactly as defined in the paper (§II-C, Table III).

    A CDU node sits in a level whose width is below ``cdu_fraction *
    num_cus`` (the paper sets the threshold at 20% of max parallelism).
    """
    n, ptr, src = edge_view(g)
    level = _levels(n, ptr, src)
    n_levels = int(level.max()) + 1
    width = np.bincount(level, minlength=n_levels)
    threshold = max(1, int(round(cdu_fraction * num_cus)))
    cdu_level = width < threshold
    is_cdu = cdu_level[level]
    indeg = np.diff(ptr)
    n_edges = int(indeg.sum())
    nnz = n_edges + n  # one final op per node (== matrix nnz for SpTRSV)
    total_edges = max(1, n_edges)
    cdu_nodes = int(is_cdu.sum())
    cdu_edges = int(indeg[is_cdu].sum())
    return DagInfo(
        name=g.name,
        n=n,
        nnz=nnz,
        binary_nodes=2 * nnz - n,
        levels=level,
        n_levels=n_levels,
        level_width=width,
        cdu_threshold=threshold,
        cdu_node_ratio=cdu_nodes / n,
        cdu_edge_ratio=cdu_edges / total_edges,
        cdu_level_ratio=float(cdu_level.sum()) / n_levels,
        cdu_edges_per_node=(cdu_edges / cdu_nodes) if cdu_nodes else 0.0,
        max_in_degree=int(indeg.max()) if n else 0,
    )
