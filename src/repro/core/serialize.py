"""Versioned, checksummed `Program` serialization (DESIGN.md §7).

A compiled program is the expensive artifact of this stack — the serving
roadmap ("compile once, serve millions of requests") needs fleet nodes to
load a precompiled `Program` from disk instead of re-running the compiler.
That only works if a damaged blob can never be executed, so the format is
integrity-first:

    [ magic 8B ][ version u32 ][ header_len u32 ][ header_crc32 u32 ]
    [ header: UTF-8 JSON                                            ]
    [ payload: raw C-order array bytes, concatenated                ]

The JSON header carries the `AccelConfig`, the scalar `ScheduleStats`
fields, and a manifest of every payload array (name, dtype, shape, byte
length, CRC32) plus a whole-payload CRC32 — every byte of the file is
covered by either the header CRC or the payload CRC, so flipping *any*
byte (magic, version, lengths, checksums themselves, header, payload)
surfaces as a `ProgramCorruptionError` at load time, never as a silently
wrong solve.  `load_program` additionally re-validates the decoded
instruction stream structurally (`robust.verify_program`) unless asked
not to.

Not serialized: ``stats.pass_stats`` (per-pass compile telemetry — it
describes the compilation run, not the artifact) — a loaded program
carries ``pass_stats=None``.
"""

from __future__ import annotations

import dataclasses
import io
import json
import struct
import zlib

import numpy as np

from .errors import ProgramCorruptionError
from .program import AccelConfig, Program, ScheduleStats

__all__ = [
    "FORMAT_VERSION",
    "MAGIC",
    "save_program",
    "load_program",
    "dumps_program",
    "loads_program",
]

MAGIC = b"SPTRSVPG"
FORMAT_VERSION = 1

_FIXED = struct.Struct("<8sIII")  # magic, version, header_len, header_crc

# payload arrays in fixed order; (attribute, required)
_ARRAYS = (
    ("instr", True),
    ("val_idx", True),
    ("stream", True),
    ("row_lo", False),
    ("row_hi", False),
    ("stream_src", False),  # value provenance (values-only recompile path)
)
_STATS_ARRAYS = (("per_cu_edges", False),)
# ScheduleStats fields that do NOT round-trip as JSON scalars
# (schedule_costs is a nested dict — auto-select evidence, not a scalar;
# the chosen strategy name itself round-trips via the "schedule" field)
_STATS_SKIP = {"per_cu_edges", "pass_stats", "schedule_costs"}


def _corrupt(msg: str, **detail) -> ProgramCorruptionError:
    return ProgramCorruptionError(f"serialized program corrupt: {msg}",
                                  detail=detail)


def _jsonable(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return v


def dumps_program(prog: Program) -> bytes:
    """Serialize ``prog`` to a self-verifying byte blob (format above)."""
    manifest = []
    payload = io.BytesIO()
    arrays = [(name, getattr(prog, name), req) for name, req in _ARRAYS]
    arrays += [(name, getattr(prog.stats, name), req)
               for name, req in _STATS_ARRAYS]
    for name, arr, required in arrays:
        if arr is None:
            if required:
                raise ValueError(f"program is missing required array {name!r}")
            continue
        raw = np.ascontiguousarray(arr).tobytes()
        manifest.append({
            "name": name,
            "dtype": np.asarray(arr).dtype.str,
            "shape": list(np.asarray(arr).shape),
            "nbytes": len(raw),
            "crc32": zlib.crc32(raw),
        })
        payload.write(raw)
    payload_bytes = payload.getvalue()

    stats = {
        f.name: _jsonable(getattr(prog.stats, f.name))
        for f in dataclasses.fields(ScheduleStats)
        if f.name not in _STATS_SKIP
    }
    header = {
        "format": "sptrsv-program",
        "version": FORMAT_VERSION,
        "n": int(prog.n),
        "num_slots": int(prog.num_slots),
        "config": {f.name: _jsonable(getattr(prog.config, f.name))
                   for f in dataclasses.fields(AccelConfig)},
        "stats": stats,
        "arrays": manifest,
        "payload_crc32": zlib.crc32(payload_bytes),
    }
    header_bytes = json.dumps(header, sort_keys=True).encode("utf-8")
    fixed = _FIXED.pack(MAGIC, FORMAT_VERSION, len(header_bytes),
                        zlib.crc32(header_bytes))
    return fixed + header_bytes + payload_bytes


def loads_program(data: bytes, *, verify: bool = True) -> Program:
    """Parse a blob from `dumps_program`; every defect raises
    `ProgramCorruptionError` (bad magic/version, truncation, trailing
    bytes, any CRC mismatch, malformed header, manifest/shape drift).

    ``verify=True`` (default) additionally runs the structural validator
    (`robust.verify_program`) on the decoded program.
    """
    if len(data) < _FIXED.size:
        raise _corrupt("truncated fixed header",
                       have=len(data), need=_FIXED.size)
    magic, version, header_len, header_crc = _FIXED.unpack_from(data)
    if magic != MAGIC:
        raise _corrupt(f"bad magic {magic!r}", expected=MAGIC.decode())
    if version != FORMAT_VERSION:
        raise _corrupt(f"unsupported format version {version}",
                       supported=FORMAT_VERSION)
    header_end = _FIXED.size + header_len
    if len(data) < header_end:
        raise _corrupt("truncated header", have=len(data), need=header_end)
    header_bytes = data[_FIXED.size:header_end]
    if zlib.crc32(header_bytes) != header_crc:
        raise _corrupt("header CRC mismatch")
    try:
        header = json.loads(header_bytes.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise _corrupt(f"header not valid JSON ({e})") from e

    payload = data[header_end:]
    expected = sum(a["nbytes"] for a in header.get("arrays", ()))
    if len(payload) != expected:
        raise _corrupt("payload length mismatch",
                       have=len(payload), need=expected)
    if zlib.crc32(payload) != header.get("payload_crc32"):
        raise _corrupt("payload CRC mismatch")

    arrays: dict[str, np.ndarray] = {}
    off = 0
    for entry in header["arrays"]:
        raw = payload[off:off + entry["nbytes"]]
        off += entry["nbytes"]
        if zlib.crc32(raw) != entry["crc32"]:
            raise _corrupt(f"array {entry['name']!r} CRC mismatch")
        try:
            arr = np.frombuffer(raw, dtype=np.dtype(entry["dtype"]))
            arrays[entry["name"]] = arr.reshape(entry["shape"]).copy()
        except (TypeError, ValueError) as e:
            raise _corrupt(
                f"array {entry['name']!r} undecodable ({e})") from e

    try:
        config = AccelConfig(**header["config"])
        stats = ScheduleStats(
            **header["stats"],
            per_cu_edges=arrays.pop("per_cu_edges", None),
        )
        prog = Program(
            config=config,
            n=header["n"],
            instr=arrays["instr"],
            val_idx=arrays["val_idx"],
            stream=arrays["stream"],
            stats=stats,
            num_slots=header["num_slots"],
            row_lo=arrays.get("row_lo"),
            row_hi=arrays.get("row_hi"),
            stream_src=arrays.get("stream_src"),
        )
    except (KeyError, TypeError) as e:
        raise _corrupt(f"header schema mismatch ({e})") from e
    if verify:
        from .robust import verify_program  # lazy: robust imports executor

        verify_program(prog)
    return prog


def save_program(prog: Program, path) -> None:
    """Write ``prog`` to ``path`` in the checksummed format above."""
    blob = dumps_program(prog)
    with open(path, "wb") as f:
        f.write(blob)


def load_program(path, *, verify: bool = True) -> Program:
    """Load a program saved by `save_program`; see `loads_program`."""
    with open(path, "rb") as f:
        data = f.read()
    return loads_program(data, verify=verify)
