"""ICR-reorder pass: per-cycle intra-node edge computation reordering.

Implements §IV-C of the paper (Algorithm 2, exact: max-count category,
tie → min initial R-value) plus the two register-file models the chosen
sources are filtered through:

  * the online banked-read model — one distinct address per bank per
    cycle; identical addresses broadcast for free via the crossbar
    (bank assignment is online least-used first-fit, DESIGN.md §5);
  * the x_i register-file spill-reload model (§III-B live-range/spill).

This pass runs *per cycle*, interleaved with the psum-cache schedule
(`sched.py`): which edge each CU executes this cycle feeds back into the
next cycle's node state, so ICR cannot be a whole-program reordering.
The pipeline still reports it as its own stage — `BankSpillState` carries
the cross-cycle state and accumulates the pass metrics (constraints,
conflicts, broadcast reuse) into the shared `ScheduleStats`.
"""

from __future__ import annotations

import heapq
from collections import Counter

import numpy as np

from ..program import AccelConfig, ScheduleStats

__all__ = ["BankSpillState", "icr_assign", "assign_sources"]


def icr_assign(edge_cus, cands):
    """Algorithm 2 of the paper, exact, via a lazy max-heap.

    Returns {cu: src}.  Categories = distinct source nodes; repeatedly pick
    the category with the most remaining edges (tie -> smallest initial
    R-value, then smallest id), assign it to every CU that has it, remove
    those CUs, and recount.
    """
    cnt: Counter = Counter()
    cu_of_src: dict[int, list[int]] = {}
    for c in edge_cus:
        for s in cands[c]:
            cnt[s] += 1
            cu_of_src.setdefault(s, []).append(c)
    r_value = dict(cnt)
    heap = [(-v, r_value[s], s) for s, v in cnt.items()]
    heapq.heapify(heap)
    assigned: dict[int, int] = {}
    unassigned = set(edge_cus)
    while unassigned and heap:
        negv, _, s = heapq.heappop(heap)
        if cnt.get(s, 0) != -negv:
            continue  # stale entry
        for c in cu_of_src[s]:
            if c in unassigned:
                assigned[c] = s
                unassigned.discard(c)
                for s2 in cands[c]:
                    v = cnt.get(s2, 0)
                    if v > 0:
                        cnt[s2] = v - 1
                        if v > 1:
                            heapq.heappush(heap, (-(v - 1), r_value[s2], s2))
                        else:
                            del cnt[s2]
    return assigned


class BankSpillState:
    """Cross-cycle state of the ICR pass: bank map + per-pass counters."""

    __slots__ = ("bank_of", "bank_load", "bank_free_order")

    def __init__(self, cfg: AccelConfig):
        self.bank_of: dict[int, int] = {}
        self.bank_load = np.zeros(cfg.num_banks, dtype=np.int64)
        self.bank_free_order = list(range(cfg.num_banks))

    def metrics(self, stats: ScheduleStats, cfg: AccelConfig) -> dict:
        return {
            "icr": cfg.icr,
            "icr_window": cfg.icr_window,
            "distinct_reads": stats.distinct_reads,
            "reuse_events": stats.reuse_events,
            "constraints": stats.constraints,
            "conflicts": stats.conflicts,
            "banks_used": len(set(self.bank_of.values())),
            "spill_reload_stalls": stats.snop,
        }


def assign_sources(state: BankSpillState, cfg: AccelConfig,
                   stats: ScheduleStats, chosen, nop_kind, cus) -> dict:
    """One cycle of ICR + bank/spill filtering.

    ``chosen[c]`` is the psum-schedule pass's pick for CU ``c`` (or None);
    edge picks get a source assigned here.  CUs losing their pick to a
    bank conflict or a spill reload are demoted to NOPs in place (their
    ``chosen`` entry cleared, ``nop_kind`` set) — the replay happens next
    cycle.  Returns {cu: src} for the surviving edge lanes.
    """
    p = len(chosen)
    edge_cus = [c for c in range(p) if chosen[c] and chosen[c][0] == "edge"]
    assigned_src: dict[int, int] = {}
    if not edge_cus:
        return assigned_src
    w = cfg.icr_window
    cands = {c: chosen[c][1].ready[:w] for c in edge_cus}
    if cfg.icr:
        assigned_src = icr_assign(edge_cus, cands)
    else:
        for c in edge_cus:  # traditional ascending-source-id pick
            assigned_src[c] = min(chosen[c][1].ready)

    group = Counter(assigned_src.values())
    stats.distinct_reads += len(group)
    stats.reuse_events += sum(v - 1 for v in group.values())
    k = len(group)
    stats.constraints += k * (k - 1) // 2

    # banked-read model: one distinct address per bank per cycle;
    # identical addresses broadcast for free via the crossbar.
    used_banks: dict[int, int] = {}
    for s in sorted(group, key=lambda s_: (-group[s_], s_)):
        if s not in state.bank_of:
            free = [b for b in state.bank_free_order if b not in used_banks]
            pool = free if free else state.bank_free_order
            b = min(pool, key=lambda b_: (state.bank_load[b_], b_))
            state.bank_of[s] = b
            state.bank_load[b] += 1
        b = state.bank_of[s]
        if b in used_banks and used_banks[b] != s:
            for c in [c_ for c_, ss in assigned_src.items() if ss == s]:
                del assigned_src[c]
                chosen[c] = None
                nop_kind[c] = "b"
                stats.conflicts += 1
        else:
            used_banks[b] = s

    # x_i register-file spill-reload model
    for c in list(assigned_src):
        s = assigned_src[c]
        cu = cus[c]
        if s in cu.spilled:
            cu.spilled.discard(s)
            if len(cu.resident) >= cfg.xi_words:
                evict = min(cu.resident, key=cu.resident.get)
                cu.spilled.add(evict)
                del cu.resident[evict]
            cu.resident[s] = 1
            del assigned_src[c]
            chosen[c] = None
            nop_kind[c] = "s"
    return assigned_src
