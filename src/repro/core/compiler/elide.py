"""Stall-elide pass: dense `ScheduleIR` → emitted `EmitIR`.

Cycles in which no lane executes (bank-conflict replay, global psum
stalls) count as hardware time (``stats.cycles``) but carry no
information — an all-NOP row changes no state, so streaming it would be
pure instruction HBM traffic.  This pass drops them from the emitted
stream (``stats.emitted_cycles`` = rows kept) and computes each emitted
row's touched-solution-row envelope ``[row_lo, row_hi]`` (EDGE lanes read
x[src]; FINAL lanes read b[src] and write x[src]) — the metadata the
row-blocked Pallas placement plans its sliding VMEM window from
(DESIGN.md §1).
"""

from __future__ import annotations

import numpy as np

from .ir import EmitIR, ScheduleIR

__all__ = ["run"]


def run(sir: ScheduleIR) -> EmitIR:
    active = sir.ops != 0                       # [C, P]
    keep = active.any(axis=1)                   # a lane executed this cycle
    ops = sir.ops[keep]
    src = sir.src[keep]
    act = active[keep]
    n = sir.n
    row_lo = np.where(act, src, n).min(axis=1).astype(np.int32)
    row_hi = np.where(act, src, -1).max(axis=1).astype(np.int32)

    stats = sir.stats
    stats.emitted_cycles = int(keep.sum())
    metrics = {
        "hardware_cycles": int(keep.size),
        "emitted_cycles": stats.emitted_cycles,
        "stall_rows_elided": int(keep.size) - stats.emitted_cycles,
    }
    return EmitIR(
        name=sir.name, n=n,
        ops=ops, val_idx=sir.val_idx[keep], src=src,
        ctl=sir.ctl[keep], slot=sir.slot[keep],
        row_lo=row_lo, row_hi=row_hi,
        stream=sir.stream, num_slots=sir.num_slots,
        stats=stats, metrics=metrics,
        stream_src=sir.stream_src,
    )
