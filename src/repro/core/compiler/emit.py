"""Pack/emit pass: `EmitIR` → packed `Program`.

Packs the per-field instruction planes into the canonical single-word
int32 encoding (``src | op | ctl | slot`` — `program.pack_instructions`,
with the automatic two-plane fallback for n > 2^SRC_BITS) and assembles
the final `Program`.  Every downstream consumer — the numpy / `lax.scan`
executors, both Pallas placements, batching, sharding — sees only this
format, which is what lets every frontend workload run on them unchanged.
"""

from __future__ import annotations

import numpy as np

from ..program import AccelConfig, Program, pack_instructions, packed_planes
from .ir import EmitIR

__all__ = ["run"]


def run(eir: EmitIR, cfg: AccelConfig, planes: int | None = None) -> Program:
    instr = pack_instructions(
        eir.ops, eir.src, eir.ctl, eir.slot,
        planes=planes if planes is not None else packed_planes(eir.n),
    )
    return Program(
        num_slots=eir.num_slots,
        config=cfg,
        n=eir.n,
        instr=instr,
        val_idx=eir.val_idx,
        stream=np.array(eir.stream, dtype=np.float32),
        stats=eir.stats,
        row_lo=eir.row_lo,
        row_hi=eir.row_hi,
        stream_src=eir.stream_src,
    )
