"""Psum-cache schedule pass: `AssignIR` → dense `ScheduleIR` cycle trace.

This is the cycle-accurate heart of the compiler: it simulates the
synchronized VLIW machine cycle by cycle, applying the medium-granularity
dataflow (§IV-A, node = allocation unit / edge = scheduling unit) and the
partial-sum caching mechanism (§IV-B) with the deadlock-avoiding capacity
rules of Fig. 7.  Each cycle's edge picks are filtered through the ICR
reorder + bank/spill models (`icr.py`) — a per-cycle sub-stage, since its
outcome feeds the next cycle's node state.

The produced trace is *dense*: one row per hardware cycle, all-NOP stall
rows included — eliding them is the next pass's job (`elide.py`), and the
schedule length is the hardware cycle count (the paper's compiler "can
fully predict the behavior of the hardware", §III-B).

Deviations from the paper (DESIGN.md §5): online least-used-first-fit bank
assignment; windowed ICR; emergency psum overflow parks on detected global
stalls (counted as ``dm_escapes``).
"""

from __future__ import annotations

import time

import numpy as np

from ..program import (
    MAX_SLOT,
    OP_EDGE,
    OP_FINAL,
    PS_KEEP,
    PS_LOAD,
    PS_RESET,
    PS_STORE_RESET,
    PS_SWAP,
    SLOT_BITS,
    AccelConfig,
    ScheduleStats,
)
from . import icr
from .ir import AssignIR, ScheduleIR

__all__ = ["run", "PSUM_OVERFLOW_SLOTS", "MAX_PSUM_SLOT"]

PSUM_OVERFLOW_SLOTS = 4  # emergency data-memory-modelled psum spill slots

# Overflow slots grow on demand but every slot id must fit the packed
# instruction word's slot field (core/program.py: SLOT_BITS wide).
MAX_PSUM_SLOT = MAX_SLOT


class _Node:
    __slots__ = (
        "nid", "owner", "srcs", "val_of", "gidx_of", "ready", "pending",
        "remaining", "started", "solved", "slot",
    )

    def __init__(self, nid: int, owner: int, srcs, weights, edge0: int = 0):
        self.nid = nid
        self.owner = owner
        self.srcs = srcs
        self.val_of = dict(zip(srcs.tolist(), weights.tolist()))
        # source node id -> global edge index into ComputeDag.weight: the
        # value-provenance map the stream_src plane (values-only
        # recompilation, `compiler.recompile_values`) is built from
        self.gidx_of = {s: edge0 + k for k, s in enumerate(srcs.tolist())}
        self.ready: list[int] = []
        self.pending = len(srcs)
        self.remaining = len(srcs)
        self.started = False
        self.solved = False
        self.slot = -1

    def has_work(self) -> bool:
        return bool(self.ready) or (self.remaining == 0 and not self.solved)


class _CU:
    __slots__ = (
        "cid", "name", "tasks", "pos_of", "head", "started_mask", "current",
        "cached", "free_slots", "free_over", "next_over", "resident",
        "spilled", "done_count", "edge_count",
    )

    def __init__(self, cid: int, name: str, tasks: list[int], psum_words: int):
        self.cid = cid
        self.name = name
        self.tasks = tasks
        self.pos_of = {nd: k for k, nd in enumerate(tasks)}
        self.head = 0
        self.started_mask = np.zeros(len(tasks), dtype=bool)
        self.current: _Node | None = None
        self.cached: list[_Node] = []
        self.free_slots = list(range(psum_words))
        self.free_over = list(range(psum_words, psum_words + PSUM_OVERFLOW_SLOTS))
        self.next_over = psum_words + PSUM_OVERFLOW_SLOTS  # grows on demand
        self.resident: dict[int, int] = {}
        self.spilled: set[int] = set()
        self.done_count = 0
        self.edge_count = 0

    def peek_over_slot(self) -> int:
        """Next overflow slot (modelled data-memory psum spill).

        Grows on demand up to the capacity of the packed instruction word's
        ``slot`` field (`program.SLOT_BITS` ⇒ slot ids 0..`MAX_PSUM_SLOT`,
        overflow included).
        """
        if self.free_over:
            return self.free_over[0]
        if self.next_over > MAX_PSUM_SLOT:
            raise RuntimeError(
                f"psum overflow slots exhausted compiling {self.name!r} on "
                f"CU {self.cid}: slot id {self.next_over} does not fit the "
                f"{SLOT_BITS}-bit packed slot field (max {MAX_PSUM_SLOT}); "
                f"raise AccelConfig.psum_words or split heavy nodes "
                f"(core.transform.split_heavy_nodes)")
        return self.next_over

    def advance_head(self) -> None:
        while self.head < len(self.tasks) and self.started_mask[self.head]:
            self.head += 1

    def release_slot(self, slot: int, psum_words: int) -> None:
        if slot < psum_words:
            self.free_slots.append(slot)
        else:
            self.free_over.append(slot)

    def all_done(self) -> bool:
        return self.done_count == len(self.tasks)


def run(air: AssignIR, cfg: AccelConfig) -> ScheduleIR:
    """Simulate the machine over the assigned DAG; return the dense trace."""
    if cfg.dataflow not in ("medium", "coarse"):
        raise ValueError(f"unknown dataflow {cfg.dataflow!r}")
    dag = air.part.dag
    n, p = dag.n, cfg.num_cus
    scale = dag.scale
    task_lists = air.task_lists
    owner = air.owner
    consumers = air.part.consumers

    nodes: list[_Node] = []
    for i in range(n):
        srcs, weights = dag.node(i)
        nodes.append(_Node(i, int(owner[i]), srcs, weights,
                           edge0=int(dag.ptr[i])))

    cus = [_CU(c, dag.name, task_lists[c], cfg.psum_words) for c in range(p)]
    startable: list[dict[int, int]] = [dict() for _ in range(p)]  # pos -> nid
    for nd in nodes:
        if nd.pending == 0:
            c = nd.owner
            startable[c][cus[c].pos_of[nd.nid]] = nd.nid

    ops_t, val_t, src_t, pct_t, psl_t = [], [], [], [], []
    stream: list[float] = []
    # value provenance, parallel to `stream`: entry >= 0 is a global edge
    # index into dag.weight, entry < 0 encodes node id -(i+1) whose scale
    # was streamed (the values-only recompile path reads this plane)
    stream_src: list[int] = []
    stats = ScheduleStats(name=dag.name, n=n, nnz=dag.nnz, cycles=0,
                          exec_edges=0, exec_finals=0)

    bank_state = icr.BankSpillState(cfg)
    icr_seconds = 0.0

    solved_total = 0
    cycle = 0
    stall_streak = 0
    max_cycles = 8 * dag.nnz + 64 * n + 4096

    while solved_total < n:
        if cycle > max_cycles:
            raise RuntimeError(f"scheduler did not converge on {dag.name}")
        op_row = np.zeros(p, dtype=np.uint8)
        val_row = np.zeros(p, dtype=np.int32)
        src_row = np.zeros(p, dtype=np.int32)
        pct_row = np.zeros(p, dtype=np.uint8)
        psl_row = np.zeros(p, dtype=np.uint8)

        # ---------------------------------------------- phase 1: node choice
        chosen: list[tuple[str, _Node, int, int] | None] = [None] * p
        nop_kind: list[str | None] = [None] * p

        for cu in cus:
            c = cu.cid
            if cu.all_done():
                nop_kind[c] = "l"
                continue
            cur = cu.current
            cur_live = cur is not None and not cur.solved

            if cfg.dataflow == "coarse":
                cu.advance_head()
                if cur_live and cur.has_work():
                    kind = "edge" if cur.ready else "final"
                    chosen[c] = (kind, cur, PS_KEEP, 0)
                elif not cur_live and cu.head < len(cu.tasks):
                    nd = nodes[cu.tasks[cu.head]]
                    if nd.pending == 0:
                        kind = "edge" if nd.ready else "final"
                        chosen[c] = (kind, nd, PS_RESET, 0)
                    else:
                        nop_kind[c] = "d"
                else:
                    nop_kind[c] = "d"
                continue

            picked: tuple[str, _Node] | None = None
            for nd in cu.cached:  # cached nodes have absolute priority
                if nd.has_work():
                    picked = ("resume", nd)
                    break
            if picked is None and cur_live and cur.has_work():
                picked = ("continue", cur)
            if picked is None and startable[c] and (cfg.psum_cache or not cur_live):
                pos = min(startable[c])
                picked = ("start", nodes[startable[c][pos]])
            if picked is None:
                # deadlock escape (also required with psum_cache=False: a
                # blocked current node can circularly wait on unstarted
                # nodes — see module docstring)
                if stall_streak >= 2 and cur_live and startable[c]:
                    pos = min(startable[c])
                    nd = nodes[startable[c][pos]]
                    stats.dm_escapes += 1
                    kind = "edge" if nd.ready else "final"
                    chosen[c] = (kind, nd, PS_STORE_RESET, cu.peek_over_slot())
                    continue
                nop_kind[c] = "d"
                continue

            mode, nd = picked
            if mode == "resume":
                if cur_live:
                    ctrl, slot = PS_SWAP, nd.slot  # read-before-write swap
                else:
                    ctrl, slot = PS_LOAD, nd.slot
            elif mode == "continue":
                ctrl, slot = PS_KEEP, 0
            else:  # start
                if cur_live:
                    cu.advance_head()
                    first_new = (cu.head < len(cu.tasks)
                                 and cu.tasks[cu.head] == nd.nid)
                    need = 1 if first_new else 2
                    if len(cu.free_slots) < need:
                        if stall_streak >= 2:
                            # emergency psum overflow park (DESIGN.md §5)
                            ctrl, slot = PS_STORE_RESET, cu.peek_over_slot()
                            stats.dm_escapes += 1
                            kind = "edge" if nd.ready else "final"
                            chosen[c] = (kind, nd, ctrl, slot)
                            continue
                        nop_kind[c] = "p"
                        continue
                    ctrl, slot = PS_STORE_RESET, cu.free_slots[0]
                else:
                    ctrl, slot = PS_RESET, 0
            kind = "edge" if nd.ready else "final"
            chosen[c] = (kind, nd, ctrl, slot)

        # ------------------------------- phase 2: ICR reorder + bank/spill
        t_icr = time.perf_counter()
        assigned_src = icr.assign_sources(bank_state, cfg, stats, chosen,
                                          nop_kind, cus)
        icr_seconds += time.perf_counter() - t_icr

        # ---------------------------------------------- phase 3: execute
        newly_solved: list[_Node] = []
        executed = 0
        for c in range(p):
            if chosen[c] is None:
                k = nop_kind[c]
                if k == "b":
                    stats.bnop += 1
                elif k == "p":
                    stats.pnop += 1
                elif k == "s":
                    stats.snop += 1
                elif k == "l":
                    stats.lnop += 1
                else:
                    stats.dnop += 1
                continue
            executed += 1
            kind, nd, ctrl, slot = chosen[c]
            cu = cus[c]
            cur = cu.current

            if ctrl == PS_SWAP:
                cur.slot = nd.slot
                cu.cached[cu.cached.index(nd)] = cur
                nd.slot = -1
            elif ctrl == PS_LOAD:
                cu.release_slot(nd.slot, cfg.psum_words)
                cu.cached.remove(nd)
                nd.slot = -1
            elif ctrl == PS_STORE_RESET:
                if slot < cfg.psum_words:
                    cu.free_slots.remove(slot)
                elif slot in cu.free_over:
                    cu.free_over.remove(slot)
                else:
                    assert slot == cu.next_over
                    cu.next_over += 1
                cur.slot = slot
                cu.cached.append(cur)

            if not nd.started:
                nd.started = True
                pos = cu.pos_of[nd.nid]
                cu.started_mask[pos] = True
                startable[c].pop(pos, None)
                cu.advance_head()
            cu.current = nd

            pct_row[c] = ctrl
            psl_row[c] = slot

            if kind == "edge":
                s = assigned_src[c]
                nd.ready.remove(s)
                nd.remaining -= 1
                cu.edge_count += 1
                if s in cu.resident:
                    cu.resident[s] -= 1
                    if cu.resident[s] <= 0:
                        del cu.resident[s]  # release after last use (R_vs)
                op_row[c] = OP_EDGE
                val_row[c] = len(stream)
                stream.append(float(nd.val_of[s]))
                stream_src.append(nd.gidx_of[s])
                src_row[c] = s
                stats.exec_edges += 1
            else:
                op_row[c] = OP_FINAL
                val_row[c] = len(stream)
                stream.append(float(scale[nd.nid]))
                stream_src.append(-(nd.nid + 1))
                src_row[c] = nd.nid  # FINAL writes x[src]: out_idx is derived
                nd.solved = True
                cu.done_count += 1
                newly_solved.append(nd)
                stats.exec_finals += 1

        stall_streak = 0 if executed else stall_streak + 1

        # deliver newly solved values — consumable from the NEXT cycle
        for nd in newly_solved:
            solved_total += 1
            j = nd.nid
            per_cu_uses: dict[int, int] = {}
            for i in consumers[j]:
                cons = nodes[i]
                cons.ready.append(j)
                cons.pending -= 1
                cu_i = cons.owner
                per_cu_uses[cu_i] = per_cu_uses.get(cu_i, 0) + 1
                if not cons.started:
                    startable[cu_i][cus[cu_i].pos_of[i]] = i
            for cu_i, uses in per_cu_uses.items():
                cu = cus[cu_i]
                if len(cu.resident) < cfg.xi_words:
                    cu.resident[j] = cu.resident.get(j, 0) + uses
                else:
                    cu.spilled.add(j)
                    stats.spilled_values += 1

        # dense trace: stall rows (executed == 0) are kept here — the
        # stall-elide pass drops them from the emitted stream
        ops_t.append(op_row)
        val_t.append(val_row)
        src_t.append(src_row)
        pct_t.append(pct_row)
        psl_t.append(psl_row)
        cycle += 1

    stats.cycles = cycle
    stats.per_cu_edges = np.array([cu.edge_count for cu in cus])
    num_slots = max(cu.next_over for cu in cus)

    metrics = {
        "dataflow": cfg.dataflow,
        "hardware_cycles": cycle,
        "exec_edges": stats.exec_edges,
        "exec_finals": stats.exec_finals,
        "dm_escapes": stats.dm_escapes,
        "psum_slots_used": num_slots,
        "spilled_values": stats.spilled_values,
    }
    icr_metrics = dict(bank_state.metrics(stats, cfg),
                       seconds=round(icr_seconds, 6))
    return ScheduleIR(
        name=dag.name, n=n,
        ops=np.stack(ops_t), val_idx=np.stack(val_t), src=np.stack(src_t),
        ctl=np.stack(pct_t), slot=np.stack(psl_t),
        stream=np.array(stream, dtype=np.float64),
        num_slots=num_slots, stats=stats, metrics=metrics,
        icr_metrics=icr_metrics,
        stream_src=np.array(stream_src, dtype=np.int64),
    )
