"""Intermediate representations of the staged compiler pipeline (DESIGN.md §6).

The compiler is a sequence of passes, each consuming and producing an
explicit IR dataclass::

    frontend            ComputeDag      (generic SpTRSV-like compute DAG)
      └─ partition   →  PartitionIR     (medium-granularity node/edge view)
         └─ cu-assign→  AssignIR        (+ node→CU ownership)
            └─ psum-cache schedule + ICR reorder
                      →  ScheduleIR     (dense cycle trace, incl. stall rows)
               └─ stall-elide
                      →  EmitIR         (all-NOP rows dropped, row envelopes)
                  └─ pack/emit
                      →  Program        (packed VLIW words, core/program.py)

`ComputeDag` is the frontend contract: *any* workload whose nodes compute

    x[i] = (b[i] - sum_k weight[k] * x[src[k]]) * scale[i]

over a DAG in topological order lowers to it — lower-triangular SpTRSV
(`frontends/sptrsv.py`, weight = L_ij / scale = 1/L_ii), upper-triangular
and transpose solves via index reversal (`frontends/upper.py`), and
general DPU-v2-style weighted-accumulate circuits (`frontends/dagcirc.py`).
The emitted `Program` format is unchanged, so every executor (numpy,
`lax.scan`, both Pallas placements), batching, sharding and the packed
encoding run all of these workloads verbatim.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..program import ScheduleStats

__all__ = [
    "ComputeDag",
    "PartitionIR",
    "AssignIR",
    "ScheduleIR",
    "EmitIR",
    "PassStats",
]


@dataclasses.dataclass(frozen=True)
class ComputeDag:
    """Generic SpTRSV-like compute DAG — the compiler's frontend IR.

    Node ``i`` (ids ``0..n-1``, a topological order) computes

        x[i] = (b[i] - sum_k weight[k] * x[src[k]]) * scale[i]

    where ``k`` ranges over ``ptr[i]:ptr[i+1]``.  Sources must be strictly
    smaller node ids (topological order), ascending and duplicate-free
    within a node — exactly the off-diagonal layout of the paper's CSR
    convention, minus the triangular-matrix interpretation.
    """

    name: str
    n: int
    ptr: np.ndarray     # int64 [n+1] — per-node edge slices
    src: np.ndarray     # int64 [E]   — source node ids (ascending per node)
    weight: np.ndarray  # float64 [E] — coefficient on x[src] in the psum
    scale: np.ndarray   # float64 [n] — multiplier applied to (b[i] - psum)

    # ------------------------------------------------------------------
    @property
    def n_edges(self) -> int:
        return int(self.ptr[-1])

    @property
    def nnz(self) -> int:
        """Edge count + one final op per node (== matrix nnz for SpTRSV)."""
        return self.n_edges + self.n

    @property
    def binary_nodes(self) -> int:
        """Flop count: one FMA per edge + one mul-sub per final."""
        return 2 * self.nnz - self.n

    def node(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = int(self.ptr[i]), int(self.ptr[i + 1])
        return self.src[lo:hi], self.weight[lo:hi]

    def in_degree(self) -> np.ndarray:
        return np.diff(self.ptr)

    # ------------------------------------------------------------------
    def validate(self) -> None:
        """Enforce the frontend contract (raises ValueError)."""
        if self.ptr.shape != (self.n + 1,) or self.ptr[0] != 0:
            raise ValueError(f"{self.name}: ptr must be [n+1] starting at 0")
        if np.any(np.diff(self.ptr) < 0):
            raise ValueError(f"{self.name}: ptr must be non-decreasing")
        e = self.n_edges
        if self.src.shape != (e,) or self.weight.shape != (e,):
            raise ValueError(f"{self.name}: src/weight must have ptr[-1] entries")
        if self.scale.shape != (self.n,):
            raise ValueError(f"{self.name}: scale must be [n]")
        if not np.all(np.isfinite(self.scale)) or np.any(self.scale == 0.0):
            raise ValueError(f"{self.name}: scale must be finite and non-zero")
        if e:
            if not np.all(np.isfinite(self.weight)):
                raise ValueError(f"{self.name}: non-finite edge weight")
            owner_row = np.repeat(np.arange(self.n), np.diff(self.ptr))
            if int(self.src.min()) < 0 or np.any(self.src >= owner_row):
                raise ValueError(
                    f"{self.name}: every edge source must be a strictly "
                    f"smaller node id (topological order)")
            inner = np.ones(e, dtype=bool)
            bnd = self.ptr[1:-1]
            inner[bnd[bnd < e]] = False  # node boundaries
            if np.any((np.diff(self.src) <= 0)[inner[1:]]):
                raise ValueError(
                    f"{self.name}: sources must be ascending and "
                    f"duplicate-free within a node")


@dataclasses.dataclass(frozen=True)
class PartitionIR:
    """Output of the partition pass: the medium-granularity node/edge view.

    Nodes are the minimal *allocation* units, edges the minimal
    *scheduling* units (§IV-A); the consumer adjacency is what the
    scheduler uses to wake nodes as their inputs finalize.
    """

    dag: ComputeDag
    consumers: list            # list[list[int]] — consumers[j] ascending
    in_degree: np.ndarray      # int64 [n]
    metrics: dict


@dataclasses.dataclass(frozen=True)
class AssignIR:
    """Output of the cu-assign pass: node → CU ownership."""

    part: PartitionIR
    owner: np.ndarray          # int64 [n] — owning CU per node
    task_lists: list           # list[list[int]] — per-CU nodes, topo order
    metrics: dict


@dataclasses.dataclass(frozen=True)
class ScheduleIR:
    """Output of the psum-cache schedule (+ per-cycle ICR reorder) passes.

    A *dense* cycle trace: one row per hardware cycle, including all-NOP
    stall rows (bank-conflict replay / global psum stalls) — those are the
    stall-elide pass's input.  ``stats`` is the shared `ScheduleStats`
    accumulator (cycles / nop breakdown / ICR counters already filled;
    ``emitted_cycles`` is set later by stall-elide).
    """

    name: str
    n: int
    ops: np.ndarray            # uint8 [C, P]
    val_idx: np.ndarray        # int32 [C, P] — index into `stream`
    src: np.ndarray            # int32 [C, P]
    ctl: np.ndarray            # uint8 [C, P]
    slot: np.ndarray           # uint8 [C, P]
    stream: np.ndarray         # float64 [S] — values in schedule order
    num_slots: int
    stats: ScheduleStats
    metrics: dict              # psum-schedule pass metrics
    icr_metrics: dict          # ICR-reorder pass metrics
    # value provenance, parallel to `stream`: entry >= 0 is a global edge
    # index into the frontend ComputeDag's weight array, entry < 0 encodes
    # node id -(i+1) whose scale was streamed — the map the values-only
    # recompile path (`compiler.recompile_values`) regathers from
    stream_src: np.ndarray | None = None  # int64 [S]


@dataclasses.dataclass(frozen=True)
class EmitIR:
    """Output of the stall-elide pass: the rows actually streamed.

    All-NOP rows are dropped (they change no state — streaming them would
    be pure HBM traffic); ``row_lo/row_hi`` are the per-emitted-row
    touched-solution-row envelopes the row-blocked Pallas placement plans
    its VMEM window from (DESIGN.md §1).
    """

    name: str
    n: int
    ops: np.ndarray            # uint8 [T, P]
    val_idx: np.ndarray        # int32 [T, P]
    src: np.ndarray            # int32 [T, P]
    ctl: np.ndarray            # uint8 [T, P]
    slot: np.ndarray           # uint8 [T, P]
    row_lo: np.ndarray         # int32 [T]
    row_hi: np.ndarray         # int32 [T]
    stream: np.ndarray         # float64 [S]
    num_slots: int
    stats: ScheduleStats
    metrics: dict
    stream_src: np.ndarray | None = None  # int64 [S] (see ScheduleIR)


@dataclasses.dataclass(frozen=True)
class PassStats:
    """Per-pass observability record (attached as ``stats.pass_stats``)."""

    name: str
    seconds: float
    metrics: dict
