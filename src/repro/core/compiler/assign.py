"""CU-assign pass: `PartitionIR` → `AssignIR` (node → CU allocation).

Nodes are handed to CUs in topological order (== node-id order): the
``least_edges`` policy gives each next node to the CU with the least
accumulated work (edges + finalize), the ``roundrobin`` policy stripes
ids.  This is the paper's coarse-node allocation step, generalized from
matrix rows to generic DAG nodes.
"""

from __future__ import annotations

import heapq

import numpy as np

from ..program import AccelConfig
from .ir import AssignIR, PartitionIR

__all__ = ["allocate", "run"]


def allocate(n: int, in_degree: np.ndarray, cfg: AccelConfig) -> list[list[int]]:
    """Allocate nodes ``0..n-1`` to ``cfg.num_cus`` CUs; returns task lists."""
    p = cfg.num_cus
    tasks: list[list[int]] = [[] for _ in range(p)]
    if cfg.alloc == "roundrobin":
        for i in range(n):
            tasks[i % p].append(i)
        return tasks
    if cfg.alloc != "least_edges":
        raise ValueError(f"unknown alloc policy {cfg.alloc!r}")
    heap = [(0, c) for c in range(p)]  # (load, cu) — least accumulated work
    heapq.heapify(heap)
    for i in range(n):
        w, c = heapq.heappop(heap)
        tasks[c].append(i)
        heapq.heappush(heap, (w + int(in_degree[i]) + 1, c))
    return tasks


def run(part: PartitionIR, cfg: AccelConfig) -> AssignIR:
    n = part.dag.n
    task_lists = allocate(n, part.in_degree, cfg)
    owner = np.empty(n, dtype=np.int64)
    for c, ts in enumerate(task_lists):
        for nid in ts:
            owner[nid] = c
    # planned per-CU load (edges + finalizes) — the allocation objective
    load = np.array([int(part.in_degree[ts].sum()) + len(ts)
                     for ts in task_lists], dtype=np.float64)
    cv = float(100.0 * load.std() / max(load.mean(), 1e-12))
    metrics = {"alloc": cfg.alloc, "num_cus": cfg.num_cus,
               "planned_load_cv_pct": round(cv, 2)}
    return AssignIR(part=part, owner=owner, task_lists=task_lists,
                    metrics=metrics)
