"""Level-set schedule pass: `AssignIR` → dense `ScheduleIR` (DESIGN.md §11).

The sync-free / level-set line of SpTRSV work (Li et al., arXiv
1710.04985) schedules each dependency *level* of the DAG as one parallel
wavefront.  This pass transplants that idea onto the synchronized VLIW
machine: a node becomes runnable only once **all** of its inputs have
been delivered (not merely one, as the paper's psum-cache scheduler
allows), and each CU drains its runnable set in ascending level order,
packing every level greedily across the CUs that own its nodes.

Because a node starts with its inputs complete, it runs to completion —
edges then FINAL — without ever parking a partial sum: every node uses
``PS_RESET`` on its first op and ``PS_KEEP`` after, the slot plane stays
zero, and there are no psum spills by construction.  The price is lost
overlap: a CU idles (``dnop``) whenever none of its nodes is fully
delivered yet, which is exactly where the paper's medium-granularity
dataflow wins on deep, narrow DAGs.  On wide shallow DAGs the two are
close to tied and this pass's zero spill traffic can win the frontier.

Per-cycle edge picks still run through the ICR reorder + bank/spill
models (`icr.assign_sources`) so bank conflicts and x_i reload stalls
are accounted identically to the paper scheduler.
"""

from __future__ import annotations

import heapq
import time

from ...program import OP_EDGE, OP_FINAL, PS_KEEP, PS_RESET, AccelConfig, ScheduleStats
from .. import icr
from ..ir import AssignIR, ScheduleIR
from . import base

__all__ = ["run", "NAME"]

NAME = "level"


class _CU:
    """Per-CU state: a level-ordered runnable heap + the x_i file model."""

    __slots__ = ("cid", "heap", "current", "resident", "spilled",
                 "done_count", "edge_count", "total")

    def __init__(self, cid: int, total: int):
        self.cid = cid
        self.heap: list[tuple[int, int, int]] = []  # (level, pos, nid)
        self.current: base.Node | None = None
        self.resident: dict[int, int] = {}
        self.spilled: set[int] = set()
        self.done_count = 0
        self.edge_count = 0
        self.total = total


def run(air: AssignIR, cfg: AccelConfig) -> ScheduleIR:
    """Schedule the assigned DAG level by level; return the dense trace."""
    dag = air.part.dag
    n, p = dag.n, cfg.num_cus
    scale = dag.scale
    consumers = air.part.consumers

    nodes = base.make_nodes(air)
    depth = base.node_depths(dag)
    pos_of = [{nid: k for k, nid in enumerate(air.task_lists[c])}
              for c in range(p)]
    cus = [_CU(c, len(air.task_lists[c])) for c in range(p)]

    def enqueue(nd: base.Node) -> None:
        heapq.heappush(cus[nd.owner].heap,
                       (int(depth[nd.nid]), pos_of[nd.owner][nd.nid], nd.nid))

    for nd in nodes:          # sources are runnable immediately
        if nd.pending == 0:
            enqueue(nd)

    trace = base.Trace(p)
    stats = ScheduleStats(name=dag.name, n=n, nnz=dag.nnz, cycles=0,
                          exec_edges=0, exec_finals=0)
    bank_state = icr.BankSpillState(cfg)
    icr_seconds = 0.0

    solved_total = 0
    cycle = 0
    max_cycles = base.max_schedule_cycles(dag)

    while solved_total < n:
        if cycle > max_cycles:
            raise RuntimeError(
                f"level-set scheduler did not converge on {dag.name}")
        op_row, val_row, src_row, ctl_row, slot_row = trace.new_row()

        # phase 1: each CU continues its node, else peeks its level heap.
        # The pick is only *committed* (heap pop / current switch) when the
        # op actually lands — a bank/spill demotion replays next cycle.
        chosen: list[tuple[str, base.Node, int, int] | None] = [None] * p
        nop_kind: list[str | None] = [None] * p
        for cu in cus:
            c = cu.cid
            if cu.done_count == cu.total:
                nop_kind[c] = "l"
                continue
            cur = cu.current
            if cur is not None and not cur.solved:
                nd = cur
            elif cu.heap:
                nd = nodes[cu.heap[0][2]]
            else:
                nop_kind[c] = "d"  # nothing delivered-complete yet
                continue
            kind = "edge" if nd.ready else "final"
            ctl = PS_RESET if nd.issued == 0 else PS_KEEP
            chosen[c] = (kind, nd, ctl, 0)

        # phase 2: ICR reorder + bank/spill filtering (shared with paper)
        t_icr = time.perf_counter()
        assigned_src = icr.assign_sources(bank_state, cfg, stats, chosen,
                                          nop_kind, cus)
        icr_seconds += time.perf_counter() - t_icr

        # phase 3: execute surviving lanes
        newly_solved: list[base.Node] = []
        for c in range(p):
            if chosen[c] is None:
                k = nop_kind[c]
                if k == "b":
                    stats.bnop += 1
                elif k == "s":
                    stats.snop += 1
                elif k == "l":
                    stats.lnop += 1
                else:
                    stats.dnop += 1
                continue
            kind, nd, ctl, slot = chosen[c]
            cu = cus[c]
            if cu.current is not nd:
                heapq.heappop(cu.heap)
                cu.current = nd
            nd.issued += 1
            ctl_row[c] = ctl
            slot_row[c] = slot

            if kind == "edge":
                s = assigned_src[c]
                nd.ready.remove(s)
                nd.remaining -= 1
                cu.edge_count += 1
                if s in cu.resident:
                    cu.resident[s] -= 1
                    if cu.resident[s] <= 0:
                        del cu.resident[s]  # release after last use
                op_row[c] = OP_EDGE
                val_row[c] = len(trace.stream)
                trace.stream.append(float(nd.val_of[s]))
                trace.stream_src.append(nd.gidx_of[s])
                src_row[c] = s
                stats.exec_edges += 1
            else:
                op_row[c] = OP_FINAL
                val_row[c] = len(trace.stream)
                trace.stream.append(float(scale[nd.nid]))
                trace.stream_src.append(-(nd.nid + 1))
                src_row[c] = nd.nid
                nd.solved = True
                cu.done_count += 1
                newly_solved.append(nd)
                stats.exec_finals += 1

        solved_total += base.deliver(newly_solved, nodes, consumers, cus,
                                     cfg, stats, on_runnable=enqueue)
        trace.push(op_row, val_row, src_row, ctl_row, slot_row)
        cycle += 1

    levels = int(depth.max()) + 1 if n else 0
    return base.build_schedule_ir(
        NAME, air, cfg, trace, stats, cus, bank_state, icr_seconds,
        num_slots=1, extra_metrics={"dataflow": cfg.dataflow,
                                    "levels": levels})
