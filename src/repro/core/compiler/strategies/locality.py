"""Locality-first list schedule passes: `AssignIR` → dense `ScheduleIR`.

A family of list schedulers in the block-bounded style of polyphony's
compiler (SNIPPETS.md Snippets 1–2) and the partition-based parallel
scheduling of Böhnlein et al. (arXiv 2503.05408), re-targeted at the
paper's VLIW machine.  They keep the paper scheduler's full partial-sum
caching mechanics — SWAP / LOAD / STORE_RESET slot transitions, the
Fig. 7 capacity rules, the emergency overflow park — and change only the
*pick order*, a lookahead priority function instead of the paper's fixed
"resume first cached > continue > start next in program order".  Three
points on the frontier are registered (`strategies.STRATEGIES`):

  * ``"locality"`` — **continue** the current node while it has work
    (the psum feedback path is free: staying put costs no ctl traffic
    and no slot pressure), then resume the parked node with the greatest
    critical-path height, then start in program order.  Wins on
    psum-capacity-bound circuit DAGs, where the paper's resume-first
    order swaps partial sums in and out of slots it is short on.
  * ``"cpath"``   — resume the deepest-critical-path parked node *before*
    continuing, then start in program order.  Pure critical-path list
    scheduling; wins where finishing parked nodes early unblocks the
    longest chains.
  * ``"eager"``   — like ``"locality"`` but starts the node with the
    most immediately issuable edges instead of program order.  A
    consume-early heuristic: draining delivered values before new ones
    arrive keeps the x_i register file from thrashing, which wins on
    spill-bound hub DAGs (the ``hub_wall`` stressor) at the price of
    delaying program-order finals everywhere else.

No single pick order dominates — that is the point of the strategy
frontier; `schedule="auto"` arbitrates per matrix by predicted cycles.

Per-cycle edge picks run through the same ICR reorder + bank/spill
models (`icr.assign_sources`) as every other strategy.
"""

from __future__ import annotations

import time

from ...program import (
    OP_EDGE,
    OP_FINAL,
    PS_KEEP,
    PS_LOAD,
    PS_RESET,
    PS_STORE_RESET,
    PS_SWAP,
    AccelConfig,
    ScheduleStats,
)
from .. import icr
from ..ir import AssignIR, ScheduleIR
from ..sched import _CU, _Node
from . import base

__all__ = ["run", "run_cpath", "run_eager", "NAME", "CPATH", "EAGER"]

NAME = "locality"
CPATH = "cpath"
EAGER = "eager"


def run(air: AssignIR, cfg: AccelConfig) -> ScheduleIR:
    """Psum-reuse-first list schedule (``"locality"``; module docstring)."""
    return _run(air, cfg, name=NAME, continue_first=True, start_key="pos")


def run_cpath(air: AssignIR, cfg: AccelConfig) -> ScheduleIR:
    """Critical-path-first list schedule (``"cpath"``; module docstring)."""
    return _run(air, cfg, name=CPATH, continue_first=False, start_key="pos")


def run_eager(air: AssignIR, cfg: AccelConfig) -> ScheduleIR:
    """Consume-early list schedule (``"eager"``; module docstring)."""
    return _run(air, cfg, name=EAGER, continue_first=True, start_key="ready")


def _run(air: AssignIR, cfg: AccelConfig, *, name: str, continue_first: bool,
         start_key: str) -> ScheduleIR:
    """The shared list-scheduler machine behind the three presets."""
    if cfg.dataflow != "medium":
        raise ValueError(
            f"schedule={name!r} requires dataflow='medium', "
            f"got {cfg.dataflow!r} (use schedule='paper')")
    dag = air.part.dag
    n, p = dag.n, cfg.num_cus
    scale = dag.scale
    owner = air.owner
    consumers = air.part.consumers
    height = base.node_heights(consumers, n)

    nodes = [_Node(i, int(owner[i]), *dag.node(i), edge0=int(dag.ptr[i]))
             for i in range(n)]
    cus = [_CU(c, dag.name, air.task_lists[c], cfg.psum_words)
           for c in range(p)]
    startable: list[dict[int, int]] = [dict() for _ in range(p)]  # pos -> nid
    for nd in nodes:
        if nd.pending == 0:
            c = nd.owner
            startable[c][cus[c].pos_of[nd.nid]] = nd.nid

    if start_key == "ready":
        def best_start(c: int) -> _Node:
            # consume-early lookahead: most issuable edges, program order
            # breaking ties (sources have no edges, so -pos decides them)
            pos = max(startable[c],
                      key=lambda p_: (len(nodes[startable[c][p_]].ready), -p_))
            return nodes[startable[c][pos]]
    else:
        def best_start(c: int) -> _Node:
            return nodes[startable[c][min(startable[c])]]  # program order

    trace = base.Trace(p)
    stats = ScheduleStats(name=dag.name, n=n, nnz=dag.nnz, cycles=0,
                          exec_edges=0, exec_finals=0)
    bank_state = icr.BankSpillState(cfg)
    icr_seconds = 0.0

    solved_total = 0
    cycle = 0
    stall_streak = 0
    max_cycles = base.max_schedule_cycles(dag)

    while solved_total < n:
        if cycle > max_cycles:
            raise RuntimeError(
                f"{name} scheduler did not converge on {dag.name}")
        op_row, val_row, src_row, ctl_row, slot_row = trace.new_row()

        # ---------------------------------------------- phase 1: node choice
        chosen: list[tuple[str, _Node, int, int] | None] = [None] * p
        nop_kind: list[str | None] = [None] * p

        for cu in cus:
            c = cu.cid
            if cu.all_done():
                nop_kind[c] = "l"
                continue
            cur = cu.current
            cur_live = cur is not None and not cur.solved

            picked: tuple[str, _Node] | None = None
            if continue_first and cur_live and cur.has_work():
                picked = ("continue", cur)       # psum feedback stays hot
            if picked is None:
                resumable = [nd for nd in cu.cached if nd.has_work()]
                if resumable:                    # deepest critical path first
                    picked = ("resume",
                              max(resumable, key=lambda nd: height[nd.nid]))
            if picked is None and cur_live and cur.has_work():
                picked = ("continue", cur)
            if picked is None and startable[c] and (cfg.psum_cache
                                                    or not cur_live):
                picked = ("start", best_start(c))
            if picked is None:
                # deadlock escape, identical to the paper scheduler's
                if stall_streak >= 2 and cur_live and startable[c]:
                    nd = best_start(c)
                    stats.dm_escapes += 1
                    kind = "edge" if nd.ready else "final"
                    chosen[c] = (kind, nd, PS_STORE_RESET, cu.peek_over_slot())
                    continue
                nop_kind[c] = "d"
                continue

            mode, nd = picked
            if mode == "resume":
                if cur_live:
                    ctrl, slot = PS_SWAP, nd.slot  # read-before-write swap
                else:
                    ctrl, slot = PS_LOAD, nd.slot
            elif mode == "continue":
                ctrl, slot = PS_KEEP, 0
            else:  # start
                if cur_live:
                    cu.advance_head()
                    first_new = (cu.head < len(cu.tasks)
                                 and cu.tasks[cu.head] == nd.nid)
                    need = 1 if first_new else 2  # Fig. 7 capacity rule
                    if len(cu.free_slots) < need:
                        if stall_streak >= 2:
                            ctrl, slot = PS_STORE_RESET, cu.peek_over_slot()
                            stats.dm_escapes += 1
                            kind = "edge" if nd.ready else "final"
                            chosen[c] = (kind, nd, ctrl, slot)
                            continue
                        nop_kind[c] = "p"
                        continue
                    ctrl, slot = PS_STORE_RESET, cu.free_slots[0]
                else:
                    ctrl, slot = PS_RESET, 0
            kind = "edge" if nd.ready else "final"
            chosen[c] = (kind, nd, ctrl, slot)

        # ------------------------------- phase 2: ICR reorder + bank/spill
        t_icr = time.perf_counter()
        assigned_src = icr.assign_sources(bank_state, cfg, stats, chosen,
                                          nop_kind, cus)
        icr_seconds += time.perf_counter() - t_icr

        # ---------------------------------------------- phase 3: execute
        newly_solved: list[_Node] = []
        executed = 0
        for c in range(p):
            if chosen[c] is None:
                k = nop_kind[c]
                if k == "b":
                    stats.bnop += 1
                elif k == "p":
                    stats.pnop += 1
                elif k == "s":
                    stats.snop += 1
                elif k == "l":
                    stats.lnop += 1
                else:
                    stats.dnop += 1
                continue
            executed += 1
            kind, nd, ctrl, slot = chosen[c]
            cu = cus[c]
            cur = cu.current

            if ctrl == PS_SWAP:
                cur.slot = nd.slot
                cu.cached[cu.cached.index(nd)] = cur
                nd.slot = -1
            elif ctrl == PS_LOAD:
                cu.release_slot(nd.slot, cfg.psum_words)
                cu.cached.remove(nd)
                nd.slot = -1
            elif ctrl == PS_STORE_RESET:
                if slot < cfg.psum_words:
                    cu.free_slots.remove(slot)
                elif slot in cu.free_over:
                    cu.free_over.remove(slot)
                else:
                    assert slot == cu.next_over
                    cu.next_over += 1
                cur.slot = slot
                cu.cached.append(cur)

            if not nd.started:
                nd.started = True
                pos = cu.pos_of[nd.nid]
                cu.started_mask[pos] = True
                startable[c].pop(pos, None)
                cu.advance_head()
            cu.current = nd

            ctl_row[c] = ctrl
            slot_row[c] = slot

            if kind == "edge":
                s = assigned_src[c]
                nd.ready.remove(s)
                nd.remaining -= 1
                cu.edge_count += 1
                if s in cu.resident:
                    cu.resident[s] -= 1
                    if cu.resident[s] <= 0:
                        del cu.resident[s]  # release after last use (R_vs)
                op_row[c] = OP_EDGE
                val_row[c] = len(trace.stream)
                trace.stream.append(float(nd.val_of[s]))
                trace.stream_src.append(nd.gidx_of[s])
                src_row[c] = s
                stats.exec_edges += 1
            else:
                op_row[c] = OP_FINAL
                val_row[c] = len(trace.stream)
                trace.stream.append(float(scale[nd.nid]))
                trace.stream_src.append(-(nd.nid + 1))
                src_row[c] = nd.nid  # FINAL writes x[src]
                nd.solved = True
                cu.done_count += 1
                newly_solved.append(nd)
                stats.exec_finals += 1

        stall_streak = 0 if executed else stall_streak + 1

        # deliver newly solved values — consumable from the NEXT cycle
        for nd in newly_solved:
            solved_total += 1
            j = nd.nid
            per_cu_uses: dict[int, int] = {}
            for i in consumers[j]:
                cons = nodes[i]
                cons.ready.append(j)
                cons.pending -= 1
                cu_i = cons.owner
                per_cu_uses[cu_i] = per_cu_uses.get(cu_i, 0) + 1
                if not cons.started:
                    startable[cu_i][cus[cu_i].pos_of[i]] = i
            for cu_i, uses in per_cu_uses.items():
                cu = cus[cu_i]
                if len(cu.resident) < cfg.xi_words:
                    cu.resident[j] = cu.resident.get(j, 0) + uses
                else:
                    cu.spilled.add(j)
                    stats.spilled_values += 1

        trace.push(op_row, val_row, src_row, ctl_row, slot_row)
        cycle += 1

    num_slots = max(cu.next_over for cu in cus)
    return base.build_schedule_ir(
        name, air, cfg, trace, stats, cus, bank_state, icr_seconds,
        num_slots=num_slots, extra_metrics={"dataflow": cfg.dataflow})
