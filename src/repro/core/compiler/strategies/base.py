"""Shared machinery of the pluggable scheduler strategies (DESIGN.md §11).

Every strategy pass consumes the pipeline's `AssignIR` and produces the
standard dense `ScheduleIR` cycle trace, so the downstream passes (ICR is
already folded in per cycle, stall-elide, pack/emit), the IR contract
verifiers and all three executors run a strategy's schedule unchanged.
The pieces every strategy shares live here:

  * `Node` — per-DAG-node scheduling state (delivered inputs, remaining
    edges, value/provenance maps for the stream planes);
  * `deliver` — the end-of-cycle wavefront: consumers of newly solved
    rows wake *next* cycle (which is what makes every strategy's trace
    RAW-clean by construction) and the x_i register-file resident/spill
    model updates exactly as the paper scheduler's (`compiler.sched`);
  * `node_depths` / `node_heights` — longest-path levels from the
    sources (level-set packing order) and to the sinks (critical-path
    priority);
  * `build_schedule_ir` — assembles the trace planes, the shared
    `ScheduleStats`, and the ICR metrics into a `ScheduleIR`.

Strategies must respect the invariants `analysis.contracts.verify_schedule`
pins: every node executes wholly on its assigned CU, each edge exactly
once, FINAL strictly after all inputs finalized, one stream value appended
per executed lane, and the `stream_src` provenance plane filled so
values-only recompilation (`compiler.recompile_values`) keeps working.
"""

from __future__ import annotations

import numpy as np

from ...program import AccelConfig, ScheduleStats
from .. import icr
from ..ir import AssignIR, ScheduleIR

__all__ = [
    "Node",
    "Trace",
    "node_depths",
    "node_heights",
    "deliver",
    "build_schedule_ir",
    "max_schedule_cycles",
]


class Node:
    """Scheduling state of one DAG node (mirrors `compiler.sched._Node`)."""

    __slots__ = ("nid", "owner", "srcs", "val_of", "gidx_of", "ready",
                 "pending", "remaining", "issued", "solved")

    def __init__(self, nid: int, owner: int, srcs, weights, edge0: int = 0):
        self.nid = nid
        self.owner = owner
        self.srcs = srcs
        self.val_of = dict(zip(srcs.tolist(), weights.tolist()))
        # source node id -> global edge index into ComputeDag.weight (the
        # value-provenance map the stream_src plane is built from)
        self.gidx_of = {s: edge0 + k for k, s in enumerate(srcs.tolist())}
        self.ready: list[int] = []
        self.pending = len(srcs)
        self.remaining = len(srcs)
        self.issued = 0          # ops executed so far (0 -> next op RESETs)
        self.solved = False


def make_nodes(air: AssignIR) -> list[Node]:
    dag = air.part.dag
    owner = air.owner
    return [Node(i, int(owner[i]), *dag.node(i), edge0=int(dag.ptr[i]))
            for i in range(dag.n)]


def node_depths(dag) -> np.ndarray:
    """Longest-path level from the sources (level-set membership)."""
    depth = np.zeros(dag.n, dtype=np.int64)
    ptr, src = dag.ptr, dag.src
    for i in range(dag.n):
        lo, hi = int(ptr[i]), int(ptr[i + 1])
        if hi > lo:
            depth[i] = int(depth[src[lo:hi]].max()) + 1
    return depth


def node_heights(consumers, n: int) -> np.ndarray:
    """Longest-path distance to a sink (critical-path priority)."""
    height = np.zeros(n, dtype=np.int64)
    for j in range(n - 1, -1, -1):
        cons = consumers[j]
        if cons:
            height[j] = int(height[cons].max() if isinstance(cons, np.ndarray)
                            else max(height[i] for i in cons)) + 1
    return height


def deliver(newly_solved, nodes, consumers, cus, cfg: AccelConfig,
            stats: ScheduleStats, on_runnable=None) -> int:
    """End-of-cycle delivery of newly finalized rows (next-cycle visible).

    Updates consumer ready/pending state and the per-CU x_i register-file
    resident/spill model exactly as the paper scheduler does (the spill
    set feeds `icr.assign_sources`' reload stalls).  ``on_runnable(node)``
    fires when a consumer's last input arrives (strategies enqueue it);
    returns the number of rows delivered.
    """
    for nd in newly_solved:
        j = nd.nid
        per_cu_uses: dict[int, int] = {}
        for i in consumers[j]:
            cons = nodes[i]
            cons.ready.append(j)
            cons.pending -= 1
            per_cu_uses[cons.owner] = per_cu_uses.get(cons.owner, 0) + 1
            if cons.pending == 0 and on_runnable is not None:
                on_runnable(cons)
        for cu_i, uses in per_cu_uses.items():
            cu = cus[cu_i]
            if len(cu.resident) < cfg.xi_words:
                cu.resident[j] = cu.resident.get(j, 0) + uses
            else:
                cu.spilled.add(j)
                stats.spilled_values += 1
    return len(newly_solved)


def max_schedule_cycles(dag) -> int:
    """Divergence guard shared with the paper scheduler."""
    return 8 * dag.nnz + 64 * dag.n + 4096


class Trace:
    """Accumulates the dense per-cycle instruction planes + value stream."""

    def __init__(self, p: int):
        self.p = p
        self.ops: list[np.ndarray] = []
        self.val: list[np.ndarray] = []
        self.src: list[np.ndarray] = []
        self.ctl: list[np.ndarray] = []
        self.slot: list[np.ndarray] = []
        self.stream: list[float] = []
        self.stream_src: list[int] = []

    def new_row(self):
        return (np.zeros(self.p, dtype=np.uint8),
                np.zeros(self.p, dtype=np.int32),
                np.zeros(self.p, dtype=np.int32),
                np.zeros(self.p, dtype=np.uint8),
                np.zeros(self.p, dtype=np.uint8))

    def push(self, op_row, val_row, src_row, ctl_row, slot_row) -> None:
        self.ops.append(op_row)
        self.val.append(val_row)
        self.src.append(src_row)
        self.ctl.append(ctl_row)
        self.slot.append(slot_row)


def build_schedule_ir(strategy: str, air: AssignIR, cfg: AccelConfig,
                      trace: Trace, stats: ScheduleStats, cus,
                      bank_state: icr.BankSpillState, icr_seconds: float,
                      num_slots: int, extra_metrics: dict | None = None,
                      ) -> ScheduleIR:
    """Assemble the standard dense `ScheduleIR` from a strategy's trace."""
    dag = air.part.dag
    stats.cycles = len(trace.ops)
    stats.per_cu_edges = np.array([cu.edge_count for cu in cus])
    stats.schedule = strategy
    metrics = {
        "strategy": strategy,
        "hardware_cycles": stats.cycles,
        "exec_edges": stats.exec_edges,
        "exec_finals": stats.exec_finals,
        "dm_escapes": stats.dm_escapes,
        "psum_slots_used": num_slots,
        "spilled_values": stats.spilled_values,
        **(extra_metrics or {}),
    }
    icr_metrics = dict(bank_state.metrics(stats, cfg),
                       seconds=round(icr_seconds, 6))
    return ScheduleIR(
        name=dag.name, n=dag.n,
        ops=np.stack(trace.ops), val_idx=np.stack(trace.val),
        src=np.stack(trace.src), ctl=np.stack(trace.ctl),
        slot=np.stack(trace.slot),
        stream=np.array(trace.stream, dtype=np.float64),
        num_slots=num_slots, stats=stats, metrics=metrics,
        icr_metrics=icr_metrics,
        stream_src=np.array(trace.stream_src, dtype=np.int64),
    )
