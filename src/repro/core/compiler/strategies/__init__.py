"""Pluggable scheduler strategies + per-matrix auto-select (DESIGN.md §11).

The staged pipeline made the schedule pass swappable: any function
``run(air: AssignIR, cfg: AccelConfig) -> ScheduleIR`` that honours the
`analysis.contracts.verify_schedule` contract slots in between cu-assign
and stall-elide, and every downstream pass and executor runs its output
unchanged.  This package holds the strategy registry:

  * ``"paper"``    — the paper's psum-cache scheduler (`compiler.sched`),
                     the default and the baseline;
  * ``"level"``    — level-set wavefront packing (`level.py`);
  * ``"locality"`` — psum-reuse-first list scheduling (`locality.py`);
  * ``"cpath"``    — critical-path-first list scheduling (`locality.py`);
  * ``"eager"``    — consume-early list scheduling for spill-bound hub
                     DAGs (`locality.py`);
  * ``"auto"``     — compile every applicable candidate, score each dense
                     trace with the analytic cost model (`cost.py`), keep
                     the cheapest.  Ties keep registry order, so ``auto``
                     is never predicted-worse than ``paper``.

`select` implements the auto mode; `frontier_costs` exposes the whole
frontier for one workload (the SPT208 perf lint and the frontier
benchmark are built on it).
"""

from __future__ import annotations

import time

from ...program import AccelConfig
from .. import sched
from ..ir import AssignIR, ScheduleIR
from . import level, locality
from .cost import CostEstimate, predict_cycles

__all__ = [
    "STRATEGIES",
    "AUTO",
    "get",
    "candidate_names",
    "select",
    "frontier_costs",
    "CostEstimate",
    "predict_cycles",
]

AUTO = "auto"

# Registry order is the tie-break order: "paper" first means the baseline
# wins every tie, which is what makes auto never predicted-worse than it.
STRATEGIES: dict[str, object] = {
    "paper": sched.run,
    level.NAME: level.run,
    locality.NAME: locality.run,
    locality.CPATH: locality.run_cpath,
    locality.EAGER: locality.run_eager,
}


def get(name: str):
    """Resolve a strategy name to its schedule pass; raise on unknown."""
    try:
        return STRATEGIES[name]
    except KeyError:
        options = ", ".join([*STRATEGIES, AUTO])
        raise ValueError(
            f"unknown schedule strategy {name!r}; options: {options}"
        ) from None


def candidate_names(cfg: AccelConfig) -> list[str]:
    """Strategies applicable under ``cfg`` (auto's candidate set).

    The alternative strategies model the medium-granularity machine; the
    coarse dataflow keeps its single paper schedule.
    """
    if cfg.dataflow != "medium":
        return ["paper"]
    return list(STRATEGIES)


def select(air: AssignIR, cfg: AccelConfig):
    """Auto-select: run every candidate, keep the predicted-cheapest.

    Returns ``(sir, chosen, costs, seconds)`` — the winning dense trace,
    its strategy name, ``{name: cost-dict}`` over all candidates, and
    ``{name: schedule-pass seconds}`` (the winner's entry is what the
    pipeline reports as the ``psum_schedule`` pass time; the rest is
    selection overhead).
    """
    sirs: dict[str, ScheduleIR] = {}
    ests: dict[str, CostEstimate] = {}
    seconds: dict[str, float] = {}
    for name in candidate_names(cfg):
        t = time.perf_counter()
        sirs[name] = get(name)(air, cfg)
        seconds[name] = time.perf_counter() - t
        ests[name] = predict_cycles(sirs[name], cfg)
    chosen = min(ests, key=lambda k: ests[k].sort_key())
    costs = {name: est.to_dict() for name, est in ests.items()}
    return sirs[chosen], chosen, costs, seconds


def frontier_costs(dag, cfg: AccelConfig | None = None) -> dict[str, dict]:
    """Predicted cost of every applicable strategy for one workload.

    Runs the pipeline front half (partition → cu-assign) once, then each
    candidate schedule pass; returns ``{name: cost-dict}`` as stored in
    ``stats.schedule_costs`` by auto compiles.  This is what lets
    `scripts/lint_program.py --frontier` flag an explicitly chosen
    strategy that leaves cycles on the table (SPT208).
    """
    from .. import assign, partition

    cfg = cfg or AccelConfig()
    air = assign.run(partition.run(dag), cfg)
    return {name: predict_cycles(get(name)(air, cfg), cfg).to_dict()
            for name in candidate_names(cfg)}
