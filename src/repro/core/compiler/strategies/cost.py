"""Analytic cost model over dense schedule traces (DESIGN.md §11).

The auto-select mode compares candidate schedules *before* emission, so
the model reads only what a dense `ScheduleIR` already states exactly:

  * ``cycles`` — the trace length IS the hardware cycle count (the
    compiler "fully predicts the behavior of the hardware", paper
    §III-B), so the prediction equals the emitted program's
    ``stats.cycles`` by construction;
  * ``stall_rows`` — all-NOP rows: hardware time that emits nothing;
  * ``psum_spills`` — STORE_RESET parks landing beyond the psum register
    file (the overflow region is modelled data memory: each park
    round-trips a partial sum through spill traffic);
  * ``planes`` — the packed-word layout the program will emit with; the
    two-plane large-n fallback doubles instruction HBM bytes per lane.

`CostEstimate.sort_key` is the auto-select ordering: predicted cycles
weighted by instruction bytes per lane-cycle (``4 * planes + 4``, see
`Program.instr_bytes_per_lane_cycle`), then spills, then stall rows.
All candidates of one matrix share ``n`` (hence ``planes``), so the
primary term reduces to plain predicted cycles — the weight only matters
when comparing across packings.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ...program import PS_STORE_RESET, AccelConfig, packed_planes
from ..ir import ScheduleIR

__all__ = ["CostEstimate", "predict_cycles"]


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Predicted cost of one candidate schedule (see module docstring)."""

    strategy: str
    cycles: int        # == emitted stats.cycles, exactly
    stall_rows: int    # all-NOP rows inside those cycles
    psum_spills: int   # STORE_RESET parks into the overflow region
    planes: int        # packed-word layout the emission will choose

    def sort_key(self) -> tuple:
        """Auto-select ordering: lower is better, ties keep registry order."""
        return (self.cycles * (4 * self.planes + 4),
                self.psum_spills, self.stall_rows)

    def to_dict(self) -> dict:
        return {"cycles": self.cycles, "stall_rows": self.stall_rows,
                "psum_spills": self.psum_spills, "planes": self.planes}


def predict_cycles(sir: ScheduleIR,
                   cfg: AccelConfig | None = None) -> CostEstimate:
    """Predict the emitted program's cost from a dense schedule trace.

    The prediction is exact for ``cycles`` (the dense trace row count is
    the hardware cycle count the emitted ``stats.cycles`` reports) —
    pinned by `tests/test_strategies.py` — and exact for the spill/stall
    structure the trace already encodes.
    """
    cfg = cfg or AccelConfig()
    active = np.asarray(sir.ops) != 0
    spills = (active & (np.asarray(sir.ctl) == PS_STORE_RESET)
              & (np.asarray(sir.slot) >= cfg.psum_words))
    return CostEstimate(
        strategy=str(getattr(sir.stats, "schedule", "paper")),
        cycles=int(sir.ops.shape[0]),
        stall_rows=int((~active.any(axis=1)).sum()),
        psum_spills=int(spills.sum()),
        planes=packed_planes(sir.n),
    )
