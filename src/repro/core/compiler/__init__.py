"""Staged compiler pipeline for SpTRSV-like compute DAGs (DESIGN.md §6).

Replaces the historical monolithic ``schedule.compile_program`` with an
explicit pass pipeline over documented IR dataclasses (`ir.py`)::

    ComputeDag → partition → cu-assign → psum-cache schedule (+ per-cycle
    ICR reorder) → stall-elide → pack/emit → Program

`compile_dag` is the generic entry point: it accepts any workload lowered
to the `ComputeDag` frontend contract (`core/frontends/`) and emits the
unchanged `Program` format every executor, the batching/sharding paths and
the packed encoding already consume.  ``schedule.compile_program`` is now
a thin TriCSR wrapper over this pipeline.

Per-pass wall-clock and metrics are recorded on
``program.stats.pass_stats`` (a list of `PassStats`) for observability;
``compile_seconds`` stays the end-to-end total.
"""

from __future__ import annotations

import time

from ..program import AccelConfig, Program
from . import assign, elide, emit, partition, sched
from .ir import (  # noqa: F401  (re-exported IR surface)
    AssignIR,
    ComputeDag,
    EmitIR,
    PartitionIR,
    PassStats,
    ScheduleIR,
)
from .sched import MAX_PSUM_SLOT, PSUM_OVERFLOW_SLOTS  # noqa: F401

__all__ = [
    "compile_dag",
    "recompile_values",
    "ComputeDag",
    "PartitionIR",
    "AssignIR",
    "ScheduleIR",
    "EmitIR",
    "PassStats",
    "PASS_NAMES",
    "PSUM_OVERFLOW_SLOTS",
    "MAX_PSUM_SLOT",
]

PASS_NAMES = ("partition", "cu_assign", "psum_schedule", "icr_reorder",
              "stall_elide", "pack_emit")


def compile_dag(dag: ComputeDag, cfg: AccelConfig | None = None, *,
                planes: int | None = None,
                schedule: str = "paper",
                verify_ir: bool = False) -> Program:
    """Compile a `ComputeDag` workload into a packed VLIW `Program`.

    ``planes`` forces the packed-word layout (1 = single-word, 2 = the
    large-n fallback); ``None`` auto-selects via `program.packed_planes`.
    The pipeline stages run in order; each records a `PassStats` entry on
    ``program.stats.pass_stats``.

    ``schedule`` picks the schedule pass (DESIGN.md §11): ``"paper"`` (the
    default psum-cache scheduler), an alternative strategy by name
    (``"level"``, ``"locality"``), or ``"auto"`` — compile every candidate
    and keep the one the analytic cost model predicts cheapest.  The
    decision lands in ``stats.schedule`` (and, for auto, the per-candidate
    predictions in ``stats.schedule_costs``); auto's selection overhead is
    a synthetic ``"strategy_select"`` entry on ``pass_stats``.

    ``verify_ir=True`` runs the per-pass contract verifiers
    (`core/analysis/contracts.py`) on every intermediate IR and raises
    `errors.IRValidationError` naming the guilty pass on the first broken
    invariant; the verifier wall-clock is appended to ``pass_stats`` as a
    synthetic ``"verify_ir"`` entry so the overhead stays observable.
    """
    cfg = cfg or AccelConfig()
    t0 = time.perf_counter()

    if verify_ir:
        from ..analysis import contracts

        t_verify = 0.0
        verified = 0

        def _check(diags_fn, stage):
            nonlocal t_verify, verified
            t = time.perf_counter()
            diags = diags_fn()
            contracts.raise_on_errors(diags, stage, dag.name)
            t_verify += time.perf_counter() - t
            verified += 1
    else:
        def _check(diags_fn, stage):
            pass

    def _timed(fn, *args, **kw):
        t = time.perf_counter()
        out = fn(*args, **kw)
        return out, time.perf_counter() - t

    _check(lambda: contracts.verify_frontend(dag), "frontend")
    pir, t_part = _timed(partition.run, dag)
    _check(lambda: contracts.verify_partition(pir), "partition")
    air, t_assign = _timed(assign.run, pir, cfg)
    _check(lambda: contracts.verify_assign(air, cfg), "cu_assign")
    select_stats = None
    if schedule == "auto":
        from . import strategies

        t = time.perf_counter()
        sir, chosen, costs, run_seconds = strategies.select(air, cfg)
        t_select = time.perf_counter() - t
        t_sched = run_seconds[chosen]
        sir.stats.schedule_costs = costs
        select_stats = PassStats("strategy_select", t_select - t_sched, {
            "chosen": chosen,
            "candidates": list(costs),
            "predicted_cycles": {k: v["cycles"] for k, v in costs.items()},
        })
    elif schedule == "paper":
        sir, t_sched = _timed(sched.run, air, cfg)
    else:
        from . import strategies

        sir, t_sched = _timed(strategies.get(schedule), air, cfg)
    _check(lambda: contracts.verify_schedule(sir, air, cfg), "psum_schedule")
    eir, t_elide = _timed(elide.run, sir)
    _check(lambda: contracts.verify_emit(eir, sir), "stall_elide")
    prog, t_emit = _timed(emit.run, eir, cfg, planes=planes)
    _check(lambda: contracts.verify_packed_program(prog, eir, cfg),
           "pack_emit")

    # the ICR reorder runs per cycle inside the schedule pass (its outcome
    # feeds the next cycle's node state); it accumulates its own time and
    # metrics in the trace, reported here as its own stage
    t_icr = sir.icr_metrics.get("seconds", 0.0)
    icr_metrics = {k: v for k, v in sir.icr_metrics.items() if k != "seconds"}
    prog.stats.pass_stats = [
        PassStats("partition", t_part, pir.metrics),
        PassStats("cu_assign", t_assign, air.metrics),
        PassStats("psum_schedule", t_sched - t_icr, sir.metrics),
        PassStats("icr_reorder", t_icr, icr_metrics),
        PassStats("stall_elide", t_elide, eir.metrics),
        PassStats("pack_emit", t_emit, {
            "planes": prog.planes,
            "emitted_cycles": prog.cycles,
            "instr_bytes": prog.instr_bytes(),
        }),
    ]
    if select_stats is not None:
        prog.stats.pass_stats.append(select_stats)
    if verify_ir:
        prog.stats.pass_stats.append(
            PassStats("verify_ir", t_verify, {"stages_verified": verified}))
    prog.stats.compile_seconds = time.perf_counter() - t0
    return prog


def recompile_values(prog: Program, new_workload) -> Program:
    """Values-only recompilation: reuse the schedule, regather the stream.

    Factorization loops re-solve one sparsity *pattern* with fresh numeric
    values every step; the schedule (partition / cu-assign / psum-cache /
    ICR / elide — everything but the value stream) depends only on the
    pattern, so recompiling it is pure waste.  This fast path gathers a
    fresh value stream through the program's provenance plane
    (``prog.stream_src``, recorded by the schedule pass: entry >= 0 is a
    global edge index into the workload's weight array, a negative entry
    -(i+1) is node i's scale) and returns a *new* `Program` sharing every
    other tensor with ``prog``.

    ``new_workload`` is a `TriCSR` (lowered through the SpTRSV frontend —
    a pure re-slicing, no scheduling) or any `ComputeDag`.  It must have
    the same pattern as the program's source workload: same ``n``, same
    edge count.  Callers that cannot guarantee pattern equality must key
    on a structure fingerprint first (`serve.pattern_fingerprint`, as
    `serve.ProgramCache` does).

    Raises ``ValueError`` when ``prog`` carries no provenance plane (a
    pre-provenance deserialized program — take the full recompile path)
    or when the shapes disagree; the new workload's values are validated
    (finite weights, finite non-zero scale) before gathering.

    The returned program is a distinct object on purpose: executors fold
    the stream into their traces as constants and cache per program
    *identity*, so refreshing values in place would silently serve stale
    numbers from cached traces.
    """
    import dataclasses

    import numpy as np

    from ..csr import TriCSR

    if isinstance(new_workload, TriCSR):
        from ..frontends.sptrsv import lower_tri

        dag = lower_tri(new_workload)
    else:
        dag = new_workload
    ss = prog.stream_src
    if ss is None:
        raise ValueError(
            "program carries no value-provenance plane (stream_src) — "
            "compiled before values-only recompilation existed; run a "
            "full recompile instead")
    if dag.n != prog.n:
        raise ValueError(
            f"values refresh for n={prog.n} program got a workload with "
            f"n={dag.n}")
    if ss.shape != prog.stream.shape:
        raise ValueError(
            f"provenance plane has {ss.size} entries but the stream has "
            f"{prog.stream.size}")
    dag.validate()
    edge = ss >= 0
    if (edge.any() and int(ss[edge].max()) >= dag.n_edges) or \
            ((~edge).any() and int(-(ss[~edge].min() + 1)) >= dag.n):
        raise ValueError(
            f"provenance plane indexes outside the new workload "
            f"({dag.n_edges} edges, {dag.n} nodes) — pattern mismatch")
    new_stream = np.empty(ss.shape, dtype=np.float64)
    new_stream[edge] = dag.weight[ss[edge]]
    new_stream[~edge] = dag.scale[-(ss[~edge] + 1)]
    return dataclasses.replace(
        prog,
        stream=new_stream.astype(np.float32),
        stats=dataclasses.replace(prog.stats, name=dag.name),
    )
