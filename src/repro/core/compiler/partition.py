"""Partition pass: frontend `ComputeDag` → medium-granularity `PartitionIR`.

The paper's medium-granularity dataflow (§IV-A) fixes the partitioning of
work: each DAG node is the minimal *allocation* unit (all its input edges
run on one CU, accumulating into that CU's psum feedback) and each edge is
the minimal *scheduling* unit (edges of one node may execute in any order,
interleaved with other nodes via the psum cache).  This pass materializes
that view: it enforces the frontend contract (`ComputeDag.validate`) and
builds the consumer adjacency + in-degrees the scheduler wakes nodes with.
"""

from __future__ import annotations

from .ir import ComputeDag, PartitionIR

__all__ = ["run"]


def run(dag: ComputeDag) -> PartitionIR:
    dag.validate()
    n = dag.n
    consumers: list[list[int]] = [[] for _ in range(n)]
    ptr, src = dag.ptr, dag.src
    for i in range(n):
        for j in src[ptr[i] : ptr[i + 1]]:
            consumers[j].append(i)
    in_degree = dag.in_degree()
    metrics = {
        "nodes": n,
        "edges": dag.n_edges,
        "max_in_degree": int(in_degree.max()) if n else 0,
        "source_nodes": int((in_degree == 0).sum()),
    }
    return PartitionIR(dag=dag, consumers=consumers, in_degree=in_degree,
                       metrics=metrics)
