"""Structured exception taxonomy for the hardened solve path (DESIGN.md §7).

Every detectable failure in the compile/serialize/execute stack maps to one
of three families so callers (the fallback ladder in `core/robust.py`, the
serving layer, operators reading incident records) can branch on *what went
wrong* instead of parsing message strings:

  * `ProgramCorruptionError`   — the compiled artifact itself is damaged:
    checksum mismatch on a serialized blob, packed instruction fields out
    of range, row-envelope metadata inconsistent with the instruction
    words, psum slot lifetime violations, dependency-order violations.
    A corrupted program must never be executed; re-fetch or recompile.
  * `NumericalHealthError`     — the program is fine but the *numbers*
    are not: NaN/Inf in the right-hand side, non-finite solution values,
    a relative residual above tolerance.  Retrying the same backend is
    pointless; degrading to a reference executor (or re-validating the
    inputs) is the correct response.
  * `BackendExecutionError`    — an execution engine failed or was asked
    for an impossible configuration: unknown backend name, stray options,
    an infeasible kernel placement, or a crash inside the backend.  The
    next rung of the ladder may well succeed.

Several leaves multiply inherit the historical builtin (``ValueError`` /
``TypeError``) they replace, so pre-taxonomy callers and tests that catch
the builtin keep working while new code catches the taxonomy — and unlike
the bare ``assert`` validation they replace, these survive ``python -O``.
"""

from __future__ import annotations

__all__ = [
    "RobustnessError",
    "ProgramCorruptionError",
    "IRValidationError",
    "MatrixValidationError",
    "NumericalHealthError",
    "BackendExecutionError",
    "UnknownBackendError",
    "BackendOptionsError",
    "PlacementInfeasibleError",
    "ServingError",
    "DeadlineExceededError",
    "LoadShedError",
]


class RobustnessError(Exception):
    """Base of the hardened-solve-path taxonomy (DESIGN.md §7).

    ``detail`` is an optional machine-readable payload (plain dict) that
    incident records (`robust.Incident`) carry verbatim.
    """

    def __init__(self, message: str, *, detail: dict | None = None):
        super().__init__(message)
        self.detail = dict(detail) if detail else {}


class ProgramCorruptionError(RobustnessError, ValueError):
    """A compiled `Program` (or its serialized form) failed integrity checks."""


class IRValidationError(ProgramCorruptionError):
    """An intermediate IR broke a pass contract (`compile_dag(verify_ir=True)`).

    Raised between compiler passes by the static analyzer
    (`core/analysis/contracts.py`); the message and ``detail`` name the
    pipeline stage whose output violated its invariant plus the
    diagnostic codes found, so a miscompile is attributed to a pass
    instead of surfacing later as a generic corrupt-program failure.
    """


class MatrixValidationError(RobustnessError, ValueError):
    """A sparse-matrix container violates its layout contract.

    Raised by `TriCSR.validate` / `UpperCSR.validate` / `from_coo` with the
    offending matrix name and row in the message (and in ``detail``), in
    place of the historical bare ``assert``s that vanished under
    ``python -O``.
    """


class NumericalHealthError(RobustnessError, ValueError):
    """Inputs or outputs of a solve are numerically unhealthy.

    Covers NaN/Inf right-hand sides, wrong input shape/dtype, non-finite
    solution components, and relative residuals above tolerance.
    """


class BackendExecutionError(RobustnessError, RuntimeError):
    """An execution backend failed, or was configured impossibly."""


class UnknownBackendError(BackendExecutionError, ValueError):
    """Backend name outside the supported set (``"jax"``/``"pallas"``/...)."""


class BackendOptionsError(BackendExecutionError, TypeError):
    """Options passed to a backend that does not accept them."""


class PlacementInfeasibleError(BackendExecutionError, ValueError):
    """The requested Pallas memory placement admits no valid window plan."""


class ServingError(RobustnessError):
    """Service-level failure of the resilient serving layer (DESIGN.md §10).

    The solve stack below is healthy or degraded as its own taxonomy
    describes; this family covers the *service* refusing or abandoning a
    request — by policy, never silently.  ``detail`` carries the
    machine-readable request context (matrix id, deadline, budgets).
    """


class DeadlineExceededError(ServingError):
    """A request's deadline passed before its solve could complete.

    Raised from `serve.SolveTicket.result` when the serving layer failed
    the ticket fast (already expired at submit, or expired while pending)
    instead of consuming a solve on an answer nobody is waiting for.
    ``detail`` carries ``deadline`` / ``now`` on the service clock.
    """


class LoadShedError(ServingError):
    """A request was shed by admission control (bounded pending budgets).

    Raised from `serve.ShedTicket.result`: the per-matrix or global
    pending-column budget was full, so the service refused the request
    instead of growing its queues unboundedly.  ``detail`` names the
    exhausted budget and its limit.
    """
