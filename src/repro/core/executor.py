"""Executors for compiled VLIW programs.

Three implementations of identical semantics:
  * `execute_numpy`  — simple per-cycle Python/numpy loop (debug oracle);
  * `execute_jax`    — `jax.lax.scan` over cycles, fully vectorized over CUs
                       (the production CPU/TPU path for moderate n);
  * the Pallas kernel in `repro.kernels.sptrsv` (VMEM-resident register
    files, BlockSpec-tiled instruction stream).

Per-cycle semantics (see program.py): the psum control is applied first
(it configures the S1/S2 muxes and psum register file of Fig. 4b), then the
PE op executes.  Edges only ever read x values finalized in *earlier*
cycles (the scheduler guarantees it), so a cycle can be evaluated as one
parallel gather/FMA/scatter over all CUs.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .program import (
    OP_EDGE,
    OP_FINAL,
    PS_KEEP,
    PS_LOAD,
    PS_RESET,
    PS_STORE_RESET,
    PS_SWAP,
    Program,
)
from .schedule import PSUM_OVERFLOW_SLOTS

__all__ = ["execute_numpy", "execute_jax", "make_jax_executor"]


def _psum_slots(prog: Program) -> int:
    base = prog.config.psum_words + PSUM_OVERFLOW_SLOTS
    return max(base, prog.num_slots or 0)


def execute_numpy(prog: Program, b: np.ndarray) -> np.ndarray:
    """Reference interpretation of the instruction stream."""
    n, p = prog.n, prog.num_cus
    x = np.zeros(n + 1, dtype=np.float64)
    feedback = np.zeros(p, dtype=np.float64)
    rf = np.zeros((p, _psum_slots(prog)), dtype=np.float64)
    stream = prog.stream.astype(np.float64)

    for t in range(prog.cycles):
        for c in range(p):
            op = prog.opcode[t, c]
            if op == 0:
                continue
            ctrl = prog.psum_ctrl[t, c]
            slot = prog.psum_slot[t, c]
            pv = feedback[c]
            if ctrl == PS_RESET:
                pv = 0.0
            elif ctrl == PS_LOAD:
                pv = rf[c, slot]
            elif ctrl == PS_STORE_RESET:
                rf[c, slot] = pv
                pv = 0.0
            elif ctrl == PS_SWAP:
                pv, rf[c, slot] = rf[c, slot], pv
            v = stream[prog.val_idx[t, c]]
            s = prog.src_idx[t, c]
            if op == OP_EDGE:
                pv = pv + v * x[s]
            else:  # OP_FINAL
                out = (b[s] - pv) * v
                x[prog.out_idx[t, c]] = out
            feedback[c] = pv
    return x[:n]


def make_jax_executor(prog: Program):
    """Build a jitted `solve(b) -> x` closure for one compiled program.

    All instruction arrays become constants folded into the jaxpr; the
    cycle loop is a `lax.scan` whose carry is (x, feedback, psum_rf).
    """
    n, p = prog.n, prog.num_cus
    ops = jnp.asarray(prog.opcode.astype(np.int32))
    vidx = jnp.asarray(prog.val_idx)
    sidx = jnp.asarray(prog.src_idx)
    oidx = jnp.asarray(prog.out_idx)
    pctl = jnp.asarray(prog.psum_ctrl.astype(np.int32))
    pslt = jnp.asarray(prog.psum_slot.astype(np.int32))
    stream = jnp.asarray(prog.stream, dtype=jnp.float32)
    nslots = _psum_slots(prog)
    lanes = jnp.arange(p)

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        bx = jnp.concatenate([b.astype(jnp.float32), jnp.zeros((1,), jnp.float32)])

        def step(carry, instr):
            x, feedback, rf = carry
            op, vi, si, oi, ct, sl = instr
            pv = feedback
            slot_val = rf[lanes, sl]
            # psum control mux (S1/S2 of Fig. 4b)
            pv = jnp.where(ct == PS_RESET, 0.0, pv)
            pv = jnp.where(ct == PS_LOAD, slot_val, pv)
            store_val = jnp.where(
                (ct == PS_STORE_RESET) | (ct == PS_SWAP), feedback, slot_val
            )
            rf = rf.at[lanes, sl].set(store_val)
            pv = jnp.where(ct == PS_STORE_RESET, 0.0, pv)
            pv = jnp.where(ct == PS_SWAP, slot_val, pv)

            v = stream[vi]
            pv = jnp.where(op == OP_EDGE, pv + v * x[si], pv)
            outv = (bx[si] - pv) * v
            # non-FINAL lanes scatter into the dummy slot x[n]
            write_idx = jnp.where(op == OP_FINAL, oi, n)
            x = x.at[write_idx].set(outv, mode="promise_in_bounds")
            return (x, pv, rf), ()

        x0 = jnp.zeros(n + 1, dtype=jnp.float32)
        f0 = jnp.zeros(p, dtype=jnp.float32)
        rf0 = jnp.zeros((p, nslots), dtype=jnp.float32)
        (x, _, _), _ = jax.lax.scan(
            step, (x0, f0, rf0), (ops, vidx, sidx, oidx, pctl, pslt)
        )
        return x[:n]

    return jax.jit(solve)


def execute_jax(prog: Program, b: np.ndarray) -> np.ndarray:
    return np.asarray(make_jax_executor(prog)(jnp.asarray(b)))
