"""Executors for compiled VLIW programs.

Three implementations of identical semantics:
  * `execute_numpy`  — per-cycle numpy loop, vectorized over CUs and batch
                       (debug oracle);
  * `execute_jax`    — `jax.lax.scan` over cycles, fully vectorized over CUs
                       and right-hand sides (the production CPU/TPU path);
  * the Pallas kernel in `repro.kernels.sptrsv` (`make_pallas_executor`):
    VMEM-resident register files, double-buffered async-DMA instruction
    streaming, and — for n too large for VMEM residency — the HBM-resident
    row-blocked x/b placement with level-boundary window streaming
    (DESIGN.md §1).

Per-cycle semantics (see program.py): the psum control is applied first
(it configures the S1/S2 muxes and psum register file of Fig. 4b), then the
PE op executes.  Edges only ever read x values finalized in *earlier*
cycles (the scheduler guarantees it), so a cycle can be evaluated as one
parallel gather/FMA/scatter over all CUs.

Batched multi-RHS execution
---------------------------
The instruction stream depends only on the matrix L, not on b, so one pass
over the stream can solve `B` right-hand sides at once: state becomes
``x[n_pad, B]``, ``feedback[P, B]``, ``rf[P, S, B]`` and every per-cycle
gather/FMA/select/scatter broadcasts the instruction word over the batch
axis.  This amortizes instruction-stream traffic and jit/dispatch overhead
across the batch — the software analogue of streaming the VLIW program once
while the datapath processes many vectors.

Executors are cached per compiled program and *padded* batch width
(`pad_batch`), so repeated solves — including nearby batch sizes that pad
to the same width — never retrace.

Multi-device: `repro.core.shard` maps `build_solve_cols` over per-device
column blocks of the batch axis with `shard_map` (its own cache, keyed per
(program, padded per-device width, mesh)); `trace_count` observes both
paths.
"""

from __future__ import annotations

import weakref

import numpy as np

import jax
import jax.numpy as jnp

from .program import (
    OP_EDGE,
    OP_FINAL,
    PS_LOAD,
    PS_RESET,
    PS_STORE_RESET,
    PS_SWAP,
    Program,
    decode_instructions,
)
from .schedule import PSUM_OVERFLOW_SLOTS

__all__ = [
    "as_batch",
    "batched_entry",
    "build_solve_cols",
    "cached_entries",
    "execute_numpy",
    "execute_jax",
    "make_jax_executor",
    "make_pallas_executor",
    "pad_batch",
    "trace_count",
    "validate_backend",
]

BATCH_PAD = 8  # batch widths are padded to a multiple of this (lane-friendly)

# Bumped (at trace time only) whenever a jax executor is traced; tests use it
# to assert the per-program cache prevents retracing.
_TRACE_COUNT = 0

# prog -> {padded_batch_width -> jitted solve}; weak keys let programs die.
_EXEC_CACHE: "weakref.WeakKeyDictionary[Program, dict]" = weakref.WeakKeyDictionary()


def trace_count() -> int:
    """Number of jax-executor traces so far (cache-hit observability)."""
    return _TRACE_COUNT


def cached_entries(prog: Program) -> list:
    """Keys of the per-program executor cache (cache-hit observability).

    Jax entries are padded-width ints (the cache-key contract asserted in
    `_cached_executor`); pallas entries are ``("pallas", width, *knobs)``
    tuples.  The serving tests use this to prove micro-batch bucketing
    never creates a key the contract forbids."""
    return sorted(_EXEC_CACHE.get(prog, {}), key=repr)


def pad_batch(width: int) -> int:
    """Round a batch width up to the lane-friendly padded width."""
    if width <= 1:
        return 1
    return -(-width // BATCH_PAD) * BATCH_PAD


def as_batch(b: np.ndarray, dtype=None) -> tuple[np.ndarray, bool]:
    """Normalize a RHS to ``([n, B], was_1d)`` — shared by all executors.

    With ``dtype=None``, arrays (including device-resident jax arrays) pass
    through without a host copy; only array-likes are coerced.
    """
    if dtype is not None or not hasattr(b, "ndim"):
        b = np.asarray(b, dtype=dtype)
    single = b.ndim == 1
    return (b[:, None] if single else b), single


def _psum_slots(prog: Program) -> int:
    base = prog.config.psum_words + PSUM_OVERFLOW_SLOTS
    return max(base, prog.num_slots or 0)


def execute_numpy(prog: Program, b: np.ndarray) -> np.ndarray:
    """Reference interpretation of the instruction stream.

    Accepts ``b`` of shape ``[n]`` (single RHS) or ``[n, B]`` (batched);
    returns ``x`` of the matching shape.  Each cycle is evaluated as one
    vectorized gather/FMA/select/scatter over all CUs and all RHS columns.
    """
    bmat, single = as_batch(b, dtype=np.float64)
    nb = bmat.shape[1]

    n, p = prog.n, prog.num_cus
    x = np.zeros((n + 1, nb), dtype=np.float64)
    feedback = np.zeros((p, nb), dtype=np.float64)
    rf = np.zeros((p, _psum_slots(prog), nb), dtype=np.float64)
    stream = prog.stream.astype(np.float64)
    lanes = np.arange(p)
    planes = prog.planes

    for t in range(prog.cycles):
        # shared packed decode — NOP lanes carry word 0, i.e. ctrl PS_KEEP
        op, src, ctrl, slot = decode_instructions(prog.instr[t], planes)
        slot = slot.astype(np.intp)
        ctb = ctrl[:, None]

        pv = feedback
        slot_val = rf[lanes, slot]  # [p, nb]
        # psum control mux (S1/S2 of Fig. 4b)
        pv = np.where(ctb == PS_RESET, 0.0, pv)
        pv = np.where(ctb == PS_LOAD, slot_val, pv)
        store = (ctrl == PS_STORE_RESET) | (ctrl == PS_SWAP)
        rf[lanes[store], slot[store]] = feedback[store]
        pv = np.where(ctb == PS_STORE_RESET, 0.0, pv)
        pv = np.where(ctb == PS_SWAP, slot_val, pv)

        v = stream[prog.val_idx[t]][:, None]  # [p, 1]
        edge = op == OP_EDGE
        pv = np.where(edge[:, None], pv + v * x[src], pv)
        fin = op == OP_FINAL
        if fin.any():
            # FINAL writes x[src] (the derived out index); finalized rows
            # are distinct within a cycle (scheduler guarantee)
            x[src[fin]] = (bmat[src[fin]] - pv[fin]) * v[fin]
        feedback = pv
    xr = x[:n]
    return xr[:, 0] if single else xr


def build_solve_cols(prog: Program, width: int):
    """Unjitted `solve(b[n, width]) -> x[n, width]` over the instruction stream.

    All instruction arrays become constants folded into the jaxpr; the
    cycle loop is a `lax.scan` whose carry is (x, feedback, psum_rf), each
    carrying a trailing batch axis of `width` RHS columns.

    This is the trace target shared by the local jit path below and the
    multi-device `shard_map` path (`repro.core.shard`), which maps it over
    per-device column blocks with the instruction constants replicated.
    """
    n, p = prog.n, prog.num_cus
    planes = prog.planes
    instr_words = jnp.asarray(prog.instr)  # [T, planes, P] packed
    vidx = jnp.asarray(prog.val_idx)
    stream = jnp.asarray(prog.stream, dtype=jnp.float32)
    nslots = _psum_slots(prog)
    lanes = jnp.arange(p)

    def solve_cols(b: jnp.ndarray) -> jnp.ndarray:
        global _TRACE_COUNT
        _TRACE_COUNT += 1  # runs at trace time only
        bx = jnp.concatenate(
            [b.astype(jnp.float32), jnp.zeros((1, width), jnp.float32)], axis=0
        )

        def step(carry, instr):
            x, feedback, rf = carry
            iw, vi = instr
            op, si, ct, sl = decode_instructions(iw, planes)
            ctb = ct[:, None]
            pv = feedback
            slot_val = rf[lanes, sl]  # [p, width]
            # psum control mux (S1/S2 of Fig. 4b)
            pv = jnp.where(ctb == PS_RESET, 0.0, pv)
            pv = jnp.where(ctb == PS_LOAD, slot_val, pv)
            store_val = jnp.where(
                (ctb == PS_STORE_RESET) | (ctb == PS_SWAP), feedback, slot_val
            )
            rf = rf.at[lanes, sl].set(store_val)
            pv = jnp.where(ctb == PS_STORE_RESET, 0.0, pv)
            pv = jnp.where(ctb == PS_SWAP, slot_val, pv)

            v = stream[vi][:, None]
            pv = jnp.where((op == OP_EDGE)[:, None], pv + v * x[si], pv)
            outv = (bx[si] - pv) * v
            # derived out index: FINAL writes x[src], everything else
            # scatters into the dummy row x[n]
            write_idx = jnp.where(op == OP_FINAL, si, n)
            x = x.at[write_idx].set(outv, mode="promise_in_bounds")
            return (x, pv, rf), ()

        x0 = jnp.zeros((n + 1, width), dtype=jnp.float32)
        f0 = jnp.zeros((p, width), dtype=jnp.float32)
        rf0 = jnp.zeros((p, nslots, width), dtype=jnp.float32)
        (x, _, _), _ = jax.lax.scan(step, (x0, f0, rf0), (instr_words, vidx))
        return x[:n]

    return solve_cols


def _build_jax_executor(prog: Program, width: int):
    """Jitted single-device wrapper around `build_solve_cols`."""
    solve_cols = build_solve_cols(prog, width)
    if width == 1:
        # single-RHS form: `solve(b[n]) -> x[n]`, wrap/unwrap inside the jit
        # so the hot path stays one dispatch
        return jax.jit(lambda b: solve_cols(b[:, None])[:, 0])
    return jax.jit(solve_cols)


def _cached_executor(prog: Program, width: int):
    # Cache-key contract (DESIGN.md §4/§9): jax entries are keyed by the
    # *padded* width only — every caller rounds with `pad_batch` before
    # lookup, so batch sizes that pad equal share one trace, and the serve
    # layer's bucket widths (core/serve.py, which buckets with the same
    # `pad_batch`) can never diverge from the cache keys.  An unpadded
    # width reaching this point is a caller bug, not a cache miss.
    if width != pad_batch(width):
        raise AssertionError(
            f"executor cache key must be a padded width "
            f"(pad_batch({width}) == {pad_batch(width)}), got {width}")
    per_prog = _EXEC_CACHE.get(prog)
    if per_prog is None:
        per_prog = {}
        _EXEC_CACHE[prog] = per_prog
    fn = per_prog.get(width)
    if fn is None:
        fn = _build_jax_executor(prog, width)
        per_prog[width] = fn
    return fn


def batched_entry(core, n: int, batch: int, width: int, *,
                  single_core: bool = False, place=None):
    """Shared `solver(b[n, batch]) -> x[n, batch]` entry wrapper.

    Validates the shape, pads the batch axis to ``width``, optionally
    places the padded matrix on devices (``place``, the sharded path of
    `core.shard`), calls ``core`` and slices the pad columns back off.
    ``single_core`` marks a width-1 core with the `[n] -> [n]` signature.
    """

    def solve_many(bmat):
        bmat = jnp.asarray(bmat, dtype=jnp.float32)
        if bmat.shape != (n, batch):
            raise ValueError(f"expected b of shape {(n, batch)}, got {bmat.shape}")
        if batch == 0:
            return jnp.zeros((n, 0), jnp.float32)
        if single_core:
            return core(bmat[:, 0])[:, None]
        if batch != width:
            bmat = jnp.pad(bmat, ((0, 0), (0, width - batch)))
        if place is not None:
            bmat = place(bmat)
        return core(bmat)[:, :batch]

    return solve_many


def make_jax_executor(prog: Program, batch: int | None = None):
    """Build (or fetch from cache) a jitted solve closure for `prog`.

    * ``batch=None`` — `solve(b[n]) -> x[n]`, the classic single-RHS form.
    * ``batch=B``    — `solve(b[n, B]) -> x[n, B]`: one pass over the
      instruction stream solves all B columns.

    The underlying jitted executor is cached per (program identity, padded
    batch width): repeated calls — and batch widths that pad to the same
    width — reuse the trace.
    """
    if batch is None:
        core = _cached_executor(prog, 1)
        n = prog.n

        def solve_one(b):
            # np-side cast (no-copy when already f32) keeps one trace per
            # program regardless of caller dtype; jax arrays and tracers
            # pass through untouched so the closure stays transformable
            if not isinstance(b, jax.Array):
                b = np.asarray(b, np.float32)
            if b.shape != (n,):
                raise ValueError(f"expected b of shape {(n,)}, got {b.shape}")
            return core(b)

        return solve_one

    width = pad_batch(batch)
    core = _cached_executor(prog, width)
    return batched_entry(core, prog.n, batch, width, single_core=width == 1)


def validate_backend(backend: str, backend_opts: dict) -> None:
    """Shared backend-argument check for api/shard solver entry points.

    Rejections use the structured taxonomy (`core.errors`, DESIGN.md §7):
    `UnknownBackendError` for a backend name outside the supported set,
    `BackendOptionsError` for options a backend does not accept.  Both
    also subclass the historical builtin (``ValueError`` / ``TypeError``)
    they replace, so pre-taxonomy callers keep working.
    """
    from .errors import BackendOptionsError, UnknownBackendError

    if backend not in ("jax", "pallas"):
        raise UnknownBackendError(
            f"unknown backend {backend!r} (choose 'jax' or 'pallas')",
            detail={"backend": backend})
    if backend == "jax" and backend_opts:
        raise BackendOptionsError(
            f"backend='jax' takes no extra options, got "
            f"{sorted(backend_opts)}",
            detail={"backend": backend, "options": sorted(backend_opts)})


def make_pallas_executor(
    prog: Program,
    batch: int | None = None,
    *,
    cycles_per_block: int = 128,
    placement: str = "auto",
    vmem_limit_bytes: int | None = None,
    x_block_rows: int | None = None,
    interpret: bool | None = None,
):
    """Build (or fetch from cache) a Pallas-kernel solve closure for `prog`.

    Same calling convention as `make_jax_executor` (``batch=None`` ->
    ``solve(b[n]) -> x[n]``; ``batch=B`` -> ``solve(b[n, B]) -> x[n, B]``)
    but executing `repro.kernels.sptrsv` instead of the `lax.scan` program.

    ``placement`` selects the kernel's memory regime: ``"resident"`` keeps
    x and b VMEM-resident, ``"blocked"`` forces the HBM-resident row-window
    path (large n), ``"auto"`` switches on the x+b footprint crossing
    ``vmem_limit_bytes`` (see `repro.kernels.sptrsv.ops.resolve_placement`).
    Executors are cached per (program identity, padded batch width, all
    placement knobs, interpret) — the window plan and the staged
    instruction tensors are computed once per cache entry, so repeated
    solves never re-stage or retrace.
    """
    from repro.kernels.sptrsv import ops as sptrsv_ops  # lazy: ops imports us

    if vmem_limit_bytes is None:
        vmem_limit_bytes = sptrsv_ops.DEFAULT_STATE_BYTES
    width = pad_batch(batch if batch is not None else 1)
    key = ("pallas", width, cycles_per_block, placement, vmem_limit_bytes,
           x_block_rows, interpret)
    per_prog = _EXEC_CACHE.get(prog)
    if per_prog is None:
        per_prog = {}
        _EXEC_CACHE[prog] = per_prog
    core = per_prog.get(key)
    if core is None:
        try:
            core = sptrsv_ops.build_solver_cols(
                prog, width, cycles_per_block=cycles_per_block,
                placement=placement, vmem_limit_bytes=vmem_limit_bytes,
                x_block_rows=x_block_rows, interpret=interpret,
            )
        except Exception as e:
            # surface kernel/staging construction failures as the taxonomy
            # (DESIGN.md §7) so the fallback ladder can classify and
            # degrade; taxonomy leaves (e.g. an infeasible placement) pass
            # through untouched
            from .errors import BackendExecutionError, RobustnessError

            if isinstance(e, RobustnessError):
                raise
            raise BackendExecutionError(
                f"pallas solver construction failed "
                f"({type(e).__name__}: {e})",
                detail={"placement": placement, "width": width}) from e
        per_prog[key] = core
    n = prog.n
    if batch is None:
        def solve_one(b):
            b = jnp.asarray(b, jnp.float32)
            if b.shape != (n,):
                raise ValueError(f"expected b of shape {(n,)}, got {b.shape}")
            return core(b[:, None])[:, 0]

        solve_one.placement = core.placement
        solve_one.plan = core.plan
        return solve_one
    entry = batched_entry(core, n, batch, width)
    entry.placement = core.placement
    entry.plan = core.plan
    return entry


def execute_jax(prog: Program, b: np.ndarray) -> np.ndarray:
    """Solve via the cached jax executor; `b` is `[n]` or `[n, B]`."""
    bmat, single = as_batch(b)
    if single:
        return np.asarray(make_jax_executor(prog)(bmat[:, 0]))
    return np.asarray(make_jax_executor(prog, batch=bmat.shape[1])(bmat))
