"""Compressed-sparse-row storage for sparse lower-triangular systems.

Follows the paper's convention (Fig. 1b / Algo. 1):
  * the matrix is lower triangular with a non-zero diagonal,
  * within each row the off-diagonal entries come first (ascending column)
    and the diagonal entry is stored LAST (``rowptr[i+1]-1``),
  * ``rowptr`` has length ``n+1`` with ``rowptr[n] == nnz``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

from .errors import MatrixValidationError

__all__ = [
    "TriCSR",
    "UpperCSR",
    "serial_solve",
    "serial_solve_upper",
    "from_coo",
    "transpose_upper",
    "random_rhs",
]


def _reject(name: str, msg: str, row: int | None = None):
    """Raise a `MatrixValidationError` naming the matrix (and row).

    Structured replacement for the historical bare ``assert``s: the checks
    keep running under ``python -O`` and the message pinpoints the defect.
    """
    where = f"matrix {name!r}" + (f", row {row}" if row is not None else "")
    raise MatrixValidationError(
        f"{where}: {msg}",
        detail={"matrix": name, **({"row": int(row)} if row is not None else {})},
    )


def _first_bad_row(rowptr: np.ndarray, mask: np.ndarray) -> int:
    """Map a per-nnz boolean defect mask to its (first) row index."""
    pos = int(np.argmax(mask))
    return int(np.searchsorted(rowptr, pos, side="right") - 1)


@dataclasses.dataclass(frozen=True)
class TriCSR:
    """A sparse lower-triangular matrix in the paper's CSR layout."""

    n: int
    rowptr: np.ndarray  # int64 [n+1]
    colidx: np.ndarray  # int64 [nnz]
    values: np.ndarray  # float64 [nnz]
    name: str = "unnamed"

    # ------------------------------------------------------------------
    @property
    def nnz(self) -> int:
        return int(self.rowptr[-1])

    @property
    def n_edges(self) -> int:
        """Off-diagonal non-zeros == DAG edge count."""
        return self.nnz - self.n

    @property
    def binary_nodes(self) -> int:
        """Paper Table III: number of binary nodes == flop count == 2*nnz - n."""
        return 2 * self.nnz - self.n

    # ------------------------------------------------------------------
    def validate(self) -> None:
        """Check the layout contract; raises `MatrixValidationError`
        naming this matrix and the first offending row (vectorized —
        the per-row python loop only runs to localize a failure)."""
        rp, ci = self.rowptr, self.colidx
        if rp.shape != (self.n + 1,) or rp[0] != 0 or ci.shape[0] != rp[-1]:
            _reject(self.name, f"rowptr/colidx envelope broken "
                               f"(rowptr shape {rp.shape}, nnz {ci.shape})")
        deg = np.diff(rp)
        if np.any(deg < 1):
            _reject(self.name, "missing diagonal (empty row)",
                    int(np.argmax(deg < 1)))
        rows = np.repeat(np.arange(self.n, dtype=np.int64), deg)
        if not np.array_equal(ci[rp[1:] - 1], np.arange(self.n)):
            bad = int(np.argmax(ci[rp[1:] - 1] != np.arange(self.n)))
            _reject(self.name, "diagonal must be stored last", bad)
        off = np.ones(ci.shape[0], dtype=bool)
        off[rp[1:] - 1] = False  # mask the diagonal slots
        if np.any(ci[off] >= rows[off]):
            m = np.zeros_like(off)
            m[off] = ci[off] >= rows[off]
            _reject(self.name, "super-diagonal entry",
                    _first_bad_row(rp, m))
        run = np.zeros(ci.shape[0], dtype=bool)
        run[1:] = (np.diff(ci) <= 0) & off[1:] & off[:-1] \
            & (rows[1:] == rows[:-1])
        if np.any(run):
            _reject(self.name, "unsorted/duplicate columns",
                    _first_bad_row(rp, run))
        if np.any(self.values[rp[1:] - 1] == 0.0):
            _reject(self.name, "zero diagonal",
                    int(np.argmax(self.values[rp[1:] - 1] == 0.0)))

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = self.rowptr[i], self.rowptr[i + 1]
        return self.colidx[lo:hi], self.values[lo:hi]

    def diag(self) -> np.ndarray:
        return self.values[self.rowptr[1:] - 1]

    def in_degree(self) -> np.ndarray:
        """Number of input edges (off-diagonal nnz) per node."""
        return np.diff(self.rowptr) - 1

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n, self.n))
        for i in range(self.n):
            cols, vals = self.row(i)
            out[i, cols] = vals
        return out


def from_coo(
    n: int,
    rows: Iterable[int],
    cols: Iterable[int],
    vals: Iterable[float],
    diag: np.ndarray,
    name: str = "unnamed",
) -> TriCSR:
    """Build a TriCSR from strictly-lower COO triples plus a diagonal vector."""
    rows = np.asarray(list(rows), dtype=np.int64)
    cols = np.asarray(list(cols), dtype=np.int64)
    vals = np.asarray(list(vals), dtype=np.float64)
    if np.any(cols >= rows):
        bad = int(np.argmax(cols >= rows))
        _reject(name, f"COO part must be strictly lower triangular "
                      f"(entry ({rows[bad]}, {cols[bad]}))", int(rows[bad]))
    # de-duplicate (keep last) and sort row-major
    key = rows * n + cols
    order = np.argsort(key, kind="stable")
    rows, cols, vals, key = rows[order], cols[order], vals[order], key[order]
    keep = np.ones(len(key), dtype=bool)
    keep[:-1] = key[:-1] != key[1:]
    rows, cols, vals = rows[keep], cols[keep], vals[keep]

    counts = np.bincount(rows, minlength=n) + 1  # +1 diagonal per row
    rowptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=rowptr[1:])
    colidx = np.empty(rowptr[-1], dtype=np.int64)
    values = np.empty(rowptr[-1], dtype=np.float64)
    cursor = rowptr[:-1].copy()
    for r, c, v in zip(rows, cols, vals):
        colidx[cursor[r]] = c
        values[cursor[r]] = v
        cursor[r] += 1
    # diagonal last
    colidx[rowptr[1:] - 1] = np.arange(n)
    values[rowptr[1:] - 1] = np.asarray(diag, dtype=np.float64)
    mat = TriCSR(n=n, rowptr=rowptr, colidx=colidx, values=values, name=name)
    mat.validate()
    return mat


@dataclasses.dataclass(frozen=True)
class UpperCSR:
    """A sparse upper-triangular matrix, the mirror of `TriCSR`'s layout.

    Within each row the columns are ascending with the diagonal stored
    FIRST (``rowptr[i]``) — the natural output of transposing a `TriCSR`
    row-major.  Solved by backward substitution (`serial_solve_upper`) or
    compiled through the upper/transpose frontend
    (`core/frontends/upper.py`), which reverses the row order so the
    system becomes lower-triangular in the internal node numbering.
    """

    n: int
    rowptr: np.ndarray  # int64 [n+1]
    colidx: np.ndarray  # int64 [nnz]
    values: np.ndarray  # float64 [nnz]
    name: str = "unnamed"

    @property
    def nnz(self) -> int:
        return int(self.rowptr[-1])

    @property
    def n_edges(self) -> int:
        return self.nnz - self.n

    def validate(self) -> None:
        """Mirror of `TriCSR.validate` for the upper layout (diagonal
        first, strictly super-diagonal ascending tail); raises
        `MatrixValidationError` naming this matrix and row."""
        rp, ci = self.rowptr, self.colidx
        if rp.shape != (self.n + 1,) or rp[0] != 0 or ci.shape[0] != rp[-1]:
            _reject(self.name, f"rowptr/colidx envelope broken "
                               f"(rowptr shape {rp.shape}, nnz {ci.shape})")
        deg = np.diff(rp)
        if np.any(deg < 1):
            _reject(self.name, "missing diagonal (empty row)",
                    int(np.argmax(deg < 1)))
        rows = np.repeat(np.arange(self.n, dtype=np.int64), deg)
        if not np.array_equal(ci[rp[:-1]], np.arange(self.n)):
            bad = int(np.argmax(ci[rp[:-1]] != np.arange(self.n)))
            _reject(self.name, "diagonal must be stored first", bad)
        off = np.ones(ci.shape[0], dtype=bool)
        off[rp[:-1]] = False  # mask the diagonal slots
        if np.any(ci[off] <= rows[off]):
            m = np.zeros_like(off)
            m[off] = ci[off] <= rows[off]
            _reject(self.name, "sub-diagonal entry", _first_bad_row(rp, m))
        run = np.zeros(ci.shape[0], dtype=bool)
        run[1:] = (np.diff(ci) <= 0) & off[1:] & off[:-1] \
            & (rows[1:] == rows[:-1])
        if np.any(run):
            _reject(self.name, "unsorted/duplicate columns",
                    _first_bad_row(rp, run))
        if np.any(self.values[rp[:-1]] == 0.0):
            _reject(self.name, "zero diagonal",
                    int(np.argmax(self.values[rp[:-1]] == 0.0)))

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = self.rowptr[i], self.rowptr[i + 1]
        return self.colidx[lo:hi], self.values[lo:hi]

    def diag(self) -> np.ndarray:
        return self.values[self.rowptr[:-1]]

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n, self.n))
        for i in range(self.n):
            cols, vals = self.row(i)
            out[i, cols] = vals
        return out


def transpose_upper(mat: TriCSR, name: str | None = None) -> UpperCSR:
    """Return ``U = Lᵀ`` as an `UpperCSR` (CSR of Lᵀ == CSC of L).

    Row j of U collects every L[i, j] sorted by i ascending; since L is
    lower triangular with a full diagonal, the first entry of each U row
    is automatically the diagonal.
    """
    n = mat.n
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(mat.rowptr))
    order = np.argsort(mat.colidx * n + rows, kind="stable")
    rowptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(mat.colidx, minlength=n), out=rowptr[1:])
    out = UpperCSR(
        n=n,
        rowptr=rowptr,
        colidx=rows[order],
        values=mat.values[order],
        name=name if name is not None else f"{mat.name}^T",
    )
    out.validate()
    return out


def serial_solve(mat: TriCSR, b: np.ndarray) -> np.ndarray:
    """Algorithm 1 of the paper — the ground-truth oracle."""
    x = np.zeros(mat.n, dtype=np.float64)
    for i in range(mat.n):
        lo, hi = mat.rowptr[i], mat.rowptr[i + 1]
        s = 0.0
        for j in range(lo, hi - 1):
            s += mat.values[j] * x[mat.colidx[j]]
        x[i] = (b[i] - s) / mat.values[hi - 1]
    return x


def serial_solve_upper(mat: UpperCSR, b: np.ndarray) -> np.ndarray:
    """Backward substitution for Ux=b — the upper-frontend oracle."""
    x = np.zeros(mat.n, dtype=np.float64)
    for i in range(mat.n - 1, -1, -1):
        lo, hi = mat.rowptr[i], mat.rowptr[i + 1]
        s = 0.0
        for j in range(lo + 1, hi):
            s += mat.values[j] * x[mat.colidx[j]]
        x[i] = (b[i] - s) / mat.values[lo]
    return x


def random_rhs(mat: TriCSR, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal(mat.n)
