"""Compressed-sparse-row storage for sparse lower-triangular systems.

Follows the paper's convention (Fig. 1b / Algo. 1):
  * the matrix is lower triangular with a non-zero diagonal,
  * within each row the off-diagonal entries come first (ascending column)
    and the diagonal entry is stored LAST (``rowptr[i+1]-1``),
  * ``rowptr`` has length ``n+1`` with ``rowptr[n] == nnz``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

__all__ = [
    "TriCSR",
    "UpperCSR",
    "serial_solve",
    "serial_solve_upper",
    "from_coo",
    "transpose_upper",
    "random_rhs",
]


@dataclasses.dataclass(frozen=True)
class TriCSR:
    """A sparse lower-triangular matrix in the paper's CSR layout."""

    n: int
    rowptr: np.ndarray  # int64 [n+1]
    colidx: np.ndarray  # int64 [nnz]
    values: np.ndarray  # float64 [nnz]
    name: str = "unnamed"

    # ------------------------------------------------------------------
    @property
    def nnz(self) -> int:
        return int(self.rowptr[-1])

    @property
    def n_edges(self) -> int:
        """Off-diagonal non-zeros == DAG edge count."""
        return self.nnz - self.n

    @property
    def binary_nodes(self) -> int:
        """Paper Table III: number of binary nodes == flop count == 2*nnz - n."""
        return 2 * self.nnz - self.n

    # ------------------------------------------------------------------
    def validate(self) -> None:
        assert self.rowptr.shape == (self.n + 1,)
        assert self.rowptr[0] == 0
        assert np.all(np.diff(self.rowptr) >= 1), "every row needs a diagonal"
        for i in range(self.n):
            lo, hi = self.rowptr[i], self.rowptr[i + 1]
            cols = self.colidx[lo:hi]
            assert cols[-1] == i, f"row {i}: diagonal must be stored last"
            off = cols[:-1]
            assert np.all(off < i), f"row {i}: super-diagonal entry"
            assert np.all(np.diff(off) > 0), f"row {i}: unsorted/duplicate cols"
        assert not np.any(self.values[self.rowptr[1:] - 1] == 0.0), "zero diagonal"

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = self.rowptr[i], self.rowptr[i + 1]
        return self.colidx[lo:hi], self.values[lo:hi]

    def diag(self) -> np.ndarray:
        return self.values[self.rowptr[1:] - 1]

    def in_degree(self) -> np.ndarray:
        """Number of input edges (off-diagonal nnz) per node."""
        return np.diff(self.rowptr) - 1

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n, self.n))
        for i in range(self.n):
            cols, vals = self.row(i)
            out[i, cols] = vals
        return out


def from_coo(
    n: int,
    rows: Iterable[int],
    cols: Iterable[int],
    vals: Iterable[float],
    diag: np.ndarray,
    name: str = "unnamed",
) -> TriCSR:
    """Build a TriCSR from strictly-lower COO triples plus a diagonal vector."""
    rows = np.asarray(list(rows), dtype=np.int64)
    cols = np.asarray(list(cols), dtype=np.int64)
    vals = np.asarray(list(vals), dtype=np.float64)
    assert np.all(cols < rows), "COO part must be strictly lower triangular"
    # de-duplicate (keep last) and sort row-major
    key = rows * n + cols
    order = np.argsort(key, kind="stable")
    rows, cols, vals, key = rows[order], cols[order], vals[order], key[order]
    keep = np.ones(len(key), dtype=bool)
    keep[:-1] = key[:-1] != key[1:]
    rows, cols, vals = rows[keep], cols[keep], vals[keep]

    counts = np.bincount(rows, minlength=n) + 1  # +1 diagonal per row
    rowptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=rowptr[1:])
    colidx = np.empty(rowptr[-1], dtype=np.int64)
    values = np.empty(rowptr[-1], dtype=np.float64)
    cursor = rowptr[:-1].copy()
    for r, c, v in zip(rows, cols, vals):
        colidx[cursor[r]] = c
        values[cursor[r]] = v
        cursor[r] += 1
    # diagonal last
    colidx[rowptr[1:] - 1] = np.arange(n)
    values[rowptr[1:] - 1] = np.asarray(diag, dtype=np.float64)
    mat = TriCSR(n=n, rowptr=rowptr, colidx=colidx, values=values, name=name)
    mat.validate()
    return mat


@dataclasses.dataclass(frozen=True)
class UpperCSR:
    """A sparse upper-triangular matrix, the mirror of `TriCSR`'s layout.

    Within each row the columns are ascending with the diagonal stored
    FIRST (``rowptr[i]``) — the natural output of transposing a `TriCSR`
    row-major.  Solved by backward substitution (`serial_solve_upper`) or
    compiled through the upper/transpose frontend
    (`core/frontends/upper.py`), which reverses the row order so the
    system becomes lower-triangular in the internal node numbering.
    """

    n: int
    rowptr: np.ndarray  # int64 [n+1]
    colidx: np.ndarray  # int64 [nnz]
    values: np.ndarray  # float64 [nnz]
    name: str = "unnamed"

    @property
    def nnz(self) -> int:
        return int(self.rowptr[-1])

    @property
    def n_edges(self) -> int:
        return self.nnz - self.n

    def validate(self) -> None:
        assert self.rowptr.shape == (self.n + 1,)
        assert self.rowptr[0] == 0
        assert np.all(np.diff(self.rowptr) >= 1), "every row needs a diagonal"
        for i in range(self.n):
            lo, hi = self.rowptr[i], self.rowptr[i + 1]
            cols = self.colidx[lo:hi]
            assert cols[0] == i, f"row {i}: diagonal must be stored first"
            off = cols[1:]
            assert np.all(off > i), f"row {i}: sub-diagonal entry"
            assert np.all(np.diff(off) > 0), f"row {i}: unsorted/duplicate cols"
        assert not np.any(self.values[self.rowptr[:-1]] == 0.0), "zero diagonal"

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = self.rowptr[i], self.rowptr[i + 1]
        return self.colidx[lo:hi], self.values[lo:hi]

    def diag(self) -> np.ndarray:
        return self.values[self.rowptr[:-1]]

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n, self.n))
        for i in range(self.n):
            cols, vals = self.row(i)
            out[i, cols] = vals
        return out


def transpose_upper(mat: TriCSR, name: str | None = None) -> UpperCSR:
    """Return ``U = Lᵀ`` as an `UpperCSR` (CSR of Lᵀ == CSC of L).

    Row j of U collects every L[i, j] sorted by i ascending; since L is
    lower triangular with a full diagonal, the first entry of each U row
    is automatically the diagonal.
    """
    n = mat.n
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(mat.rowptr))
    order = np.argsort(mat.colidx * n + rows, kind="stable")
    rowptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(mat.colidx, minlength=n), out=rowptr[1:])
    out = UpperCSR(
        n=n,
        rowptr=rowptr,
        colidx=rows[order],
        values=mat.values[order],
        name=name if name is not None else f"{mat.name}^T",
    )
    out.validate()
    return out


def serial_solve(mat: TriCSR, b: np.ndarray) -> np.ndarray:
    """Algorithm 1 of the paper — the ground-truth oracle."""
    x = np.zeros(mat.n, dtype=np.float64)
    for i in range(mat.n):
        lo, hi = mat.rowptr[i], mat.rowptr[i + 1]
        s = 0.0
        for j in range(lo, hi - 1):
            s += mat.values[j] * x[mat.colidx[j]]
        x[i] = (b[i] - s) / mat.values[hi - 1]
    return x


def serial_solve_upper(mat: UpperCSR, b: np.ndarray) -> np.ndarray:
    """Backward substitution for Ux=b — the upper-frontend oracle."""
    x = np.zeros(mat.n, dtype=np.float64)
    for i in range(mat.n - 1, -1, -1):
        lo, hi = mat.rowptr[i], mat.rowptr[i + 1]
        s = 0.0
        for j in range(lo + 1, hi):
            s += mat.values[j] * x[mat.colidx[j]]
        x[i] = (b[i] - s) / mat.values[lo]
    return x


def random_rhs(mat: TriCSR, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal(mat.n)
