"""Performance linter over compiled `Program`s (DESIGN.md §8, SPT2xx).

Static pathologies the schedule statistics and row envelopes expose —
nothing here affects correctness, every diagnostic is a throughput or
footprint observation with a suggested knob:

  * SPT201 — CU load imbalance (input-edge CV, §V-B of the paper);
  * SPT202 — psum spill pressure: overflow slots in use or emergency
    double-buffer parks (`dm_escapes`);
  * SPT203 — stall-row density (elided all-NOP cycles / total cycles);
  * SPT204 — the 2-plane packed fallback doubled instruction traffic;
  * SPT205 — the row envelope admits no blocked placement window, so the
    HBM-resident large-n path is unavailable;
  * SPT206 — PE utilization below threshold;
  * SPT207 — bank-conflict replay density (bnop share of all lanes);
  * SPT208 — the compiled scheduler strategy's predicted cycles exceed
    the best strategy on the frontier by more than ``frontier_warn``
    (requires ``stats.schedule_costs`` — recorded by ``schedule="auto"``
    compiles, or attached by `scripts/lint_program.py --frontier`).

Thresholds live in `LintConfig`; defaults are calibrated so the bundled
suite at the default `AccelConfig` stays warning-meaningful (hub-pattern
matrices legitimately warn, banded ones stay clean).
"""

from __future__ import annotations

import dataclasses

from .diagnostics import SEV_INFO, SEV_WARN, Diagnostic

__all__ = ["LintConfig", "lint_program"]


@dataclasses.dataclass(frozen=True)
class LintConfig:
    """Thresholds of the performance linter (see module docstring)."""

    load_cv_warn: float = 75.0     # SPT201: edge-CV% across CUs
    spill_info_slots: int = 0      # SPT202: overflow slots beyond config
    stall_warn: float = 0.25       # SPT203: elided stall rows / cycles
    util_warn: float = 0.10        # SPT206: exec lanes / total lanes
    conflict_warn: float = 0.05    # SPT207: bnop lanes / total lanes
    cycles_per_block: int = 128    # SPT205: blocked-placement granularity
    frontier_warn: float = 0.10    # SPT208: predicted cycles over the best
                                   # frontier strategy, as a fraction


def _diag(code, severity, message, *, hint="", **detail):
    return Diagnostic(code=code, severity=severity, message=message,
                      pass_name="program", hint=hint, detail=detail)


def lint_program(prog, lint_cfg: LintConfig | None = None):
    """Run every performance lint over a compiled `Program`.

    Returns a list of warn/info `Diagnostic`s; never errors.  Works on
    deserialized programs too — checks whose statistics did not survive
    serialization (`per_cu_edges`) are skipped silently.
    """
    lc = lint_cfg or LintConfig()
    st = prog.stats
    cfg = prog.config
    diags: list[Diagnostic] = []

    # SPT201 — CU load imbalance
    if st.per_cu_edges is not None and len(st.per_cu_edges) > 1:
        cv = st.load_balance_cv()
        if cv > lc.load_cv_warn:
            diags.append(_diag(
                "SPT201", SEV_WARN,
                f"CU input-edge load imbalance CV {cv:.1f}% exceeds "
                f"{lc.load_cv_warn:.0f}%",
                hint="try a different AccelConfig.alloc policy or more "
                     "CUs; imbalance converts directly into lnop stalls",
                cv=round(cv, 2), per_cu_edges=[int(e) for e in
                                               st.per_cu_edges]))

    # SPT202 — psum spill pressure
    from ..compiler.sched import PSUM_OVERFLOW_SLOTS

    # num_slots starts at psum_words + PSUM_OVERFLOW_SLOTS and only grows
    # past it when emergency parks demanded extra on-the-fly slots
    over = (prog.num_slots or 0) - (cfg.psum_words + PSUM_OVERFLOW_SLOTS)
    if st.dm_escapes > 0:
        diags.append(_diag(
            "SPT202", SEV_WARN,
            f"{st.dm_escapes} emergency psum park(s) escaped to the "
            f"overflow region",
            hint="raise AccelConfig.psum_words; each park round-trips a "
                 "partial sum through spill memory",
            dm_escapes=int(st.dm_escapes)))
    elif over > lc.spill_info_slots:
        diags.append(_diag(
            "SPT202", SEV_INFO,
            f"schedule grew {over} overflow slot(s) beyond the "
            f"{cfg.psum_words}-word psum register file and its "
            f"{PSUM_OVERFLOW_SLOTS} reserved overflow slots",
            hint="psum pressure is past capacity; heavier cuts of this "
                 "DAG may start parking",
            overflow_slots=int(over)))

    # SPT203 — stall-row density (dense cycles vs emitted rows)
    if st.cycles and st.emitted_cycles:
        stall = (st.cycles - st.emitted_cycles) / st.cycles
        if stall > lc.stall_warn:
            diags.append(_diag(
                "SPT203", SEV_WARN,
                f"{100 * stall:.1f}% of hardware cycles are all-NOP stall "
                f"rows (> {100 * lc.stall_warn:.0f}%)",
                hint="inspect stats.nop_breakdown(): bnop → more banks, "
                     "pnop → more psum words, dnop/lnop → DAG critical "
                     "path or assignment",
                stall_density=round(stall, 4)))

    # SPT204 — packed-plane fallback
    if prog.planes == 2:
        diags.append(_diag(
            "SPT204", SEV_INFO,
            "n exceeds the single-word src field; the 2-plane packed "
            "fallback doubles instruction-stream HBM traffic",
            planes=2))

    # SPT205 — blocked-placement feasibility
    if prog.row_lo is not None:
        from ...kernels.sptrsv.ops import plan_window

        plan = plan_window(prog, lc.cycles_per_block)
        if not plan.feasible:
            diags.append(_diag(
                "SPT205", SEV_WARN,
                f"row envelope admits no blocked placement window "
                f"({plan.reason}); large-n solves must keep the whole x "
                f"vector VMEM-resident",
                hint="hub-free orderings (e.g. RCM pre-permutation) "
                     "restore window feasibility",
                reason=plan.reason))

    # SPT206 — PE utilization
    if st.per_cu_edges is not None and st.cycles:
        util = st.utilization()
        if util < lc.util_warn:
            diags.append(_diag(
                "SPT206", SEV_WARN,
                f"PE utilization {100 * util:.1f}% is below "
                f"{100 * lc.util_warn:.0f}%",
                hint="DAG parallelism does not feed this many CUs; fewer "
                     "CUs or a wider matrix cut may run faster per area",
                utilization=round(util, 4)))

    # SPT207 — bank-conflict replay density
    total_lanes = st.cycles * cfg.num_cus
    if total_lanes and st.bnop / total_lanes > lc.conflict_warn:
        diags.append(_diag(
            "SPT207", SEV_WARN,
            f"bank-conflict replays occupy "
            f"{100 * st.bnop / total_lanes:.1f}% of issue slots "
            f"(> {100 * lc.conflict_warn:.0f}%)",
            hint="raise AccelConfig.num_banks or enable the ICR reorder "
                 "(cfg.icr) to color conflicting reads apart",
            bnop=int(st.bnop),
            density=round(st.bnop / total_lanes, 4)))

    # SPT208 — cycles left on the scheduling-strategy frontier
    costs = getattr(st, "schedule_costs", None)
    chosen = getattr(st, "schedule", "paper")
    if costs and chosen in costs:
        mine = costs[chosen]["cycles"]
        best = min(costs, key=lambda k: costs[k]["cycles"])
        best_cycles = costs[best]["cycles"]
        if best_cycles and mine > best_cycles * (1.0 + lc.frontier_warn):
            diags.append(_diag(
                "SPT208", SEV_WARN,
                f"strategy {chosen!r} predicts {mine} cycles but "
                f"{best!r} predicts {best_cycles} "
                f"({100 * (mine / best_cycles - 1):.1f}% over, "
                f"> {100 * lc.frontier_warn:.0f}%)",
                hint=f'recompile with schedule="{best}" (or '
                     f'schedule="auto" to pick per matrix)',
                schedule=chosen, best=best,
                predicted={k: int(v["cycles"]) for k, v in costs.items()}))
    return diags
