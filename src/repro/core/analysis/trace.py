"""Uniform cycle-trace view over `ScheduleIR`, `EmitIR`, and `Program`.

The hazard detector (`hazards.py`) works on decoded per-field planes; the
three artifacts that carry an instruction trace store them differently
(dense dataclass fields, elided dataclass fields, packed int32 words).
`TraceView` is the adapter: one frozen bundle of ``[T, P]`` field planes
plus the stream/metadata every check needs, tagged with the pipeline pass
(`origin`) a violation should blame.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..program import Program, decode_instructions

__all__ = ["TraceView", "view_schedule", "view_emit", "view_program"]


@dataclasses.dataclass(frozen=True)
class TraceView:
    """Decoded instruction trace + metadata, independent of its container."""

    origin: str            # pipeline pass blamed: "psum_schedule" |
                           # "stall_elide" | "program"
    name: str
    n: int
    op: np.ndarray         # [T, P] opcodes
    src: np.ndarray        # [T, P] solution-row index
    ctl: np.ndarray        # [T, P] psum control
    slot: np.ndarray       # [T, P] psum slot
    val_idx: np.ndarray    # [T, P] index into `stream`
    stream: np.ndarray     # [S]
    num_slots: int         # executor psum register-file size
    row_lo: np.ndarray | None = None   # [T] per-row envelopes (emitted only)
    row_hi: np.ndarray | None = None
    dense: bool = False    # True when stall rows are present (ScheduleIR)

    @property
    def cycles(self) -> int:
        return int(self.op.shape[0])

    @property
    def num_cus(self) -> int:
        return int(self.op.shape[1])


def view_schedule(sir) -> TraceView:
    """Dense `ScheduleIR` trace (stall rows included)."""
    return TraceView(
        origin="psum_schedule", name=sir.name, n=sir.n,
        op=np.asarray(sir.ops), src=np.asarray(sir.src),
        ctl=np.asarray(sir.ctl), slot=np.asarray(sir.slot),
        val_idx=np.asarray(sir.val_idx), stream=np.asarray(sir.stream),
        num_slots=sir.num_slots, dense=True,
    )


def view_emit(eir) -> TraceView:
    """Elided `EmitIR` trace (row envelopes attached)."""
    return TraceView(
        origin="stall_elide", name=eir.name, n=eir.n,
        op=np.asarray(eir.ops), src=np.asarray(eir.src),
        ctl=np.asarray(eir.ctl), slot=np.asarray(eir.slot),
        val_idx=np.asarray(eir.val_idx), stream=np.asarray(eir.stream),
        num_slots=eir.num_slots,
        row_lo=np.asarray(eir.row_lo), row_hi=np.asarray(eir.row_hi),
    )


def view_program(prog: Program) -> TraceView:
    """Packed `Program` decoded back into field planes.

    Assumes the packed structure already validated (`hazards.
    packed_structure`); the executor psum register-file size mirrors
    `executor._psum_slots` (config words + overflow, grown to what the
    compiler actually used).
    """
    from ..compiler.sched import PSUM_OVERFLOW_SLOTS

    op, src, ctl, slot = decode_instructions(prog.instr, prog.planes)
    nslots = max(prog.config.psum_words + PSUM_OVERFLOW_SLOTS,
                 prog.num_slots or 0)
    return TraceView(
        origin="program", name=prog.stats.name, n=prog.n,
        op=np.asarray(op), src=np.asarray(src), ctl=np.asarray(ctl),
        slot=np.asarray(slot), val_idx=np.asarray(prog.val_idx),
        stream=np.asarray(prog.stream), num_slots=nslots,
        row_lo=prog.row_lo, row_hi=prog.row_hi,
    )
