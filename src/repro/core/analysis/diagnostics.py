"""Diagnostic records and reports of the IR static analyzer (DESIGN.md §8).

Every finding of the static-analysis subsystem — a per-pass contract
violation, a schedule hazard, a performance pathology — is one
`Diagnostic`: a stable machine-readable code (``SPT1xx`` correctness,
``SPT2xx`` performance), a severity, the pipeline pass it blames, optional
cycle/CU/node anchors, the human-readable message, and a fix hint.  An
`AnalysisReport` aggregates the diagnostics of one analyzed artifact and
renders them as text or JSON (`scripts/lint_program.py` is the CLI over
both).

The code table is mirrored in DESIGN.md §8; codes are append-only — a
published code never changes meaning, so incident pipelines and tests can
key on them.
"""

from __future__ import annotations

import dataclasses
import json

__all__ = [
    "SEV_ERROR",
    "SEV_WARN",
    "SEV_INFO",
    "CODES",
    "Diagnostic",
    "AnalysisReport",
    "render_text",
]

SEV_ERROR = "error"   # correctness hazard: the artifact must not execute
SEV_WARN = "warn"     # performance pathology worth operator attention
SEV_INFO = "info"     # observation; no action required

# Stable diagnostic-code registry (append-only; table mirrored in
# DESIGN.md §8).  SPT1xx = correctness/hazard, SPT2xx = performance.
CODES: dict[str, str] = {
    # -- structural (packed Program tensors) --------------------------------
    "SPT101": "malformed instruction tensor (shape/dtype/planes)",
    "SPT102": "packed instruction field out of bit-width range",
    "SPT103": "invalid opcode or psum-control encoding",
    "SPT104": "NOP lane carries a non-zero instruction word",
    "SPT105": "active lane reads a solution row out of bounds",
    "SPT106": "value index outside the stream",
    "SPT107": "non-finite value in the stream plane",
    "SPT108": "FINAL lane carries a zero diagonal reciprocal",
    # -- schedule hazards / races ------------------------------------------
    "SPT110": "solution row not finalized exactly once",
    "SPT111": "RAW hazard: EDGE reads a row not yet finalized",
    "SPT112": "psum slot lifetime race (read-before-store / WAW overwrite)",
    "SPT113": "psum slot id beyond the register-file capacity",
    "SPT114": "row-envelope metadata inconsistent with instruction words",
    "SPT115": "bank pressure: distinct reads in one cycle exceed the banks",
    # -- cross-IR pass contracts -------------------------------------------
    "SPT116": "node executed on a CU other than its assigned owner",
    "SPT117": "schedule incomplete: edges/finals diverge from the DAG",
    "SPT118": "frontend contract violation (ComputeDag)",
    "SPT119": "partition contract violation (consumers/in-degree)",
    "SPT120": "assign contract violation (owner/task-list mismatch)",
    "SPT121": "emit contract violation (stall row survived / stale stats)",
    # -- performance lints --------------------------------------------------
    "SPT201": "CU load imbalance above threshold",
    "SPT202": "psum spill pressure (overflow slots / emergency parks)",
    "SPT203": "stall-row density above threshold",
    "SPT204": "two-plane instruction fallback doubles instruction traffic",
    "SPT205": "row envelope admits no blocked placement window",
    "SPT206": "PE utilization below threshold",
    "SPT207": "bank-conflict replay density above threshold",
    "SPT208": "scheduler strategy leaves cycles on the table vs the frontier",
    # -- serving / resilience incidents (DESIGN.md §10) ---------------------
    # `serve.SolveService.report()` renders every `robust.Incident` of the
    # serving layer through these codes, so breaker transitions, shed
    # events and degradations come out of the same machine-readable
    # Diagnostic JSON the static analyzer emits.
    "SPT301": "serving: backend execution failure during a flush",
    "SPT302": "serving: unhealthy solve output (non-finite / residual)",
    "SPT303": "serving: request deadline exceeded",
    "SPT304": "serving: circuit breaker state transition",
    "SPT305": "serving: request shed by admission control",
    "SPT306": "serving: program-cache disk tier rejected a corrupt blob",
    "SPT307": "serving: flush retried with backoff",
    "SPT308": "serving: stage exceeded the flush timeout (hang)",
    "SPT309": "serving: incident log saturated, oldest records dropped",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding of the static analyzer (see module docstring)."""

    code: str               # stable "SPTnnn" registry key
    severity: str           # SEV_ERROR | SEV_WARN | SEV_INFO
    message: str            # human-readable, self-contained
    pass_name: str = ""     # pipeline stage blamed (compiler.PASS_NAMES
                            # entry, "frontend", or "program")
    cycle: int | None = None    # anchor: instruction row / hardware cycle
    cu: int | None = None       # anchor: compute-unit lane
    node: int | None = None     # anchor: DAG node / solution row
    hint: str = ""              # suggested fix / next step
    detail: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unregistered diagnostic code {self.code!r}")
        if self.severity not in (SEV_ERROR, SEV_WARN, SEV_INFO):
            raise ValueError(f"unknown severity {self.severity!r}")

    @property
    def title(self) -> str:
        return CODES[self.code]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["title"] = self.title
        return d

    def anchor(self) -> str:
        """Compact ``cycle/cu/node`` location string ("-" when unanchored)."""
        parts = [f"cycle {self.cycle}" if self.cycle is not None else None,
                 f"cu {self.cu}" if self.cu is not None else None,
                 f"node {self.node}" if self.node is not None else None]
        parts = [p for p in parts if p]
        return ", ".join(parts) if parts else "-"


@dataclasses.dataclass
class AnalysisReport:
    """All diagnostics of one analyzed artifact, plus context metadata.

    ``meta`` carries whatever the analysis entry point knows about the
    artifact (name, n, cycles, pass analyzed, thresholds used) so a JSON
    report is self-describing.
    """

    name: str
    diagnostics: list[Diagnostic] = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    # -- selectors ---------------------------------------------------------
    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == SEV_ERROR]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == SEV_WARN]

    @property
    def infos(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == SEV_INFO]

    def ok(self) -> bool:
        """True when no error-severity diagnostic was found."""
        return not self.errors

    def codes(self) -> set[str]:
        return {d.code for d in self.diagnostics}

    def by_code(self) -> dict[str, list[Diagnostic]]:
        out: dict[str, list[Diagnostic]] = {}
        for d in self.diagnostics:
            out.setdefault(d.code, []).append(d)
        return out

    def extend(self, diags) -> "AnalysisReport":
        self.diagnostics.extend(diags)
        return self

    # -- renderers ---------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "meta": dict(self.meta),
            "ok": self.ok(),
            "counts": {"error": len(self.errors),
                       "warn": len(self.warnings),
                       "info": len(self.infos)},
            "diagnostics": [d.to_dict() for d in self.diagnostics],
        }

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def render(self) -> str:
        return render_text(self)


def render_text(report: AnalysisReport) -> str:
    """Human-readable multi-line rendering of a report."""
    lines = [f"analysis: {report.name} — "
             f"{len(report.errors)} error(s), "
             f"{len(report.warnings)} warning(s), "
             f"{len(report.infos)} info(s)"]
    for k, v in sorted(report.meta.items()):
        lines.append(f"  {k}: {v}")
    for d in report.diagnostics:
        where = f" [{d.pass_name}]" if d.pass_name else ""
        lines.append(f"{d.code} {d.severity}{where} ({d.anchor()}): "
                     f"{d.message}")
        if d.hint:
            lines.append(f"    hint: {d.hint}")
    if not report.diagnostics:
        lines.append("  clean — no diagnostics")
    return "\n".join(lines)
