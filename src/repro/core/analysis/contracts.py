"""Per-pass contract verifiers of the staged compiler pipeline (DESIGN.md §8).

One verifier per IR the pipeline produces::

    frontend      verify_frontend(dag)            ComputeDag contract
    partition     verify_partition(pir)           consumer adjacency
    cu_assign     verify_assign(air, cfg)         owner/task-list coherence
    psum_schedule verify_schedule(sir, air, cfg)  hazards + completeness
    stall_elide   verify_emit(eir, sir)           elision + envelopes
    pack_emit     verify_packed_program(prog, eir, cfg)  packed roundtrip

Each returns a list of `Diagnostic`s whose ``pass_name`` blames the stage
that broke the invariant — the point of per-pass verification: a violation
found *after* packing (`core.robust.verify_program`) can only say the
program is corrupt, a violation found here says which pass corrupted it.
`compile_dag(verify_ir=True)` (`core/compiler`) runs these after every
stage and raises `IRValidationError` on the first error.

Cross-IR checks (``air``/``sir``/``eir`` context arguments) are optional:
a verifier called with only its own IR still enforces every invariant
derivable from that IR alone, so the verifiers also work on IRs produced
by third-party scheduler passes.
"""

from __future__ import annotations

import numpy as np

from ..errors import IRValidationError
from ..program import OP_EDGE, OP_FINAL, OP_NOP
from .diagnostics import SEV_ERROR, Diagnostic
from .hazards import envelope_diags, packed_structure, trace_hazards
from .trace import view_emit, view_program, view_schedule

__all__ = [
    "verify_frontend",
    "verify_partition",
    "verify_assign",
    "verify_schedule",
    "verify_emit",
    "verify_packed_program",
    "raise_on_errors",
]


def _err(code, message, pass_name, *, cycle=None, cu=None, node=None,
         hint="", **detail):
    return Diagnostic(code=code, severity=SEV_ERROR, message=message,
                      pass_name=pass_name, cycle=cycle, cu=cu, node=node,
                      hint=hint, detail=detail)


def raise_on_errors(diags, stage: str, name: str) -> None:
    """Raise `IRValidationError` naming ``stage`` on the first error."""
    errs = [d for d in diags if d.severity == SEV_ERROR]
    if errs:
        d = errs[0]
        raise IRValidationError(
            f"IR contract violated after pass {stage!r} compiling "
            f"{name!r}: [{d.code}] {d.message}",
            detail={"pass": stage, "code": d.code, "name": name,
                    "diagnostics": [e.to_dict() for e in errs]})


# ---------------------------------------------------------------------------
# frontend: ComputeDag
# ---------------------------------------------------------------------------
def verify_frontend(dag) -> list[Diagnostic]:
    """The `ComputeDag` frontend contract, as diagnostics (SPT118)."""
    try:
        dag.validate()
    except ValueError as e:
        return [_err("SPT118", str(e), "frontend",
                     hint="fix the workload lowering in core/frontends/")]
    return []


# ---------------------------------------------------------------------------
# partition: PartitionIR
# ---------------------------------------------------------------------------
def verify_partition(pir) -> list[Diagnostic]:
    """Consumer adjacency and in-degrees must mirror the DAG exactly."""
    diags: list[Diagnostic] = []
    dag = pir.dag
    n = dag.n
    if len(pir.consumers) != n:
        diags.append(_err("SPT119", f"consumers has {len(pir.consumers)} "
                          f"entries for {n} nodes", "partition"))
        return diags
    if not np.array_equal(np.asarray(pir.in_degree), np.diff(dag.ptr)):
        j = int(np.argmax(np.asarray(pir.in_degree) != np.diff(dag.ptr)))
        diags.append(_err("SPT119", f"in_degree[{j}] diverges from the "
                          f"DAG's edge slices", "partition", node=j))
    # edge multiset: (consumer i, source j) from the adjacency vs the DAG
    cons_i = np.fromiter((i for j in range(n) for i in pir.consumers[j]),
                         dtype=np.int64)
    cons_j = np.repeat(np.arange(n),
                       [len(pir.consumers[j]) for j in range(n)])
    owner_row = np.repeat(np.arange(n), np.diff(dag.ptr))
    a = np.lexsort((cons_j, cons_i))
    b = np.lexsort((dag.src, owner_row))
    if (cons_i.size != dag.n_edges
            or not np.array_equal(cons_i[a], owner_row[b])
            or not np.array_equal(cons_j[a], dag.src[b])):
        diags.append(_err("SPT119", f"consumer adjacency carries "
                          f"{cons_i.size} edges but the DAG has "
                          f"{dag.n_edges}; the scheduler would wake the "
                          f"wrong nodes", "partition",
                          hint="partition pass dropped or invented an "
                               "edge"))
    return diags


# ---------------------------------------------------------------------------
# cu_assign: AssignIR
# ---------------------------------------------------------------------------
def verify_assign(air, cfg=None) -> list[Diagnostic]:
    """Task lists must partition the nodes; owner must agree with them."""
    diags: list[Diagnostic] = []
    n = air.part.dag.n
    flat = np.fromiter((i for ts in air.task_lists for i in ts),
                       dtype=np.int64, count=sum(map(len, air.task_lists)))
    if not np.array_equal(np.sort(flat), np.arange(n)):
        diags.append(_err("SPT120", f"task lists do not partition the "
                          f"{n} nodes (cover {flat.size} entries)",
                          "cu_assign"))
        return diags
    owner = np.asarray(air.owner)
    for c, ts in enumerate(air.task_lists):
        ta = np.asarray(ts, dtype=np.int64)
        if ta.size and np.any(np.diff(ta) <= 0):
            diags.append(_err("SPT120", f"cu {c} task list is not in "
                              f"ascending (topological) order", "cu_assign",
                              cu=c))
            break
    bad = np.flatnonzero(owner[flat] !=
                         np.repeat(np.arange(len(air.task_lists)),
                                   [len(ts) for ts in air.task_lists]))
    if bad.size:
        i = int(flat[bad[0]])
        diags.append(_err("SPT120", f"owner[{i}] disagrees with the task "
                          f"list that carries node {i}", "cu_assign",
                          node=i))
    if cfg is not None and len(air.task_lists) != cfg.num_cus:
        diags.append(_err("SPT120", f"{len(air.task_lists)} task lists for "
                          f"{cfg.num_cus} CUs", "cu_assign"))
    return diags


# ---------------------------------------------------------------------------
# psum_schedule: ScheduleIR (dense trace)
# ---------------------------------------------------------------------------
def verify_schedule(sir, air=None, cfg=None) -> list[Diagnostic]:
    """Hazard-freedom plus (with ``air``) completeness against the DAG."""
    diags: list[Diagnostic] = []
    shapes = {sir.ops.shape, sir.val_idx.shape, sir.src.shape,
              sir.ctl.shape, sir.slot.shape}
    if len(shapes) != 1 or sir.ops.ndim != 2:
        diags.append(_err("SPT101", f"trace planes disagree on shape: "
                          f"{sorted(map(str, shapes))}", "psum_schedule"))
        return diags

    nop = sir.ops == OP_NOP
    dirty = nop & ((sir.src != 0) | (sir.ctl != 0) | (sir.slot != 0)
                   | (sir.val_idx != 0))
    if dirty.any():
        tt, pp = np.argwhere(dirty)[0]
        diags.append(_err("SPT104", f"NOP lane carries a non-zero field at "
                          f"cycle {tt}, cu {pp}", "psum_schedule",
                          cycle=int(tt), cu=int(pp)))

    # the schedule pass appends one stream value per executed lane, in
    # execution order: active val_idx must be exactly 0..S-1, row-major
    active = ~nop
    vi = sir.val_idx[active]
    if vi.size != sir.stream.size or \
            not np.array_equal(np.sort(vi), np.arange(sir.stream.size)):
        diags.append(_err("SPT117", f"stream has {sir.stream.size} values "
                          f"for {vi.size} executed lanes (val_idx must "
                          f"enumerate the stream exactly once)",
                          "psum_schedule"))

    diags += trace_hazards(view_schedule(sir), cfg,
                           check_values=vi.size == sir.stream.size)

    if air is not None:
        diags += _schedule_completeness(sir, air)
    return diags


def _schedule_completeness(sir, air) -> list[Diagnostic]:
    """Cross-IR: the trace must execute the DAG, whole and on-owner."""
    diags: list[Diagnostic] = []
    dag = air.part.dag
    owner = np.asarray(air.owner)
    # flat integer gathers: ~10x cheaper than boolean-mask fancy indexing
    # over the [T, P] planes, and the lane id falls out of the flat index
    ncu = sir.ops.shape[1]
    ops_flat = np.asarray(sir.ops).ravel()
    src_flat = np.asarray(sir.src).ravel()
    vi_flat = np.asarray(sir.val_idx).ravel()

    # FINAL lanes: node i finalized on its owning CU with scale[i] streamed
    f_idx = np.flatnonzero(ops_flat == OP_FINAL)
    fin_node = src_flat[f_idx]
    fin_cu = f_idx % ncu
    in_range = (fin_node >= 0) & (fin_node < dag.n)
    if in_range.all() and fin_node.size == dag.n:
        off = np.flatnonzero(owner[fin_node] != fin_cu)
        if off.size:
            i = int(fin_node[off[0]])
            diags.append(_err("SPT116", f"node {i} finalized on cu "
                              f"{int(fin_cu[off[0]])} but assigned to cu "
                              f"{int(owner[i])}", "psum_schedule", node=i,
                              cu=int(fin_cu[off[0]])))
        vals = sir.stream[vi_flat[f_idx]]
        want = np.asarray(dag.scale)[fin_node]
        if not np.array_equal(vals, want):
            i = int(fin_node[np.argmax(vals != want)])
            diags.append(_err("SPT117", f"FINAL of node {i} streams a "
                              f"value that is not its scale",
                              "psum_schedule", node=i))

    # EDGE lanes: multiset of (owner cu, source, weight) must equal the DAG's
    e_idx = np.flatnonzero(ops_flat == OP_EDGE)
    e_cu = e_idx % ncu
    e_src = src_flat[e_idx]
    e_val = sir.stream[vi_flat[e_idx]]
    owner_row = np.repeat(np.arange(dag.n), np.diff(dag.ptr))
    d_cu = owner[owner_row]
    d_src = np.asarray(dag.src)
    d_val = np.asarray(dag.weight)
    if e_cu.size != d_cu.size:
        diags.append(_err("SPT117", f"trace executes {e_cu.size} edges but "
                          f"the DAG has {d_cu.size}", "psum_schedule",
                          hint="an edge was dropped or duplicated"))
        return diags
    # (cu, src) packs into one integer key: a stable argsort over it is
    # several times cheaper than a 3-key lexsort with a float plane, and
    # on a well-formed schedule a CU executes its nodes in task-list
    # order, so the within-key value order already matches the DAG's —
    # the value lexsort below only runs when that fast comparison fails.
    key_e = e_cu.astype(np.int64) * np.int64(dag.n) + e_src
    key_d = d_cu.astype(np.int64) * np.int64(dag.n) + d_src
    a = np.argsort(key_e, kind="stable")
    b = np.argsort(key_d, kind="stable")
    ke, kd = key_e[a], key_d[b]
    if not np.array_equal(ke, kd):
        k = int(np.argmax(ke != kd))
        diags.append(_err("SPT117", f"edge multiset diverges from the DAG "
                          f"(first at source row {int(e_src[a[k]])} on cu "
                          f"{int(e_cu[a[k]])})", "psum_schedule",
                          node=int(e_src[a[k]]), cu=int(e_cu[a[k]])))
        return diags
    ve, vd = e_val[a], d_val[b]
    if not np.array_equal(ve, vd):
        # weights inside a duplicated (cu, src) group may legally arrive
        # in a different order (the ICR reorder permutes rows within a
        # CU); canonicalize those groups by value — they are a small
        # fraction of the edges, so the value sort stays cheap
        dup = np.empty(ke.size, dtype=bool)
        dup[0] = False
        dup[1:] = ke[1:] == ke[:-1]
        grp = dup | np.append(dup[1:], False)
        bad = (ve != vd) & ~grp
        if not bad.any():
            sub = np.flatnonzero(grp)
            ks = ke[sub]
            ves = ve[sub][np.lexsort((ve[sub], ks))]
            vds = vd[sub][np.lexsort((vd[sub], ks))]
            if np.array_equal(ves, vds):
                return diags
            k = int(sub[np.argmax(ves != vds)])
        else:
            k = int(np.argmax(bad))
        diags.append(_err("SPT117", f"edge multiset diverges from the "
                          f"DAG (first at source row {int(ke[k] % dag.n)}"
                          f" on cu {int(ke[k] // dag.n)})",
                          "psum_schedule", node=int(ke[k] % dag.n),
                          cu=int(ke[k] // dag.n)))
    return diags


# ---------------------------------------------------------------------------
# stall_elide: EmitIR
# ---------------------------------------------------------------------------
def verify_emit(eir, sir=None) -> list[Diagnostic]:
    """No stall row may survive; envelopes and stats must re-derive."""
    diags: list[Diagnostic] = []
    nop_rows = ~(eir.ops != OP_NOP).any(axis=1)
    if nop_rows.any():
        tt = int(np.argmax(nop_rows))
        diags.append(_err("SPT121", f"all-NOP stall row survived elision "
                          f"at emitted cycle {tt}", "stall_elide",
                          cycle=tt,
                          hint="streaming it is pure instruction traffic"))
    if eir.stats.emitted_cycles != eir.ops.shape[0]:
        diags.append(_err("SPT121", f"stats.emitted_cycles="
                          f"{eir.stats.emitted_cycles} but "
                          f"{eir.ops.shape[0]} rows were emitted",
                          "stall_elide"))
    if eir.row_lo is None or eir.row_hi is None or \
            eir.row_lo.shape != (eir.ops.shape[0],) or \
            eir.row_hi.shape != (eir.ops.shape[0],):
        diags.append(_err("SPT121", "row envelopes missing or mis-shaped",
                          "stall_elide"))
        return diags
    same = False
    if sir is not None:
        keep = (sir.ops != OP_NOP).any(axis=1)
        same = (np.array_equal(sir.ops[keep], eir.ops)
                and np.array_equal(sir.src[keep], eir.src)
                and np.array_equal(sir.ctl[keep], eir.ctl)
                and np.array_equal(sir.slot[keep], eir.slot)
                and np.array_equal(sir.val_idx[keep], eir.val_idx)
                and np.array_equal(sir.stream, eir.stream))
        if not same:
            diags.append(_err("SPT121", "emitted rows are not the dense "
                              "trace's active rows in order", "stall_elide"))
    if same and eir.num_slots == sir.num_slots:
        # the emitted planes ARE the verified dense trace's active rows:
        # every hazard check is order-relative, and dropping all-NOP rows
        # preserves order, so only the field elision *adds* — the row
        # envelopes — needs checking
        diags += envelope_diags(view_emit(eir))
    else:
        diags += trace_hazards(view_emit(eir))
    return diags


# ---------------------------------------------------------------------------
# pack_emit: packed Program
# ---------------------------------------------------------------------------
def verify_packed_program(prog, eir=None, cfg=None) -> list[Diagnostic]:
    """Packed structure + hazards; with ``eir``, the pack must roundtrip."""
    diags, decodable, values_ok = packed_structure(prog)
    if not decodable:
        return _blame(diags, "pack_emit")
    v = view_program(prog)
    roundtrip_ok = False
    if eir is not None:
        same = (np.array_equal(v.op, eir.ops)
                and np.array_equal(v.src, eir.src)
                and np.array_equal(v.ctl, eir.ctl)
                and np.array_equal(v.slot, eir.slot)
                and np.array_equal(np.asarray(prog.val_idx), eir.val_idx))
        if not same:
            diags.append(_err("SPT102", "packed words do not decode back "
                              "to the emitted field planes", "pack_emit"))
        stream_ok = np.allclose(np.asarray(prog.stream, dtype=np.float64),
                                eir.stream.astype(np.float32)
                                .astype(np.float64))
        if not stream_ok:
            diags.append(_err("SPT117", "value stream diverged from the "
                              "emitted schedule's stream", "pack_emit"))
        roundtrip_ok = (
            same and stream_ok and values_ok
            and v.num_slots == eir.num_slots
            and v.row_lo is not None and v.row_hi is not None
            and np.array_equal(np.asarray(v.row_lo),
                               np.asarray(eir.row_lo))
            and np.array_equal(np.asarray(v.row_hi),
                               np.asarray(eir.row_hi)))
    if not roundtrip_ok:
        # standalone program (no eir) or an imperfect roundtrip: run the
        # full hazard detector over the decoded planes.  When the decode
        # matches the already-verified EmitIR field-for-field (envelopes
        # and stream included), the detector would only re-prove what
        # `verify_emit` just proved on identical arrays — skip it.
        diags += trace_hazards(v, cfg if cfg is not None else prog.config,
                               check_values=values_ok)
    return _blame(diags, "pack_emit")


def _blame(diags: list[Diagnostic], stage: str) -> list[Diagnostic]:
    """Rewrite generic ``program`` blame onto a concrete pipeline stage."""
    import dataclasses

    return [dataclasses.replace(d, pass_name=stage)
            if d.pass_name in ("", "program") else d for d in diags]
