"""Static analysis over the compiler IRs and packed programs (DESIGN.md §8).

Three layers, one diagnostic vocabulary (`diagnostics.CODES`):

1. **Per-pass contract verifiers** (`contracts.py`) — one verifier per
   pipeline IR; `compile_dag(verify_ir=True)` runs them after every stage
   and raises `IRValidationError` naming the guilty pass.
2. **Schedule hazard/race detector** (`hazards.py` over `trace.py`
   views) — RAW hazards, psum-slot lifetime races, FINAL multiplicity,
   bank pressure, envelope consistency; the single implementation
   `core.robust.verify_program` now wraps.
3. **Performance linter** (`perf.py`) — SPT2xx warn/info lints over
   schedule statistics and row envelopes.

`analyze_program` is the everything entry point (structure + hazards +
lints → `AnalysisReport`); `scripts/lint_program.py` is the CLI.
"""

from __future__ import annotations

from .contracts import (
    raise_on_errors,
    verify_assign,
    verify_emit,
    verify_frontend,
    verify_packed_program,
    verify_partition,
    verify_schedule,
)
from .diagnostics import (
    CODES,
    SEV_ERROR,
    SEV_INFO,
    SEV_WARN,
    AnalysisReport,
    Diagnostic,
    render_text,
)
from .hazards import packed_structure, trace_hazards
from .perf import LintConfig, lint_program
from .trace import TraceView, view_emit, view_program, view_schedule

__all__ = [
    "CODES",
    "SEV_ERROR",
    "SEV_WARN",
    "SEV_INFO",
    "Diagnostic",
    "AnalysisReport",
    "render_text",
    "TraceView",
    "view_schedule",
    "view_emit",
    "view_program",
    "packed_structure",
    "trace_hazards",
    "LintConfig",
    "lint_program",
    "verify_frontend",
    "verify_partition",
    "verify_assign",
    "verify_schedule",
    "verify_emit",
    "verify_packed_program",
    "raise_on_errors",
    "program_diagnostics",
    "analyze_program",
    "analyze_schedule",
]


def program_diagnostics(prog, cfg=None):
    """Correctness diagnostics of a packed `Program` (no perf lints).

    Structure first; hazards only when the words decode.  This is the
    exact check set `core.robust.verify_program` raises on, in the same
    order, as a list instead of a raise.
    """
    diags, decodable, values_ok = packed_structure(prog)
    if decodable:
        diags += trace_hazards(view_program(prog),
                               cfg if cfg is not None else prog.config,
                               check_values=values_ok)
    return diags


def analyze_program(prog, *, lint: bool = True,
                    lint_cfg: LintConfig | None = None) -> AnalysisReport:
    """Full static analysis of a packed `Program` → `AnalysisReport`."""
    report = AnalysisReport(
        name=prog.stats.name,
        meta={"n": prog.n, "cycles": prog.cycles, "planes": prog.planes,
              "num_cus": prog.config.num_cus, "artifact": "program"})
    report.extend(program_diagnostics(prog))
    if lint:
        report.extend(lint_program(prog, lint_cfg))
    return report


def analyze_schedule(sir, air=None, cfg=None) -> AnalysisReport:
    """Static analysis of a dense `ScheduleIR` → `AnalysisReport`."""
    report = AnalysisReport(
        name=sir.name,
        meta={"n": sir.n, "cycles": int(sir.ops.shape[0]),
              "artifact": "schedule"})
    return report.extend(verify_schedule(sir, air, cfg))
