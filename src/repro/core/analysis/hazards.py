"""Schedule hazard/race detector — the single implementation (DESIGN.md §8).

Every check the executors' correctness rests on, run statically over a
`TraceView` (dense `ScheduleIR`, elided `EmitIR`, or packed `Program` —
`trace.py` adapts all three):

  * SPT105 — an active lane reads a solution row ``>= n``;
  * SPT113 — a slot-using lane addresses beyond the psum register file;
  * SPT110 — a solution row finalized zero or multiple times;
  * SPT111 — RAW hazard: an EDGE reads ``x[src]`` in a cycle not strictly
    after the FINAL that writes it;
  * SPT108 — a FINAL lane streams a zero diagonal reciprocal;
  * SPT112 — psum slot lifetime races per CU: a LOAD/SWAP reading a slot
    no earlier STORE/SWAP filled (use-before-def), and a STORE_RESET
    overwriting a slot still live (WAW);
  * SPT114 — ``row_lo/row_hi`` envelope metadata that does not re-derive
    from the instruction words it summarizes;
  * SPT115 — more distinct x-reads in one cycle than the banked
    interconnect has banks (requires an `AccelConfig`).

`packed_structure` validates what must hold before a packed `Program` can
even be decoded (tensor shapes, field bit-widths, encodings, zero NOP
words, stream/val_idx sanity).  `core.robust.verify_program` is a thin
wrapper over these two functions — diagnostic messages are the historical
`ProgramCorruptionError` messages verbatim, so callers that match on them
keep working.
"""

from __future__ import annotations

import numpy as np

from ..program import (
    OP_EDGE,
    OP_FINAL,
    OP_NOP,
    PS_LOAD,
    PS_STORE_RESET,
    PS_SWAP,
    Program,
    decode_instructions,
    validate_fields,
)
from .diagnostics import SEV_ERROR, Diagnostic
from .trace import TraceView

__all__ = ["packed_structure", "trace_hazards", "envelope_diags"]


def _err(code: str, message: str, *, pass_name: str = "program",
         cycle=None, cu=None, node=None, hint: str = "", **detail):
    return Diagnostic(code=code, severity=SEV_ERROR, message=message,
                      pass_name=pass_name, cycle=cycle, cu=cu, node=node,
                      hint=hint, detail=detail)


# ---------------------------------------------------------------------------
# packed-tensor structure (Program only)
# ---------------------------------------------------------------------------
def packed_structure(prog: Program):
    """Validate the packed tensors of a `Program` ahead of decoding.

    Returns ``(diagnostics, decodable, values_ok)``: ``decodable`` is False
    when the instruction words cannot be trusted enough to run the hazard
    detector over them; ``values_ok`` is False when value-dependent checks
    (the zero-reciprocal scan) must be skipped because ``val_idx`` points
    outside the stream.
    """
    diags: list[Diagnostic] = []
    instr = np.asarray(prog.instr)
    if instr.ndim != 3 or instr.dtype != np.int32:
        diags.append(_err("SPT101", f"instr must be [T, planes, P] int32, "
                          f"got {instr.shape} {instr.dtype}",
                          hint="recompile; do not execute"))
        return diags, False, False
    t, planes, p = instr.shape
    if planes not in (1, 2):
        diags.append(_err("SPT101", f"planes must be 1 or 2, got {planes}"))
        return diags, False, False
    vidx = np.asarray(prog.val_idx)
    if vidx.shape != (t, p):
        diags.append(_err("SPT101", f"val_idx shape {vidx.shape} != instr "
                          f"rows {(t, p)}"))
        return diags, False, False
    stream = np.asarray(prog.stream)
    if stream.ndim != 1:
        diags.append(_err("SPT101", f"stream must be 1-D, got shape "
                          f"{stream.shape}"))
        return diags, False, False

    values_ok = True
    if not np.isfinite(stream).all():
        bad = int(np.count_nonzero(~np.isfinite(stream)))
        diags.append(_err("SPT107", f"stream carries {bad} non-finite "
                          f"value(s)", non_finite=bad,
                          hint="value plane corrupt: re-fetch or recompile"))
    if vidx.size and (vidx.min() < 0 or vidx.max() >= stream.size):
        diags.append(_err("SPT106", f"val_idx out of stream bounds "
                          f"[0, {stream.size})",
                          lo=int(vidx.min()), hi=int(vidx.max())))
        values_ok = False

    op, src, ctl, slot = decode_instructions(instr, planes)
    try:
        validate_fields(op, src, ctl, slot, planes)
    except ValueError as e:
        diags.append(_err("SPT102", f"packed field range: {e}"))
        return diags, False, values_ok
    if int(op.max(initial=0)) > OP_FINAL:
        diags.append(_err("SPT103", f"invalid opcode {int(op.max())} "
                          f"(beyond OP_FINAL)"))
        return diags, False, values_ok
    if int(ctl.max(initial=0)) > PS_SWAP:
        diags.append(_err("SPT103", f"invalid psum control {int(ctl.max())} "
                          f"(beyond PS_SWAP)"))
        return diags, False, values_ok

    # NOP lanes are all-zero words by construction (pad rows, elided
    # lanes); a non-zero NOP word means bits were flipped into fields the
    # executor still applies (the psum control runs on every lane).
    nop_nonzero = (op == OP_NOP) & (instr != 0).any(axis=1)
    if nop_nonzero.any():
        tt, pp = np.argwhere(nop_nonzero)[0]
        diags.append(_err("SPT104", f"NOP lane carries a non-zero word at "
                          f"cycle {tt}, cu {pp}",
                          cycle=int(tt), cu=int(pp)))
    return diags, True, values_ok


# ---------------------------------------------------------------------------
# hazard detector (any TraceView)
# ---------------------------------------------------------------------------
def trace_hazards(v: TraceView, cfg=None, *,
                  check_values: bool = True) -> list[Diagnostic]:
    """Run every schedule hazard check over ``v``; returns diagnostics.

    Checks run in the canonical order (module docstring) and each reports
    its first instance with a count in ``detail`` — `robust.verify_program`
    raises the first error, the linter shows them all.  ``cfg`` (an
    `AccelConfig`) enables the bank-pressure check; ``check_values=False``
    skips the stream-value scan (caller already reported bad indices).
    """
    diags: list[Diagnostic] = []
    blame = dict(pass_name=v.origin)
    op, src, ctl, slot = v.op, v.src, v.ctl, v.slot
    t, p = op.shape
    n = v.n
    active = op != OP_NOP

    # SPT105 — solution-row bounds
    src_ok = True
    if active.any() and int(src[active].max()) >= n:
        src_ok = False
        diags.append(_err("SPT105", f"active lane reads row >= n={n}",
                          row=int(src[active].max()), **blame))

    # SPT113 — psum register-file capacity
    uses_slot = (ctl == PS_LOAD) | (ctl == PS_STORE_RESET) | (ctl == PS_SWAP)
    if uses_slot.any() and int(slot[uses_slot].max()) >= v.num_slots:
        diags.append(_err("SPT113", f"psum slot "
                          f"{int(slot[uses_slot].max())} >= register file "
                          f"size {v.num_slots}", num_slots=v.num_slots,
                          hint="raise AccelConfig.psum_words or split "
                               "heavy nodes", **blame))

    # SPT110 — every solution row finalized exactly once
    is_final = op == OP_FINAL
    finals = src[is_final]
    hi = max(n, (int(finals.max()) + 1) if finals.size else n)
    counts = np.bincount(finals, minlength=hi) if finals.size else \
        np.zeros(hi, dtype=np.int64)
    if finals.size != n or (counts[:n] != 1).any():
        row = int(np.argmax(counts[:n] != 1))
        diags.append(_err("SPT110", f"row {row} finalized "
                          f"{int(counts[row])} times (every row must be "
                          f"finalized exactly once)", node=row, row=row,
                          **blame))

    # SPT111 — RAW hazard: EDGE at cycle t reads x[src] => src FINAL'd at
    # some cycle < t
    cyc = np.broadcast_to(np.arange(t)[:, None], (t, p))
    final_cycle = np.full(hi, t, dtype=np.int64)
    final_cycle[finals] = cyc[is_final]
    edges = op == OP_EDGE
    if edges.any():
        viol = final_cycle[src[edges]] >= cyc[edges]
        if viol.any():
            k = int(np.argmax(viol))
            row = int(src[edges][k])
            diags.append(_err(
                "SPT111",
                f"dependency order: an EDGE reads x[{row}] at cycle "
                f"{int(cyc[edges][k])} but row {row} is finalized at cycle "
                f"{int(final_cycle[row])}",
                cycle=int(cyc[edges][k]), node=row, row=row,
                count=int(viol.sum()), **blame))

    # SPT108 — FINAL stream values are diagonal reciprocals; zero divides out
    if check_values and is_final.any():
        vi = v.val_idx[is_final]
        if vi.size == 0 or (vi.min() >= 0 and vi.max() < v.stream.size):
            fvals = v.stream[vi]
            if (fvals == 0).any():
                diags.append(_err("SPT108", "FINAL lane carries a zero "
                                  "diagonal reciprocal",
                                  count=int((fvals == 0).sum()), **blame))

    # SPT112 — psum slot lifetimes, per CU: LOAD/SWAP read a live slot;
    # STORE/SWAP fill it; LOAD consumes it; STORE over a live slot is a
    # WAW race.  Vectorized liveness replay over the sparse psum events
    # (per-(cu, slot) prefix sums); the python event loop only runs to
    # attribute violations once the fast path found one.
    ev_t, ev_p = np.nonzero(ctl)
    if ev_t.size and _psum_lifetime_broken(ctl, slot, ev_t, ev_p):
        diags += _psum_lifetime_diags(ctl, slot, ev_t, ev_p, blame)

    # SPT114 — row-envelope metadata re-derived from the words it summarizes
    if src_ok:
        diags += envelope_diags(v, blame)

    # SPT115 — banked-read pressure: every distinct x-read address in a
    # cycle needs its own bank; more distinct reads than banks cannot issue
    if cfg is not None and edges.any():
        read = np.where(edges, src, -1)
        read.sort(axis=1)
        distinct = (np.diff(read, axis=1) > 0).sum(axis=1) + (read[:, -1] >= 0)
        over = distinct > cfg.num_banks
        if over.any():
            tt = int(np.argmax(over))
            diags.append(_err("SPT115", f"cycle {tt} reads "
                              f"{int(distinct[tt])} distinct x rows but the "
                              f"interconnect has {cfg.num_banks} banks",
                              cycle=tt, count=int(over.sum()),
                              hint="the ICR/bank model cannot issue this "
                                   "row; reschedule", **blame))
    return diags


def envelope_diags(v: TraceView, blame: dict | None = None) -> list:
    """SPT114 — ``row_lo/row_hi`` must re-derive from the instruction words.

    Split out of `trace_hazards` so the per-pass verifiers can run just
    this check on a trace whose planes are already proven identical to a
    verified upstream IR (the envelope metadata is the only field such a
    trace adds).  Callers must have established ``src < n`` first.
    """
    if v.row_lo is None or v.row_hi is None:
        return []
    blame = blame if blame is not None else dict(pass_name=v.origin)
    active = v.op != OP_NOP
    lo = np.where(active, v.src, v.n).min(axis=1).astype(np.int32)
    hi_env = np.where(active, v.src, -1).max(axis=1).astype(np.int32)
    if np.array_equal(lo, v.row_lo) and np.array_equal(hi_env, v.row_hi):
        return []
    bad = int(np.argmax((lo != v.row_lo) | (hi_env != v.row_hi)))
    return [_err("SPT114", f"row-envelope metadata inconsistent with the "
                 f"instruction words at cycle {bad}", cycle=bad,
                 hint="window planning would misplace the VMEM window; "
                      "recompile", **blame)]


def _psum_lifetime_broken(ctl, slot, ev_t, ev_p) -> bool:
    """Vectorized liveness replay; True when any SPT112 race exists.

    Events are grouped by (cu, slot) in time order; ``delta`` (+1 STORE,
    -1 LOAD, 0 SWAP/RESET) prefix-summed within each group gives the
    post-event liveness, and every op pins what that liveness must be:
    a STORE must land on a free slot (post == 1), a LOAD must consume a
    live one (post == 0), a SWAP must read-and-refill a live one
    (post == 1).  RESET never touches the slot.
    """
    ev_c = ctl[ev_t, ev_p]
    ev_s = slot[ev_t, ev_p].astype(np.int64)
    order = np.lexsort((ev_t, ev_s, ev_p))  # (cu, slot) groups, time asc
    c = ev_c[order]
    key = ev_p[order].astype(np.int64) * (int(ev_s.max()) + 1) + ev_s[order]
    new_grp = np.empty(len(order), dtype=bool)
    new_grp[0] = True
    new_grp[1:] = key[1:] != key[:-1]
    delta = np.where(c == PS_STORE_RESET, 1,
                     np.where(c == PS_LOAD, -1, 0))
    cs = np.cumsum(delta)
    start = np.maximum.accumulate(np.where(new_grp, np.arange(len(order)), 0))
    post = cs - (cs - delta)[start]  # liveness after each event, per group
    viol = (((c == PS_STORE_RESET) & (post != 1))
            | ((c == PS_LOAD) & (post != 0))
            | ((c == PS_SWAP) & (post != 1)))
    return bool(viol.any())


def _psum_lifetime_diags(ctl, slot, ev_t, ev_p, blame) -> list:
    """Exact event replay attributing SPT112 races (legacy report order:
    per CU in cycle order, first instance of each race reported)."""
    diags = []
    order = np.lexsort((ev_t, ev_p))
    live: set[tuple[int, int]] = set()
    for k in order:
        c = int(ctl[ev_t[k], ev_p[k]])
        s = int(slot[ev_t[k], ev_p[k]])
        pp, tt = int(ev_p[k]), int(ev_t[k])
        key = (pp, s)
        if c in (PS_LOAD, PS_SWAP) and key not in live:
            diags.append(_err("SPT112", f"psum lifetime: cu {pp} reads "
                              f"slot {s} at cycle {tt} before any store",
                              cycle=tt, cu=pp, slot=s, **blame))
            live.add(key)  # treat as defined: report each race once
            continue
        if c == PS_STORE_RESET and key in live:
            diags.append(_err("SPT112", f"psum lifetime: cu {pp} stores "
                              f"slot {s} at cycle {tt} overwriting a live "
                              f"partial sum (WAW)",
                              cycle=tt, cu=pp, slot=s, **blame))
        if c in (PS_STORE_RESET, PS_SWAP):
            live.add(key)
        elif c == PS_LOAD:
            live.discard(key)
    return diags
