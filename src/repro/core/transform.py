"""Beyond-paper optimization: medium-node splitting for load balance.

The paper's §V-E identifies the residual bottleneck of the medium
granularity dataflow: "a small number of coarse nodes have significantly
more edges than other coarse nodes ... transforming coarse nodes into fine
or medium nodes may help mitigate load imbalance.  A medium node is a node
that performs the same basic operations as a coarse node but has fewer
input edges ... further research is required."  This module is that
research step, done as pure matrix surgery so the unmodified compiler and
hardware model run it:

A row i with in-degree k > max_indegree is split by introducing auxiliary
unknowns (one per chunk of `max_indegree` edges)

    y_c = sum_{j in chunk c} L_ij x_j        (aux row: diag 1, rhs 0)
    x_i = (b_i - sum_c y_c - sum_{rest} L_ij x_j) / L_ii

which yields an EQUIVALENT, still lower-triangular system whose DAG has
bounded in-degree: the aux nodes are medium nodes allocatable to different
CUs, parallelizing what was a serial k-edge accumulation chain on one CU.
Cost: one extra edge + one extra finalize per chunk (the psum feedback
keeps each chunk's accumulation local, exactly the paper's locality
argument).  `solve` results map back through `orig_index`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .csr import TriCSR

__all__ = ["SplitResult", "split_heavy_nodes"]


@dataclasses.dataclass(frozen=True)
class SplitResult:
    mat: TriCSR
    orig_index: np.ndarray   # position of original row i in the new system
    n_aux: int

    def expand_rhs(self, b: np.ndarray) -> np.ndarray:
        """Lift ``b`` (``[n]`` or ``[n, B]``) into the split system's space.

        Aux rows get rhs 0; any trailing batch axes are preserved so the
        transform composes with the batched and sharded solve paths.
        """
        b = np.asarray(b)
        nb = np.zeros((self.mat.n, *b.shape[1:]), dtype=b.dtype)
        nb[self.orig_index] = b
        return nb

    def extract(self, x_new: np.ndarray) -> np.ndarray:
        """Project a split-system solution back to the original unknowns
        (row gather — trailing batch axes pass through untouched)."""
        return np.asarray(x_new)[self.orig_index]


def split_heavy_nodes(mat: TriCSR, max_indegree: int = 48) -> SplitResult:
    """Split every row with more than `max_indegree` off-diagonals."""
    n = mat.n
    new_rows: list[tuple[np.ndarray, np.ndarray, float]] = []  # cols,vals,diag
    orig_index = np.zeros(n, dtype=np.int64)
    old2new: dict[int, int] = {}
    n_aux = 0

    for i in range(n):
        cols, vals = mat.row(i)
        off_c, off_v, diag = cols[:-1], vals[:-1], vals[-1]
        k = len(off_c)
        mapped = np.array([old2new[int(c)] for c in off_c], dtype=np.int64)
        if k <= max_indegree:
            new_rows.append((mapped, off_v.copy(), float(diag)))
        else:
            # chunk the edges; keep the LAST chunk inline on the parent so
            # the parent still has direct work while aux nodes compute
            n_chunks = -(-k // max_indegree)
            aux_ids = []
            for c in range(n_chunks - 1):
                lo, hi = c * max_indegree, (c + 1) * max_indegree
                # solver computes y = (0 - sum(v * x)) / 1, so negate to get
                # y_c = +sum(L_ij x_j); the parent then subtracts 1 * y_c
                new_rows.append((mapped[lo:hi], -off_v[lo:hi], 1.0))
                aux_ids.append(len(new_rows) - 1)
                n_aux += 1
            lo = (n_chunks - 1) * max_indegree
            par_cols = np.concatenate([mapped[lo:], np.array(aux_ids, np.int64)])
            par_vals = np.concatenate([off_v[lo:], np.full(len(aux_ids), 1.0)])
            order = np.argsort(par_cols)
            new_rows.append((par_cols[order], par_vals[order], float(diag)))
        old2new[i] = len(new_rows) - 1
        orig_index[i] = len(new_rows) - 1

    m = len(new_rows)
    rowptr = np.zeros(m + 1, dtype=np.int64)
    for r, (c, v, d) in enumerate(new_rows):
        rowptr[r + 1] = rowptr[r] + len(c) + 1
    colidx = np.empty(rowptr[-1], dtype=np.int64)
    values = np.empty(rowptr[-1], dtype=np.float64)
    for r, (c, v, d) in enumerate(new_rows):
        lo = rowptr[r]
        colidx[lo : lo + len(c)] = c
        values[lo : lo + len(c)] = v
        colidx[rowptr[r + 1] - 1] = r
        values[rowptr[r + 1] - 1] = d
    out = TriCSR(n=m, rowptr=rowptr, colidx=colidx, values=values,
                 name=f"{mat.name}+split{max_indegree}")
    out.validate()
    return SplitResult(mat=out, orig_index=orig_index, n_aux=n_aux)
