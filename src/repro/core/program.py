"""Instruction-stream program emitted by the compiler.

The accelerator is VLIW (paper §II-B): one instruction word per CU per cycle.
We encode the word as a *packed* dense int32 array of shape
``[cycles, planes, num_cus]`` — the software-managed-memory philosophy of the
paper carried to its conclusion: *all* irregularity is resolved at compile
time and the executor (numpy / JAX scan / Pallas kernel) runs a branch-free
data-driven program over a byte-minimal stream (DESIGN.md §Perf,
"Instruction encoding").

Packed word layout (single-plane regime, low bit -> high bit):

    [ src : SRC_BITS ][ op : 2 ][ ctl : 3 ][ slot : 8 ]     31 bits used

``src`` is the solution-row index (EDGE reads x[src]; FINAL reads b[src] and
writes x[src]) — the historical ``out_idx`` field is *derived*, not stored:
it always equals ``src`` on FINAL lanes and the dummy row otherwise, so
executors reconstruct the write index from ``(op, src)``.  The value-stream
index rides in a separate ``val_idx`` plane (the Pallas path pre-gathers
values at staging time and never streams indices at all).

Programs whose row indices do not fit ``SRC_BITS`` fall back automatically
to a two-plane layout: plane 0 carries the full-width ``src`` and plane 1
the remaining control fields with the same relative layout.  Either way one
``decode_instructions`` helper (pure ``&``/``>>`` arithmetic, numpy- and
jax-compatible) is the single source of truth for all three executors.

Opcode / psum-control encodings mirror Fig. 5 of the paper:
  * ``ct=1`` MAC edges  -> OP_EDGE  : psum += L_ij * x[src]
  * ``ct=0`` node update-> OP_FINAL : x[src] = (b[src] - psum) * L_ii^{-1}
    (division is performed as multiplication by the compiler-computed
    reciprocal, exactly as in §III-B).
The psum-control field encodes the S1/S2 multiplexer + psum register file
behaviour of §IV-B (keep/feedback, reset, load, store, read-before-write
swap).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "AccelConfig",
    "ScheduleStats",
    "Program",
    "OP_NOP",
    "OP_EDGE",
    "OP_FINAL",
    "PS_KEEP",
    "PS_RESET",
    "PS_LOAD",
    "PS_STORE_RESET",
    "PS_SWAP",
    "SRC_BITS",
    "OP_BITS",
    "CTL_BITS",
    "SLOT_BITS",
    "MAX_SLOT",
    "packed_planes",
    "pack_instructions",
    "decode_instructions",
    "validate_fields",
]

OP_NOP, OP_EDGE, OP_FINAL = 0, 1, 2
PS_KEEP, PS_RESET, PS_LOAD, PS_STORE_RESET, PS_SWAP = 0, 1, 2, 3, 4

# ---------------------------------------------------------------------------
# Packed single-word instruction encoding
# ---------------------------------------------------------------------------
# Field widths (single-plane regime).  src gets every bit left over after the
# control fields; 18 + 2 + 3 + 8 = 31 bits keeps the word non-negative in
# int32, so arithmetic right-shifts decode it on every backend.
SRC_BITS = 18
OP_BITS = 2
CTL_BITS = 3
SLOT_BITS = 8

_OP_SHIFT = 0            # within the control part ("rest")
_CTL_SHIFT = OP_BITS
_SLOT_SHIFT = OP_BITS + CTL_BITS

_SRC_MASK = (1 << SRC_BITS) - 1
_OP_MASK = (1 << OP_BITS) - 1
_CTL_MASK = (1 << CTL_BITS) - 1
_SLOT_MASK = (1 << SLOT_BITS) - 1

# Largest psum slot id the packed word can carry — the compiler's overflow
# slots grow on demand but must stop here (compiler/sched.peek_over_slot).
MAX_SLOT = _SLOT_MASK


def packed_planes(n: int) -> int:
    """Planes needed to pack a program over ``n`` rows (1, or 2 for huge n).

    The single-plane word holds row indices up to ``2**SRC_BITS - 1``, so
    one plane covers ``n <= 2**SRC_BITS``; beyond that the encoding falls
    back to two int32 planes (full-width ``src`` in plane 0, control fields
    in plane 1) — chosen automatically at compile/staging time, decoded by
    the same helper.
    """
    return 1 if n - 1 <= _SRC_MASK else 2


def validate_fields(op, src, ctl, slot, planes: int) -> None:
    """Single validation point for the packed field widths.

    Shared by the compiler and the packer: any field exceeding its bit
    width raises a clear ``ValueError`` instead of silently wrapping into a
    neighbouring field (the historical risk: `schedule._CU.peek_over_slot`
    grows overflow slots toward 250 while the packed slot field is 8 bits).
    """
    op = np.asarray(op)
    src = np.asarray(src)
    ctl = np.asarray(ctl)
    slot = np.asarray(slot)
    src_max = np.iinfo(np.int32).max if planes == 2 else _SRC_MASK
    for name, arr, hi in (
        (f"src ({SRC_BITS}-bit)" if planes == 1 else "src (int32)", src, src_max),
        (f"op ({OP_BITS}-bit)", op, _OP_MASK),
        (f"ctl ({CTL_BITS}-bit)", ctl, _CTL_MASK),
        (f"slot ({SLOT_BITS}-bit)", slot, _SLOT_MASK),
    ):
        if arr.size == 0:
            continue
        lo_v, hi_v = int(arr.min()), int(arr.max())
        if lo_v < 0 or hi_v > hi:
            raise ValueError(
                f"instruction field {name} out of range: saw value "
                f"{lo_v if lo_v < 0 else hi_v}, allowed [0, {hi}] "
                f"(planes={planes})"
            )


def pack_instructions(op, src, ctl, slot, planes: int | None = None,
                      n: int | None = None) -> np.ndarray:
    """Pack per-field ``[T, P]`` arrays into ``[T, planes, P]`` int32 words.

    ``planes=None`` auto-selects from ``n`` (or the max src value) via
    `packed_planes`.  Fields are validated against their bit widths first
    (`validate_fields`).
    """
    op = np.asarray(op, dtype=np.int64)
    src = np.asarray(src, dtype=np.int64)
    ctl = np.asarray(ctl, dtype=np.int64)
    slot = np.asarray(slot, dtype=np.int64)
    if planes is None:
        rows = n if n is not None else (int(src.max()) + 1 if src.size else 1)
        planes = packed_planes(rows)
    if planes not in (1, 2):
        raise ValueError(f"planes must be 1 or 2, got {planes}")
    validate_fields(op, src, ctl, slot, planes)
    rest = (op << _OP_SHIFT) | (ctl << _CTL_SHIFT) | (slot << _SLOT_SHIFT)
    if planes == 1:
        word = src | (rest << SRC_BITS)
        return word.astype(np.int32)[:, None, :]
    return np.stack([src, rest], axis=1).astype(np.int32)


def decode_instructions(words, planes: int):
    """Decode packed words back into ``(op, src, ctl, slot)``.

    ``words`` is ``[..., planes, P]`` — a whole program, one cycle block, or
    a single cycle row — as a numpy array, a jax array, or a tracer: the
    decode is pure ``&``/``>>`` arithmetic, so one helper serves the numpy
    oracle, the `lax.scan` executor, and the Pallas kernels identically.
    """
    w0 = words[..., 0, :]
    if planes == 1:
        src = w0 & _SRC_MASK
        rest = w0 >> SRC_BITS
    elif planes == 2:
        src = w0
        rest = words[..., 1, :]
    else:
        raise ValueError(f"planes must be 1 or 2, got {planes}")
    op = rest & _OP_MASK
    ctl = (rest >> _CTL_SHIFT) & _CTL_MASK
    slot = (rest >> _SLOT_SHIFT) & _SLOT_MASK
    return op, src, ctl, slot


@dataclasses.dataclass(frozen=True)
class AccelConfig:
    """Hardware parameters (paper §V-A synthesis configuration)."""

    num_cus: int = 64          # 2^N compute units
    xi_words: int = 64         # x_i register file words per CU (2^M)
    psum_words: int = 8        # psum register file words per CU (2^K)
    num_banks: int = 64        # banked x-read ports across the interconnect
    clock_mhz: float = 150.0   # paper runs at 150 MHz (half of DPU-v2)
    alloc: str = "least_edges"  # node->CU allocation: least_edges | roundrobin
    icr: bool = True           # intra-node edge computation reordering
    psum_cache: bool = True    # partial-sum caching mechanism (§IV-B)
    dataflow: str = "medium"   # medium | coarse
    icr_window: int = 16       # per-CU ready-edge window examined by ICR

    @property
    def clock_period_s(self) -> float:
        return 1.0 / (self.clock_mhz * 1e6)


@dataclasses.dataclass
class ScheduleStats:
    """Everything the paper reports per benchmark (Figs. 9/10, Tables III/IV)."""

    name: str
    n: int
    nnz: int
    cycles: int          # hardware cycles (incl. all-NOP stall cycles)
    exec_edges: int
    exec_finals: int
    emitted_cycles: int = 0  # instruction rows actually emitted (stall rows
                             # where no lane executes are elided at emission)
    bnop: int = 0        # bank-conflict blocking
    pnop: int = 0        # psum-capacity blocking
    dnop: int = 0        # DAG-structure blocking (has tasks, all blocked)
    lnop: int = 0        # load-imbalance blocking (task list drained)
    snop: int = 0        # x_i register-file spill reload stalls (ours; tiny)
    constraints: int = 0     # bank-coloring constraint pairs (Fig. 9d)
    conflicts: int = 0       # unresolved same-bank collisions (Fig. 9e)
    reuse_events: int = 0    # broadcast reads serving >1 CU (Fig. 9f)
    distinct_reads: int = 0  # total distinct x reads across all cycles
    spilled_values: int = 0
    dm_escapes: int = 0      # emergency psum overflow parks (DESIGN.md §5)
    per_cu_edges: np.ndarray | None = None
    compile_seconds: float = 0.0
    # per-pass observability of the staged pipeline (DESIGN.md §6): a list
    # of `compiler.PassStats` (name, seconds, metrics) in pass order
    pass_stats: list | None = None
    # scheduling-strategy frontier (DESIGN.md §11): which schedule pass
    # produced this program, and — on schedule="auto" compiles — the
    # predicted cost of every candidate ({name: {cycles, stall_rows,
    # psum_spills, planes}}), the evidence behind the pick (and behind the
    # SPT208 "cycles left on the table" perf lint)
    schedule: str = "paper"
    schedule_costs: dict | None = None

    # -- paper metrics ---------------------------------------------------
    def flops(self) -> int:
        return 2 * self.nnz - self.n

    def throughput_gops(self, cfg: AccelConfig) -> float:
        return self.flops() / (self.cycles * cfg.clock_period_s) / 1e9

    def peak_throughput_gops(self, cfg: AccelConfig) -> float:
        """Equation 3 of the paper."""
        p = cfg.num_cus
        return (2.0 * p / cfg.clock_period_s) * (1.0 - self.n / (2.0 * self.nnz)) / 1e9

    def utilization(self) -> float:
        return (self.exec_edges + self.exec_finals) / (self.cycles * max(1, len(self.per_cu_edges)))

    def load_balance_cv(self) -> float:
        """Coefficient of variation (%) of input edges per CU (§V-B)."""
        e = self.per_cu_edges.astype(np.float64)
        return float(100.0 * e.std() / max(e.mean(), 1e-12))

    def nop_breakdown(self) -> dict[str, float]:
        total = self.cycles * max(1, len(self.per_cu_edges))
        return {
            "exec": (self.exec_edges + self.exec_finals) / total,
            "bnop": self.bnop / total,
            "pnop": self.pnop / total,
            "dnop": self.dnop / total,
            "lnop": self.lnop / total,
            "snop": self.snop / total,
        }


@dataclasses.dataclass(eq=False)
class Program:
    """Compiled VLIW instruction stream + reordered stream memory.

    The canonical instruction storage is the packed ``instr`` tensor (see
    module docstring); the historical per-field planes (``opcode``,
    ``src_idx``, ``psum_ctrl``, ``psum_slot``) are decoded views, and
    ``out_idx`` is *derived* — equal to ``src_idx`` on FINAL lanes, the
    dummy row ``n`` otherwise.

    ``eq=False`` keeps identity hashing/weakref support so executors can be
    cached per compiled program (see ``executor.make_jax_executor``).
    """

    config: AccelConfig
    n: int
    instr: np.ndarray      # [T, planes, P] int32 packed instruction words
    val_idx: np.ndarray    # [T, P] int32 index into `stream`
    stream: np.ndarray     # [S] float32: L_ij / 1/L_ii in schedule order
    stats: ScheduleStats
    num_slots: int = 0     # executor psum RF size (psum_words + overflow used)
    # Per-cycle solution-row access ranges (DESIGN.md §1, row-blocked x):
    # row_lo[t]/row_hi[t] = min/max row index touched by any active lane in
    # cycle t (EDGE reads x[src]; FINAL reads b[row] and writes x[row]).
    # Cycles with no active lane carry the empty sentinel (n, -1).  The
    # Pallas wrapper reduces these to per-cycle-block VMEM window bounds
    # that drive the level-boundary flush/refill DMAs.
    row_lo: np.ndarray | None = None  # [T] int32
    row_hi: np.ndarray | None = None  # [T] int32
    # Value provenance of `stream` (values-only recompilation, DESIGN.md
    # §10): stream_src[s] >= 0 is the global edge index into the frontend
    # ComputeDag's weight array whose coefficient was streamed at slot s;
    # a negative entry -(i+1) means node i's scale (diagonal reciprocal)
    # was streamed.  `compiler.recompile_values` regathers a fresh stream
    # from this plane without rescheduling; None on pre-provenance
    # programs (they take the full recompile path).
    stream_src: np.ndarray | None = None  # [S] int64

    @property
    def cycles(self) -> int:
        """Emitted instruction rows (== ``stats.emitted_cycles``; the
        *hardware* cycle count incl. elided stall rows is ``stats.cycles``)."""
        return self.instr.shape[0]

    @property
    def planes(self) -> int:
        return self.instr.shape[1]

    @property
    def num_cus(self) -> int:
        return self.instr.shape[2]

    # -- decoded views (host-side convenience; hot paths decode packed) ----
    def _decoded(self):
        cached = getattr(self, "_decoded_cache", None)
        if cached is None:
            cached = decode_instructions(self.instr, self.planes)
            object.__setattr__(self, "_decoded_cache", cached)
        return cached

    @property
    def opcode(self) -> np.ndarray:
        return self._decoded()[0]

    @property
    def src_idx(self) -> np.ndarray:
        return self._decoded()[1]

    @property
    def psum_ctrl(self) -> np.ndarray:
        return self._decoded()[2]

    @property
    def psum_slot(self) -> np.ndarray:
        return self._decoded()[3]

    @property
    def out_idx(self) -> np.ndarray:
        """Derived x write index: ``src`` on FINAL lanes, dummy row else."""
        op, src, _, _ = self._decoded()
        return np.where(op == OP_FINAL, src, self.n).astype(np.int32)

    # -- integrity hooks (DESIGN.md §7) -------------------------------------
    def validate_fields(self) -> None:
        """Re-check every decoded field against its packed bit width.

        Method form of the module-level `validate_fields`, run over this
        program's own words — the first line of defence of the structural
        validator (`core.robust.verify_program`), which wraps the raised
        ``ValueError`` into a `ProgramCorruptionError`.
        """
        op, src, ctl, slot = decode_instructions(self.instr, self.planes)
        validate_fields(op, src, ctl, slot, self.planes)

    def content_crc32(self) -> int:
        """CRC32 fingerprint of the executable content (instr/val_idx/stream).

        Stable across processes for bit-identical programs — the cheap
        identity the serving cache and the serialized format
        (`core.serialize`) key integrity on.
        """
        import zlib

        crc = 0
        for arr in (self.instr, self.val_idx, self.stream):
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes(), crc)
        return crc

    # -- instruction-traffic accounting ------------------------------------
    def instr_bytes_per_lane_cycle(self) -> int:
        """Streamed instruction bytes per lane per emitted cycle.

        One packed int32 word per plane plus the pre-gathered f32 stream
        value: 8 B in the single-plane regime (was 24 B with the five
        unpacked int32 planes).
        """
        return 4 * self.planes + 4

    def instr_bytes(self) -> int:
        """Total instruction HBM traffic streamed for one solve."""
        return self.cycles * self.num_cus * self.instr_bytes_per_lane_cycle()

    def instruction_bits(self) -> int:
        """Approximate instruction-memory footprint (Fig. 5a word layout)."""
        import math

        cfg = self.config
        n_, m_, k_ = (
            int(math.log2(cfg.num_cus)),
            int(math.log2(cfg.xi_words)),
            int(math.log2(cfg.psum_words)),
        )
        t_ = 14  # data-memory addressing depth 2^T
        word = (1 + k_) + (1 + m_ + 1) + (1 + t_) + n_ + 2 + 2 + 2 + 1 + 1
        return int(self.cycles * self.num_cus * word)
