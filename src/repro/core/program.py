"""Instruction-stream program emitted by the compiler.

The accelerator is VLIW (paper §II-B): one instruction word per CU per cycle.
We encode the word as parallel dense arrays of shape [cycles, num_cus] — the
software-managed-memory philosophy of the paper carried to its conclusion:
*all* irregularity is resolved at compile time and the executor (numpy / JAX
scan / Pallas kernel) runs a branch-free data-driven program.

Opcode / psum-control encodings mirror Fig. 5 of the paper:
  * ``ct=1`` MAC edges  -> OP_EDGE  : psum += L_ij * x[src]
  * ``ct=0`` node update-> OP_FINAL : x[out] = (b[src] - psum) * L_ii^{-1}
    (division is performed as multiplication by the compiler-computed
    reciprocal, exactly as in §III-B).
The psum-control field encodes the S1/S2 multiplexer + psum register file
behaviour of §IV-B (keep/feedback, reset, load, store, read-before-write
swap).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "AccelConfig",
    "ScheduleStats",
    "Program",
    "OP_NOP",
    "OP_EDGE",
    "OP_FINAL",
    "PS_KEEP",
    "PS_RESET",
    "PS_LOAD",
    "PS_STORE_RESET",
    "PS_SWAP",
]

OP_NOP, OP_EDGE, OP_FINAL = 0, 1, 2
PS_KEEP, PS_RESET, PS_LOAD, PS_STORE_RESET, PS_SWAP = 0, 1, 2, 3, 4


@dataclasses.dataclass(frozen=True)
class AccelConfig:
    """Hardware parameters (paper §V-A synthesis configuration)."""

    num_cus: int = 64          # 2^N compute units
    xi_words: int = 64         # x_i register file words per CU (2^M)
    psum_words: int = 8        # psum register file words per CU (2^K)
    num_banks: int = 64        # banked x-read ports across the interconnect
    clock_mhz: float = 150.0   # paper runs at 150 MHz (half of DPU-v2)
    alloc: str = "least_edges"  # node->CU allocation: least_edges | roundrobin
    icr: bool = True           # intra-node edge computation reordering
    psum_cache: bool = True    # partial-sum caching mechanism (§IV-B)
    dataflow: str = "medium"   # medium | coarse
    icr_window: int = 16       # per-CU ready-edge window examined by ICR

    @property
    def clock_period_s(self) -> float:
        return 1.0 / (self.clock_mhz * 1e6)


@dataclasses.dataclass
class ScheduleStats:
    """Everything the paper reports per benchmark (Figs. 9/10, Tables III/IV)."""

    name: str
    n: int
    nnz: int
    cycles: int
    exec_edges: int
    exec_finals: int
    bnop: int = 0        # bank-conflict blocking
    pnop: int = 0        # psum-capacity blocking
    dnop: int = 0        # DAG-structure blocking (has tasks, all blocked)
    lnop: int = 0        # load-imbalance blocking (task list drained)
    snop: int = 0        # x_i register-file spill reload stalls (ours; tiny)
    constraints: int = 0     # bank-coloring constraint pairs (Fig. 9d)
    conflicts: int = 0       # unresolved same-bank collisions (Fig. 9e)
    reuse_events: int = 0    # broadcast reads serving >1 CU (Fig. 9f)
    distinct_reads: int = 0  # total distinct x reads across all cycles
    spilled_values: int = 0
    dm_escapes: int = 0      # emergency psum overflow parks (DESIGN.md §5)
    per_cu_edges: np.ndarray | None = None
    compile_seconds: float = 0.0

    # -- paper metrics ---------------------------------------------------
    def flops(self) -> int:
        return 2 * self.nnz - self.n

    def throughput_gops(self, cfg: AccelConfig) -> float:
        return self.flops() / (self.cycles * cfg.clock_period_s) / 1e9

    def peak_throughput_gops(self, cfg: AccelConfig) -> float:
        """Equation 3 of the paper."""
        p = cfg.num_cus
        return (2.0 * p / cfg.clock_period_s) * (1.0 - self.n / (2.0 * self.nnz)) / 1e9

    def utilization(self) -> float:
        return (self.exec_edges + self.exec_finals) / (self.cycles * max(1, len(self.per_cu_edges)))

    def load_balance_cv(self) -> float:
        """Coefficient of variation (%) of input edges per CU (§V-B)."""
        e = self.per_cu_edges.astype(np.float64)
        return float(100.0 * e.std() / max(e.mean(), 1e-12))

    def nop_breakdown(self) -> dict[str, float]:
        total = self.cycles * max(1, len(self.per_cu_edges))
        return {
            "exec": (self.exec_edges + self.exec_finals) / total,
            "bnop": self.bnop / total,
            "pnop": self.pnop / total,
            "dnop": self.dnop / total,
            "lnop": self.lnop / total,
            "snop": self.snop / total,
        }


@dataclasses.dataclass(eq=False)
class Program:
    """Compiled VLIW instruction stream + reordered stream memory.

    ``eq=False`` keeps identity hashing/weakref support so executors can be
    cached per compiled program (see ``executor.make_jax_executor``).
    """

    config: AccelConfig
    n: int
    opcode: np.ndarray     # [T, P] uint8
    val_idx: np.ndarray    # [T, P] int32 index into `stream`
    src_idx: np.ndarray    # [T, P] int32 x index (EDGE) / b index (FINAL)
    out_idx: np.ndarray    # [T, P] int32 x write index (FINAL) else n
    psum_ctrl: np.ndarray  # [T, P] uint8
    psum_slot: np.ndarray  # [T, P] uint8
    stream: np.ndarray     # [S] float32: L_ij / 1/L_ii in schedule order
    stats: ScheduleStats
    num_slots: int = 0     # executor psum RF size (psum_words + overflow used)
    # Per-cycle solution-row access ranges (DESIGN.md §1, row-blocked x):
    # row_lo[t]/row_hi[t] = min/max row index touched by any active lane in
    # cycle t (EDGE reads x[src]; FINAL reads b[row] and writes x[row]).
    # Cycles with no active lane carry the empty sentinel (n, -1).  The
    # Pallas wrapper reduces these to per-cycle-block VMEM window bounds
    # that drive the level-boundary flush/refill DMAs.
    row_lo: np.ndarray | None = None  # [T] int32
    row_hi: np.ndarray | None = None  # [T] int32

    @property
    def cycles(self) -> int:
        return self.opcode.shape[0]

    @property
    def num_cus(self) -> int:
        return self.opcode.shape[1]

    def instruction_bits(self) -> int:
        """Approximate instruction-memory footprint (Fig. 5a word layout)."""
        import math

        cfg = self.config
        n_, m_, k_ = (
            int(math.log2(cfg.num_cus)),
            int(math.log2(cfg.xi_words)),
            int(math.log2(cfg.psum_words)),
        )
        t_ = 14  # data-memory addressing depth 2^T
        word = (1 + k_) + (1 + m_ + 1) + (1 + t_) + n_ + 2 + 2 + 2 + 1 + 1
        return int(self.cycles * self.num_cus * word)
