"""Compiler entry point for lower-triangular SpTRSV (thin wrapper).

The historical 500-line monolith that lived here is now the staged pass
pipeline in `core/compiler/` (DESIGN.md §6):

    partition → cu-assign → psum-cache schedule (+ per-cycle ICR reorder)
    → stall-elide → pack/emit

over the generic `compiler.ComputeDag` IR, with workload lowerings in
`core/frontends/` (lower-triangular here; upper-triangular, transpose and
general DAG-circuit workloads beside it).  `compile_program` keeps its
historical signature — lower a `TriCSR` through the SpTRSV frontend and
run the pipeline — and produces the identical `Program` (instruction
stream, stats, row envelopes) the monolith did; the equivalence is pinned
by `tests/test_compiler_pipeline.py` against a frozen copy of the old
compiler.
"""

from __future__ import annotations

from .compiler import (  # noqa: F401  (recompile_values re-exported)
    PSUM_OVERFLOW_SLOTS,
    compile_dag,
    recompile_values,
)
from .compiler.assign import allocate
from .csr import TriCSR
from .frontends.sptrsv import lower_tri
from .program import AccelConfig, Program

__all__ = ["compile_program", "recompile_values", "allocate_nodes",
           "PSUM_OVERFLOW_SLOTS"]


def allocate_nodes(mat: TriCSR, cfg: AccelConfig) -> list[list[int]]:
    """Node → CU allocation (historical API; see `compiler.assign`)."""
    return allocate(mat.n, mat.in_degree(), cfg)


def compile_program(mat: TriCSR, cfg: AccelConfig | None = None, *,
                    planes: int | None = None,
                    schedule: str = "paper",
                    verify_ir: bool = False) -> Program:
    """Compile ``mat`` into a packed VLIW `Program`.

    ``planes`` forces the packed-word layout (1 = single-word, 2 = the
    large-n fallback); ``None`` auto-selects via `program.packed_planes`.
    ``schedule`` picks the schedule pass — a strategy name from
    `compiler.strategies` or ``"auto"`` for per-matrix cost-model
    selection (DESIGN.md §11).  ``verify_ir=True`` runs the per-pass
    contract verifiers between pipeline stages (`core/analysis/`, raises
    `errors.IRValidationError` naming the guilty pass).  Equivalent to
    ``compiler.compile_dag(frontends.sptrsv.lower_tri(mat))``.
    """
    return compile_dag(lower_tri(mat), cfg, planes=planes,
                       schedule=schedule, verify_ir=verify_ir)
