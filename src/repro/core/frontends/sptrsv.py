"""Lower-triangular SpTRSV frontend: `TriCSR` → `ComputeDag`.

The paper's workload.  Row i of Lx=b computes

    x[i] = (b[i] - sum_{j<i} L_ij x[j]) / L_ii

which is the `ComputeDag` node contract with edge weights L_ij and node
scale 1/L_ii (division as multiplication by the compiler-computed
reciprocal, §III-B).  Row order is already a topological order, so the
lowering is a pure re-slicing of the CSR arrays: drop the trailing
per-row diagonal, invert it into the scale vector.
"""

from __future__ import annotations

import numpy as np

from ..compiler.ir import ComputeDag
from ..csr import TriCSR

__all__ = ["lower_tri"]


def lower_tri(mat: TriCSR) -> ComputeDag:
    n = mat.n
    ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.diff(mat.rowptr) - 1, out=ptr[1:])
    off = np.ones(mat.nnz, dtype=bool)
    off[mat.rowptr[1:] - 1] = False  # the per-row trailing diagonal
    return ComputeDag(
        name=mat.name,
        n=n,
        ptr=ptr,
        src=mat.colidx[off],
        weight=mat.values[off],
        scale=1.0 / mat.diag(),
    )
