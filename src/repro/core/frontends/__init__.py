"""Workload frontends: lower concrete problems onto the compiler IR.

Every frontend produces a `compiler.ComputeDag` (plus, where the node
numbering differs from the user's, an index permutation) and the staged
pipeline (`core/compiler/`) does the rest — the emitted `Program` format
is unchanged, so all executors, batching, sharding and the packed
encoding serve every workload here for free.

  * `sptrsv`  — the classic lower-triangular solve Lx=b (paper workload);
  * `upper`   — upper-triangular solve Ux=b and the transpose solve
    Lᵀx=b via CSC-row reversal (the backward sweep of an incomplete-
    Cholesky preconditioner application);
  * `dagcirc` — general SpTRSV-like DAGs: DPU-v2-style weighted-
    accumulate circuits with a numpy oracle.
"""

from . import dagcirc, sptrsv, upper  # noqa: F401
from .sptrsv import lower_tri  # noqa: F401
from .upper import lower_transpose, lower_upper  # noqa: F401
from .dagcirc import DagCircuit, lower_circuit, random_circuit  # noqa: F401
