"""General SpTRSV-like DAG frontend: weighted-accumulate circuits.

DPU-v2 (the paper's fine-granularity comparison point) is evaluated on
general sparse DAG workloads, not just triangular matrices.  This
frontend opens the same door for our stack: a `DagCircuit` is a DAG whose
node ``i`` computes the affine combination

    x[i] = scale[i] * (u[i] + sum_k weight[k] * x[src[k]])

over its predecessors — the linear slice of DPU-v2's sum-product
workloads (sparse neural accumulation layers, probabilistic-circuit
marginals with fixed evidence, signal-flow graphs).  Leaves (no sources,
scale 1) pass their input through.  The lowering to the compiler IR is a
sign flip: the executor contract is ``x[i] = (b[i] - Σ w·x) * scale``, so
circuit weights negate and the circuit input vector ``u`` rides in as b.

`eval` is the numpy oracle the property tests round-trip against;
`random_circuit` generates well-conditioned instances (per-node ``Σ|w|``
bounded < 1, |scale| ≤ 1) so f32 executor parity stays tight.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..compiler.ir import ComputeDag

__all__ = ["DagCircuit", "lower_circuit", "random_circuit"]


@dataclasses.dataclass(frozen=True)
class DagCircuit:
    """A weighted-accumulate DAG circuit in topological node order."""

    name: str
    n: int
    ptr: np.ndarray     # int64 [n+1]
    src: np.ndarray     # int64 [E] — predecessors, ascending per node
    weight: np.ndarray  # float64 [E]
    scale: np.ndarray   # float64 [n]

    @property
    def n_edges(self) -> int:
        return int(self.ptr[-1])

    def eval(self, u: np.ndarray) -> np.ndarray:
        """Numpy oracle: evaluate the circuit on input ``u`` ([n] or [n, B])."""
        u = np.asarray(u, dtype=np.float64)
        x = np.zeros_like(u)
        for i in range(self.n):
            lo, hi = int(self.ptr[i]), int(self.ptr[i + 1])
            acc = u[i]
            if hi > lo:
                w = self.weight[lo:hi]
                xs = x[self.src[lo:hi]]
                acc = acc + (w @ xs if u.ndim > 1 else np.dot(w, xs))
            x[i] = self.scale[i] * acc
        return x


def lower_circuit(circ: DagCircuit) -> ComputeDag:
    """Lower a circuit to the compiler IR (pure sign flip on the weights)."""
    return ComputeDag(name=circ.name, n=circ.n, ptr=circ.ptr, src=circ.src,
                      weight=-circ.weight, scale=circ.scale)


def random_circuit(
    n: int,
    *,
    max_fan_in: int = 6,
    leaf_frac: float = 0.2,
    locality: int | None = None,
    seed: int = 0,
    name: str | None = None,
) -> DagCircuit:
    """Generate a well-conditioned random circuit in topological order.

    ``leaf_frac`` of the nodes (always including node 0) are leaves;
    internal nodes draw 1..``max_fan_in`` predecessors, biased toward
    recent nodes when ``locality`` is set (window of candidate sources).
    Per-node ``Σ|w|`` is normalized below 0.9 and ``|scale| ≤ 1`` so
    values stay O(|u|) at any depth — keeps the f32 executors within
    1e-5 of the f64 oracle.
    """
    rng = np.random.default_rng(seed)
    ptr = np.zeros(n + 1, dtype=np.int64)
    srcs: list[np.ndarray] = []
    weights: list[np.ndarray] = []
    for i in range(n):
        if i == 0 or rng.random() < leaf_frac:
            srcs.append(np.empty(0, dtype=np.int64))
            weights.append(np.empty(0, dtype=np.float64))
        else:
            k = int(rng.integers(1, max_fan_in + 1))
            lo = max(0, i - locality) if locality else 0
            cand = np.arange(lo, i)
            k = min(k, len(cand))
            pick = np.sort(rng.choice(cand, size=k, replace=False))
            w = rng.uniform(-1.0, 1.0, size=k)
            norm = np.abs(w).sum()
            if norm > 0.9:
                w *= 0.9 / norm
            srcs.append(pick.astype(np.int64))
            weights.append(w)
        ptr[i + 1] = ptr[i] + len(srcs[-1])
    scale = rng.uniform(0.5, 1.0, size=n) * rng.choice([-1.0, 1.0], size=n)
    return DagCircuit(
        name=name or f"circ_n{n}_s{seed}",
        n=n,
        ptr=ptr,
        src=np.concatenate(srcs) if srcs else np.empty(0, np.int64),
        weight=np.concatenate(weights) if weights else np.empty(0, np.float64),
        scale=scale,
    )
