"""Upper-triangular / transpose-solve frontend (CSC-row reversal).

An upper-triangular system Ux=b is a lower-triangular system in reversed
row order: with the reversal permutation ``r(i) = n-1-i``, the matrix
``P U Pᵀ`` (P the reversal) is lower triangular, so node ``k = r(i)``
solves unknown ``i`` and its sources ``r(j), j > i`` are strictly smaller
node ids — exactly the `ComputeDag` contract.  The lowering therefore
returns ``(dag, perm)`` where ``perm[k] = n-1-k`` maps internal node ids
back to user-space rows: feed the compiled program ``b[perm]``, read the
solution as ``x[perm] = x_internal`` (the reversal is an involution).

The transpose solve Lᵀx=b — the backward sweep of an incomplete-Cholesky
preconditioner application — is the special case ``U = Lᵀ``
(`csr.transpose_upper`); `api.compile_pair` packages both sweeps.
"""

from __future__ import annotations

import numpy as np

from ..compiler.ir import ComputeDag
from ..csr import TriCSR, UpperCSR, transpose_upper

__all__ = ["lower_upper", "lower_transpose"]


def lower_upper(mat: UpperCSR) -> tuple[ComputeDag, np.ndarray]:
    """Lower Ux=b to a `ComputeDag` via row reversal; returns (dag, perm).

    ``perm[k]`` is the user-space row solved by internal node ``k``
    (``perm = [n-1, ..., 0]``, its own inverse).
    """
    n = mat.n
    counts = np.diff(mat.rowptr) - 1          # off-diagonals per U row
    ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts[::-1], out=ptr[1:])      # node k == U row n-1-k
    # U row i holds the diag first, then cols j > i ascending; under the
    # reversal the entry (i, j) becomes edge src n-1-j of node n-1-i, so a
    # stable sort by (node, src) yields the per-node-ascending edge order.
    off = np.ones(mat.nnz, dtype=bool)
    off[mat.rowptr[:-1]] = False              # drop the leading diagonals
    node = n - 1 - np.repeat(np.arange(n, dtype=np.int64), counts + 1)[off]
    srcs = n - 1 - mat.colidx[off]
    order = np.argsort(node * n + srcs, kind="stable")
    src = srcs[order]
    weight = mat.values[off][order]
    scale = (1.0 / mat.diag())[::-1]
    perm = np.arange(n - 1, -1, -1, dtype=np.int64)
    dag = ComputeDag(name=f"{mat.name}+rev", n=n, ptr=ptr, src=src,
                     weight=weight, scale=scale)
    return dag, perm


def lower_transpose(mat: TriCSR) -> tuple[ComputeDag, np.ndarray]:
    """Lower the transpose solve Lᵀx=b; returns (dag, perm) as above."""
    return lower_upper(transpose_upper(mat))
