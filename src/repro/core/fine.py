"""Fine-dataflow (DPU-v2-style) cycle model — the paper's main baseline.

DPU-v2 (paper §II-C / Fig. 3) converts the coarse DAG into a *binary* DAG:
row i with k off-diagonal inputs becomes k multiply leaves + a cascade of
accumulate nodes + one final update, i.e. 2k+1 binary nodes (Table III's
"binary nodes" column = 2*nnz - n).  The binary DAG is mapped onto
tree-shaped PE arrays; whenever a node's cascade exceeds the tree depth the
partial result is written back to the register files (costing the pipeline +
RF round-trip that Fig. 3 and the Fig. 6 example charge at ~2 cycles per
tree-block plus one).

Model (matching the paper's own Fig. 6 accounting, documented in
DESIGN.md §5):
  * the machine has ``num_pes`` PEs organised as ``num_trees`` trees of depth
    ``tree_depth`` (DPU-v2 default: 56 PEs, 8 trees of 7 PEs / depth 3);
  * each tree executes one *block* (a ≤(2^depth - 1)-op fragment of one
    coarse node's binary cascade) per ``block_ii`` cycles (initiation
    interval, 1 with perfect pipelining — we use 2 per the Fig. 6 example);
  * a block may only launch once its input blocks / source nodes completed
    ``rf_latency`` cycles earlier (register-file round trip);
  * DPU-v2 runs at 2x our clock with 1-op PEs vs our 2-op PEs (paper §V-A),
    so reported *effective* cycles at the common 150 MHz clock = cycles / 2.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .csr import TriCSR

__all__ = ["FineConfig", "FineStats", "schedule_fine"]


@dataclasses.dataclass(frozen=True)
class FineConfig:
    num_pes: int = 56
    tree_depth: int = 3
    block_ii: int = 2      # cycles per tree-block launch (Fig. 6: 9 blocks/19 cyc)
    rf_latency: int = 1    # extra cycles when a value crosses blocks via the RF
    clock_ratio: float = 2.0  # DPU-v2 clock vs ours (300 MHz vs 150 MHz)

    @property
    def num_trees(self) -> int:
        return max(1, self.num_pes // (2 ** self.tree_depth - 1))

    @property
    def block_ops(self) -> int:
        return 2 ** self.tree_depth - 1


@dataclasses.dataclass
class FineStats:
    name: str
    n: int
    nnz: int
    binary_nodes: int
    blocks: int
    raw_cycles: int           # at the 2x clock
    effective_cycles: float   # normalized to the common clock

    def throughput_gops(self, clock_mhz: float = 150.0) -> float:
        flops = 2 * self.nnz - self.n
        return flops * (clock_mhz * 1e6) / self.effective_cycles / 1e9


def schedule_fine(mat: TriCSR, cfg: FineConfig | None = None) -> FineStats:
    """List-schedule the binary DAG onto the tree machine; return cycle count.

    Blocks per coarse node i with k inputs: ceil(2k+1 ops / block_ops), in a
    sequential cascade (each block consumes the previous block's partial sum
    — Fig. 3: a 4-input node on a depth-2 tree needs 4 mappings).  Block b of
    node i is ready when block b-1 finished (+rf_latency) and the source
    values consumed by its leaves are available.
    """
    cfg = cfg or FineConfig()
    n = mat.n
    solve_t = np.zeros(n, dtype=np.int64)  # completion cycle of x_i
    # per-tree next-free cycle, as a heap for earliest-available tree
    trees = [0] * cfg.num_trees
    heapq.heapify(trees)
    total_blocks = 0
    # process nodes in topological (row) order; list scheduling with the
    # earliest-ready block first is approximated by row order + readiness.
    for i in range(n):
        cols, _ = mat.row(i)
        srcs = cols[:-1]
        k = len(srcs)
        n_ops = 2 * k + 1
        n_blocks = max(1, -(-n_ops // cfg.block_ops))
        # leaves per block: assign sources to blocks round-robin in order
        per_block = max(1, -(-k // n_blocks)) if k else 0
        prev_done = 0
        for blk in range(n_blocks):
            lo = blk * per_block
            hi = min(k, (blk + 1) * per_block)
            src_ready = int(solve_t[srcs[lo:hi]].max()) + cfg.rf_latency if hi > lo else 0
            chain_ready = prev_done + (cfg.rf_latency if blk else 0)
            tree_free = heapq.heappop(trees)
            start = max(src_ready, chain_ready, tree_free)
            done = start + cfg.block_ii
            heapq.heappush(trees, done)
            prev_done = done
            total_blocks += 1
        solve_t[i] = prev_done
    raw = int(solve_t.max())
    return FineStats(
        name=mat.name,
        n=n,
        nnz=mat.nnz,
        binary_nodes=mat.binary_nodes,
        blocks=total_blocks,
        raw_cycles=raw,
        effective_cycles=raw / cfg.clock_ratio,
    )
