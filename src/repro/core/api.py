"""Public API of the SpTRSV core library.

    from repro.core import api
    mat = api.matrix("ckt_add20")
    prog = api.compile(mat)                      # medium dataflow, ICR, psum
    x = api.solve(prog, b)                       # JAX executor
    X = api.solve_batch(prog, B_matrix)          # many RHS, one stream pass
    solver = api.make_solver(prog, batch=32)     # cached jitted closure
    api.report(prog)                             # paper metrics

Batched multi-RHS execution: the compiled VLIW program depends only on L,
so one pass over the instruction stream can solve many right-hand sides at
once (`solve_batch`, or `solve` with a 2-D ``b``).  Executors are cached
per (program identity, padded batch width) — see ``executor.pad_batch`` —
so repeated solves never retrace or recompile.

Multi-device execution: pass ``mesh=`` (a `jax.sharding.Mesh`, e.g.
`shard.batch_mesh()`) to `solve_batch` / `make_solver` to shard the RHS
columns over devices — the instruction stream is replicated, each device
solves its own column block (`repro.core.shard`), and executors are cached
per (program, padded per-device width, mesh).
"""

from __future__ import annotations

import numpy as np

from . import matrices
from .csr import TriCSR, random_rhs, serial_solve
from .dag import DagInfo, analyze
from .executor import (
    as_batch,
    execute_jax,
    execute_numpy,
    make_jax_executor,
    make_pallas_executor,
    validate_backend,
)
from .fine import FineConfig, FineStats, schedule_fine
from .program import AccelConfig, Program
from .schedule import compile_program

__all__ = [
    "matrix",
    "compile",
    "solve",
    "solve_batch",
    "make_solver",
    "solve_numpy",
    "reference_solve",
    "report",
    "AccelConfig",
    "Program",
    "TriCSR",
    "DagInfo",
]


def matrix(name: str) -> TriCSR:
    return matrices.generate(name)


def compile(mat: TriCSR, cfg: AccelConfig | None = None) -> Program:  # noqa: A001
    return compile_program(mat, cfg)


def solve(prog: Program, b: np.ndarray) -> np.ndarray:
    """Solve Lx=b with the cached JAX executor.

    ``b`` may be ``[n]`` or ``[n, B]``; 2-D input delegates to the batched
    path (one instruction-stream pass for all B columns).
    """
    return execute_jax(prog, b)


def solve_batch(prog: Program, b_matrix: np.ndarray, mesh=None,
                backend: str = "jax", **backend_opts) -> np.ndarray:
    """Solve Lx=b for every column of ``b_matrix`` (shape ``[n, B]``).

    One pass over the compiled instruction stream solves all B right-hand
    sides; the batch axis is padded to a lane-friendly width and the jitted
    executor is cached per (program, padded width), so repeated calls —
    including nearby batch sizes — never retrace.  A 1-D ``b`` is treated
    as ``B=1`` and returns shape ``[n, 1]``.

    ``mesh=`` (a `jax.sharding.Mesh`) shards the B columns over devices:
    the instruction stream is replicated and each device solves its own
    column block (`repro.core.shard.make_sharded_solver`), cached per
    (program, padded per-device width, mesh).

    ``backend="pallas"`` solves through the TPU kernel (see `make_solver`
    for the placement knobs, including the HBM-resident row-blocked
    large-n path).
    """
    validate_backend(backend, backend_opts)
    bmat, _ = as_batch(b_matrix)
    if mesh is not None or backend != "jax":
        solver = make_solver(prog, batch=bmat.shape[1], mesh=mesh,
                             backend=backend, **backend_opts)
        return np.asarray(solver(bmat))
    return execute_jax(prog, bmat)


def make_solver(prog: Program, batch: int | None = None, mesh=None,
                backend: str = "jax", **backend_opts):
    """Return a cached jitted solve closure for `prog`.

    * ``batch=None`` — `solver(b[n]) -> x[n]`;
    * ``batch=B``    — `solver(b[n, B]) -> x[n, B]` (batched multi-RHS);
    * ``batch=B, mesh=m`` — as above with the B columns sharded over the
      devices of `jax.sharding.Mesh` ``m`` (instruction stream replicated,
      no collectives; see `repro.core.shard`).

    ``backend="pallas"`` executes through the TPU kernel instead of the
    `lax.scan` program; extra keywords are the kernel knobs
    (``cycles_per_block``, ``placement`` in {"auto", "resident",
    "blocked"}, ``vmem_limit_bytes``, ``x_block_rows``, ``interpret`` —
    see `executor.make_pallas_executor`).  The ``placement="blocked"`` /
    auto-over-threshold regime keeps x and b HBM-resident with a sliding
    VMEM row window, lifting the VMEM cap on solvable n (DESIGN.md §1).

    The closure reuses the per-program executor cache: building it twice
    (or solving repeatedly) costs one trace total per padded batch width —
    per (padded per-device width, mesh) on the sharded path, per (padded
    width + placement knobs) on the pallas backend.
    """
    validate_backend(backend, backend_opts)
    if mesh is not None:
        if batch is None:
            raise ValueError("mesh= requires an explicit batch size")
        from .shard import make_sharded_solver

        return make_sharded_solver(prog, batch, mesh, backend=backend,
                                   **backend_opts)
    if backend == "pallas":
        return make_pallas_executor(prog, batch=batch, **backend_opts)
    return make_jax_executor(prog, batch=batch)


def solve_numpy(prog: Program, b: np.ndarray) -> np.ndarray:
    """Reference numpy executor; accepts ``[n]`` or ``[n, B]`` like `solve`."""
    return execute_numpy(prog, b)


def reference_solve(mat: TriCSR, b: np.ndarray) -> np.ndarray:
    return serial_solve(mat, b)


def report(prog: Program) -> dict:
    st, cfg = prog.stats, prog.config
    out = {
        "name": st.name,
        "n": st.n,
        "nnz": st.nnz,
        "cycles": st.cycles,
        "throughput_gops": round(st.throughput_gops(cfg), 3),
        "peak_gops": round(st.peak_throughput_gops(cfg), 3),
        "pe_utilization": round(st.utilization(), 4),
        "load_balance_cv_pct": round(st.load_balance_cv(), 1),
        "compile_s": round(st.compile_seconds, 4),
        "dm_escapes": st.dm_escapes,
        **{k: round(v, 4) for k, v in st.nop_breakdown().items()},
        "constraints": st.constraints,
        "conflicts": st.conflicts,
        "reuse_events": st.reuse_events,
    }
    return out


def compile_split(mat: TriCSR, cfg: AccelConfig | None = None,
                  max_indegree: int = 64):
    """Beyond-paper path: split heavy nodes (core.transform), then compile.

    Returns (program, split_result); solve with `solve_split`, which
    accepts single (``[n]``) and batched (``[n, B]``) right-hand sides.
    """
    from .transform import split_heavy_nodes

    split = split_heavy_nodes(mat, max_indegree=max_indegree)
    return compile_program(split.mat, cfg), split


def solve_split(prog: Program, split, b: np.ndarray, mesh=None,
                backend: str = "jax", **backend_opts) -> np.ndarray:
    """Solve through a node-splitting transform; ``b`` is ``[n]`` or ``[n, B]``.

    `SplitResult.expand_rhs` / `extract` preserve a trailing batch axis, so
    node splitting composes with the batched executors, with the
    multi-device sharded path (``mesh=``), and with the Pallas kernel's
    placements (``backend="pallas"`` + `make_solver` knobs, including the
    row-blocked large-n regime).
    """
    eb = split.expand_rhs(np.asarray(b))
    if mesh is not None or backend != "jax":
        x = solve_batch(prog, eb, mesh=mesh, backend=backend, **backend_opts)
        return split.extract(x[:, 0] if eb.ndim == 1 else x)
    return split.extract(execute_jax(prog, eb))


def baseline_coarse(mat: TriCSR, base: AccelConfig | None = None) -> Program:
    cfg = base or AccelConfig()
    import dataclasses

    return compile_program(
        mat, dataclasses.replace(cfg, dataflow="coarse", icr=False, psum_cache=False)
    )


def baseline_fine(mat: TriCSR, cfg: FineConfig | None = None) -> FineStats:
    return schedule_fine(mat, cfg)
