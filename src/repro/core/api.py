"""Public API of the SpTRSV core library.

    from repro.core import api
    mat = api.matrix("ckt_add20")
    prog = api.compile(mat)                      # medium dataflow, ICR, psum
    x = api.solve(prog, b)                       # JAX executor
    X = api.solve_batch(prog, B_matrix)          # many RHS, one stream pass
    solver = api.make_solver(prog, batch=32)     # cached jitted closure
    api.report(prog)                             # paper metrics

DAG-workload frontends (DESIGN.md §6): the compiler is a staged pipeline
over a generic compute-DAG IR, so SpTRSV-like workloads beyond Lx=b
compile to the same `Program` format and run on every executor:

    cw = api.compile_upper(U)                    # Ux=b (UpperCSR)
    x = cw.solve(b)                              # or api.solve_upper(cw, b)
    pair = api.compile_pair(L)                   # Ly=b then Lᵀx=y (IC sweep)
    x = pair.solve(b)
    cw = api.compile_circuit(circ)               # general DAG circuit
    y = cw.solve(u)

Batched multi-RHS execution: the compiled VLIW program depends only on L,
so one pass over the instruction stream can solve many right-hand sides at
once (`solve_batch`, or `solve` with a 2-D ``b``).  Executors are cached
per (program identity, padded batch width) — see ``executor.pad_batch`` —
so repeated solves never retrace or recompile.

Multi-device execution: pass ``mesh=`` (a `jax.sharding.Mesh`, e.g.
`shard.batch_mesh()`) to `solve_batch` / `make_solver` to shard the RHS
columns over devices — the instruction stream is replicated, each device
solves its own column block (`repro.core.shard`), and executors are cached
per (program, padded per-device width, mesh).

Hardened solve path (DESIGN.md §7): `save_program` / `load_program`
round-trip a compiled `Program` through the versioned, CRC32-checksummed
on-disk format (`core.serialize`) — a damaged blob raises
`ProgramCorruptionError`, never executes; `verify_program` structurally
validates any in-memory program; `robust_solver` wraps `make_solver` with
input/output health checks and the graceful-degradation backend ladder
(`core.robust.RobustSolver`):

    api.save_program(prog, "ckt.prog")
    prog = api.load_program("ckt.prog")          # CRC + structural verify
    solver = api.robust_solver(prog, mat)        # checked, self-degrading
    x = solver(b)                                # solver.last_incidents

Production serving (DESIGN.md §9): `make_service` fronts the stack with
a continuous micro-batching solve service over a multi-tenant LRU
program cache (structure-only pattern fingerprints, CRC-verified disk
tier, injectable-clock bucket/deadline scheduling — `core.serve`):

    svc = api.make_service({"ckt": mat}, max_batch=16, max_delay=2e-3)
    t = svc.submit("ckt", b)                     # SolveTicket
    svc.drain();  x = t.result()                 # svc.stats / svc.cache

Static analysis (DESIGN.md §8): every compile entry point takes
``verify_ir=True`` to run the per-pass IR contract verifiers between
pipeline stages (a broken invariant raises `errors.IRValidationError`
naming the guilty pass); `analyze_program` runs the full hazard detector
plus performance linter over a compiled program and returns a structured
`analysis.AnalysisReport` (``python -m scripts.lint_program`` is the CLI):

    prog = api.compile(mat, verify_ir=True)      # per-pass contracts
    report = api.analyze_program(prog)           # hazards + SPT2xx lints
    print(report.render())                       # or report.to_json()
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import matrices
from .compiler import ComputeDag, compile_dag as _compile_dag
from .csr import (  # noqa: F401  (random_rhs re-exported for callers)
    TriCSR,
    UpperCSR,
    random_rhs,
    serial_solve,
    transpose_upper,
)
from .dag import DagInfo, analyze  # noqa: F401  (analyze is public API)
from .executor import (
    as_batch,
    execute_jax,
    execute_numpy,
    make_jax_executor,
    make_pallas_executor,
    validate_backend,
)
from .fine import FineConfig, FineStats, schedule_fine
from .frontends.dagcirc import DagCircuit, lower_circuit
from .frontends.upper import lower_upper
from .program import AccelConfig, Program
from .schedule import compile_program

__all__ = [
    "matrix",
    "compile",
    "recompile_values",
    "compile_dag",
    "compile_upper",
    "compile_pair",
    "compile_circuit",
    "solve",
    "solve_batch",
    "solve_upper",
    "solve_pair",
    "make_solver",
    "make_service",
    "solve_numpy",
    "reference_solve",
    "report",
    "save_program",
    "load_program",
    "verify_program",
    "analyze_program",
    "robust_solver",
    "AccelConfig",
    "Program",
    "CompiledWorkload",
    "SolvePair",
    "TriCSR",
    "UpperCSR",
    "DagInfo",
]


def matrix(name: str) -> TriCSR:
    return matrices.generate(name)


def compile(mat: TriCSR, cfg: AccelConfig | None = None, *,  # noqa: A001
            schedule: str = "paper",
            verify_ir: bool = False) -> Program:
    """Compile ``mat``; ``schedule="auto"`` picks the predicted-cheapest
    scheduler strategy per matrix (`compiler.strategies`, DESIGN.md §11)."""
    return compile_program(mat, cfg, schedule=schedule, verify_ir=verify_ir)


def recompile_values(prog: Program, mat: TriCSR) -> Program:
    """Values-only recompilation for factorization loops (DESIGN.md §10).

    ``mat`` must share the compiled program's sparsity pattern; the
    schedule is reused and only the value stream regathers through the
    program's provenance plane — a *new* `Program` (executor caches key
    on identity), bit-identical to a full recompile, at a fraction of
    the cost.  Raises ``ValueError`` on a pattern mismatch or a program
    serialized before provenance existed (run `compile` instead).
    """
    from .schedule import recompile_values as _recompile

    return _recompile(prog, mat)


def solve(prog: Program, b: np.ndarray) -> np.ndarray:
    """Solve Lx=b with the cached JAX executor.

    ``b`` may be ``[n]`` or ``[n, B]``; 2-D input delegates to the batched
    path (one instruction-stream pass for all B columns).
    """
    return execute_jax(prog, b)


def solve_batch(prog: Program, b_matrix: np.ndarray, mesh=None,
                backend: str = "jax", **backend_opts) -> np.ndarray:
    """Solve Lx=b for every column of ``b_matrix`` (shape ``[n, B]``).

    One pass over the compiled instruction stream solves all B right-hand
    sides; the batch axis is padded to a lane-friendly width and the jitted
    executor is cached per (program, padded width), so repeated calls —
    including nearby batch sizes — never retrace.  A 1-D ``b`` is treated
    as ``B=1`` and returns shape ``[n, 1]``.

    ``mesh=`` (a `jax.sharding.Mesh`) shards the B columns over devices:
    the instruction stream is replicated and each device solves its own
    column block (`repro.core.shard.make_sharded_solver`), cached per
    (program, padded per-device width, mesh).

    ``backend="pallas"`` solves through the TPU kernel (see `make_solver`
    for the placement knobs, including the HBM-resident row-blocked
    large-n path).
    """
    validate_backend(backend, backend_opts)
    bmat, _ = as_batch(b_matrix)
    if mesh is not None or backend != "jax":
        solver = make_solver(prog, batch=bmat.shape[1], mesh=mesh,
                             backend=backend, **backend_opts)
        return np.asarray(solver(bmat))
    return execute_jax(prog, bmat)


def make_solver(prog: Program, batch: int | None = None, mesh=None,
                backend: str = "jax", **backend_opts):
    """Return a cached jitted solve closure for `prog`.

    * ``batch=None`` — `solver(b[n]) -> x[n]`;
    * ``batch=B``    — `solver(b[n, B]) -> x[n, B]` (batched multi-RHS);
    * ``batch=B, mesh=m`` — as above with the B columns sharded over the
      devices of `jax.sharding.Mesh` ``m`` (instruction stream replicated,
      no collectives; see `repro.core.shard`).

    ``backend="pallas"`` executes through the TPU kernel instead of the
    `lax.scan` program; extra keywords are the kernel knobs
    (``cycles_per_block``, ``placement`` in {"auto", "resident",
    "blocked"}, ``vmem_limit_bytes``, ``x_block_rows``, ``interpret`` —
    see `executor.make_pallas_executor`).  The ``placement="blocked"`` /
    auto-over-threshold regime keeps x and b HBM-resident with a sliding
    VMEM row window, lifting the VMEM cap on solvable n (DESIGN.md §1).

    The closure reuses the per-program executor cache: building it twice
    (or solving repeatedly) costs one trace total per padded batch width —
    per (padded per-device width, mesh) on the sharded path, per (padded
    width + placement knobs) on the pallas backend.
    """
    validate_backend(backend, backend_opts)
    if mesh is not None:
        if batch is None:
            raise ValueError("mesh= requires an explicit batch size")
        from .shard import make_sharded_solver

        return make_sharded_solver(prog, batch, mesh, backend=backend,
                                   **backend_opts)
    if backend == "pallas":
        return make_pallas_executor(prog, batch=batch, **backend_opts)
    return make_jax_executor(prog, batch=batch)


# ---------------------------------------------------------------------------
# DAG-workload frontends (DESIGN.md §6): upper / transpose / circuit solves
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class CompiledWorkload:
    """A compiled frontend workload: `Program` + internal↔user index map.

    Frontends whose internal node numbering differs from the user's
    unknowns (e.g. the reversed upper-triangular solve) carry ``perm``:
    internal node ``k`` solves user unknown ``perm[k]``, so the program
    consumes ``b[perm]`` and its solution scatters back through ``perm``.
    ``perm=None`` means the identity (lower-tri, circuits).

    `solve` accepts ``[n]`` or ``[n, B]`` right-hand sides and runs any
    executor: ``backend`` in {"numpy", "jax", "pallas"} plus the usual
    batching/sharding/placement knobs of `solve_batch` — the emitted
    `Program` format is unchanged, so every execution path works on every
    frontend workload.
    """

    program: Program
    perm: np.ndarray | None = None
    name: str = ""

    def solve(self, b: np.ndarray, *, backend: str = "jax", mesh=None,
              **backend_opts) -> np.ndarray:
        b = np.asarray(b)
        single = b.ndim == 1
        bi = b[self.perm] if self.perm is not None else b
        if backend == "numpy":
            if mesh is not None or backend_opts:
                raise ValueError("backend='numpy' takes no mesh/extra options")
            xi = execute_numpy(self.program, bi)
        elif backend == "jax" and mesh is None and not backend_opts:
            xi = execute_jax(self.program, bi)
        else:
            bmat, _ = as_batch(bi)
            xi = solve_batch(self.program, bmat, mesh=mesh, backend=backend,
                             **backend_opts)
            if single:
                xi = xi[:, 0]
        if self.perm is None:
            return xi
        x = np.empty_like(xi)
        x[self.perm] = xi
        return x


@dataclasses.dataclass(eq=False)
class SolvePair:
    """Forward+backward sweep pair: Ly=b then Lᵀx=y from ONE factor L.

    One incomplete-Cholesky preconditioner application is
    ``x = Lᵀ \\ (L \\ b)``; `compile_pair` compiles both sweeps once and
    this object replays them per application (any backend/mesh knobs are
    shared by both sweeps).
    """

    forward: CompiledWorkload   # Ly=b (identity perm)
    backward: CompiledWorkload  # Lᵀx=y (reversed node order)

    def solve(self, b: np.ndarray, **opts) -> np.ndarray:
        return self.backward.solve(self.forward.solve(b, **opts), **opts)


def compile_dag(dag: ComputeDag, cfg: AccelConfig | None = None, *,
                planes: int | None = None,
                schedule: str = "paper",
                verify_ir: bool = False) -> Program:
    """Compile a generic `compiler.ComputeDag` through the staged pipeline.

    ``schedule`` picks the schedule pass — ``"paper"``, an alternative
    strategy name, or ``"auto"`` for per-matrix cost-model selection
    (DESIGN.md §11).  ``verify_ir=True`` runs the per-pass contract
    verifiers between stages (`core/analysis/`) and raises
    `errors.IRValidationError` naming the guilty pass on the first broken
    invariant.
    """
    return _compile_dag(dag, cfg, planes=planes, schedule=schedule,
                        verify_ir=verify_ir)


def compile_upper(mat: UpperCSR, cfg: AccelConfig | None = None, *,
                  planes: int | None = None,
                  schedule: str = "paper",
                  verify_ir: bool = False) -> CompiledWorkload:
    """Compile the upper-triangular solve Ux=b (CSC-row reversal frontend)."""
    dag, perm = lower_upper(mat)
    return CompiledWorkload(_compile_dag(dag, cfg, planes=planes,
                                         schedule=schedule,
                                         verify_ir=verify_ir),
                            perm=perm, name=mat.name)


def compile_pair(mat: TriCSR, cfg: AccelConfig | None = None, *,
                 planes: int | None = None,
                 schedule: str = "paper",
                 verify_ir: bool = False) -> SolvePair:
    """Compile the forward (Ly=b) + backward (Lᵀx=y) sweep pair of ``mat``."""
    fwd = CompiledWorkload(compile_program(mat, cfg, planes=planes,
                                           schedule=schedule,
                                           verify_ir=verify_ir),
                           name=mat.name)
    bwd = compile_upper(transpose_upper(mat), cfg, planes=planes,
                        schedule=schedule, verify_ir=verify_ir)
    return SolvePair(forward=fwd, backward=bwd)


def compile_circuit(circ: DagCircuit, cfg: AccelConfig | None = None, *,
                    planes: int | None = None,
                    schedule: str = "paper",
                    verify_ir: bool = False) -> CompiledWorkload:
    """Compile a general DAG circuit (`frontends.dagcirc`) workload."""
    return CompiledWorkload(_compile_dag(lower_circuit(circ), cfg,
                                         planes=planes, schedule=schedule,
                                         verify_ir=verify_ir),
                            name=circ.name)


def solve_upper(cw: CompiledWorkload | UpperCSR, b: np.ndarray,
                **opts) -> np.ndarray:
    """Solve Ux=b; accepts a `CompiledWorkload` (preferred — reuses the
    compile) or a raw `UpperCSR` (compiled ad hoc)."""
    if isinstance(cw, UpperCSR):
        cw = compile_upper(cw)
    return cw.solve(b, **opts)


def solve_pair(pair: SolvePair, b: np.ndarray, **opts) -> np.ndarray:
    """Run one forward+backward preconditioner application through `pair`."""
    return pair.solve(b, **opts)


def save_program(prog: Program, path) -> None:
    """Persist a compiled program in the checksummed on-disk format
    (`core.serialize`, DESIGN.md §7) for compile-once/serve-many reuse."""
    from .serialize import save_program as _save

    _save(prog, path)


def load_program(path, *, verify: bool = True) -> Program:
    """Load a program saved by `save_program`; CRC mismatches and (with
    ``verify=True``) structural violations raise `ProgramCorruptionError`."""
    from .serialize import load_program as _load

    return _load(path, verify=verify)


def verify_program(prog: Program) -> None:
    """Structurally validate a compiled program (`core.robust`); raises
    `ProgramCorruptionError` on the first violated invariant."""
    from .robust import verify_program as _verify

    _verify(prog)


def analyze_program(prog: Program, *, lint: bool = True):
    """Full static analysis of a compiled program (`core.analysis`).

    Returns an `analysis.AnalysisReport`: correctness diagnostics (the
    same hazard checks `verify_program` raises on, collected instead of
    raised) plus, with ``lint=True``, the SPT2xx performance lints.
    ``report.ok()`` is True when no error-severity diagnostic was found;
    ``report.render()`` / ``report.to_json()`` are the two renderers the
    `scripts/lint_program.py` CLI exposes.
    """
    from .analysis import analyze_program as _analyze

    return _analyze(prog, lint=lint)


def robust_solver(prog: Program, mat: TriCSR | None = None, **opts):
    """Health-checked solve closure with graceful degradation.

    Returns a `core.robust.RobustSolver` — callable like the `make_solver`
    closures (``solver(b)`` with ``b`` of shape ``[n]`` or ``[n, B]``) but
    with input validation, output health checks (non-finite x, relative
    residual against ``mat`` when retained), and the deterministic
    fallback ladder pallas-blocked → pallas-resident → jax → numpy →
    reference with machine-readable incident records (DESIGN.md §7).
    """
    from .robust import RobustSolver

    return RobustSolver(prog, mat, **opts)


def make_service(matrices=None, *, capacity: int = 32, disk_dir=None,
                 max_batch: int = 16, max_delay: float = 1e-3,
                 clock=None, timer=None, cfg: AccelConfig | None = None,
                 schedule: str = "paper", backend: str = "jax", mesh=None,
                 resilience=None, **backend_opts):
    """Build a production solve service (`core.serve`, DESIGN.md §9).

    Returns a `serve.SolveService` over a fresh `serve.ProgramCache`
    (bounded LRU of ``capacity`` programs keyed by the structure-only
    `serve.pattern_fingerprint`; ``disk_dir=`` adds the CRC-verified disk
    tier that rehydrates evicted entries through `save_program` /
    `load_program` instead of recompiling).  ``matrices`` is an optional
    ``{matrix_id: TriCSR}`` dict to register up front; more tenants can
    join later via ``service.register``.

    Requests stream in through ``service.submit(matrix_id, b)`` (``b`` of
    shape ``[n]`` or ``[n, k]``) and micro-batch per matrix into the
    padded widths the batched executor cache keys on; a bucket flushes at
    ``max_batch`` columns or when its oldest column ages past
    ``max_delay`` seconds (checked by ``service.pump()`` /
    at the next submit; ``service.drain()`` flushes everything).  The
    scheduling core runs entirely on the injectable ``clock`` — here, and
    only here, a missing clock defaults to the wall
    (``time.monotonic``); construct `serve.SolveService` directly (or
    pass a `serve.ManualClock`) for deterministic tests.

    ``backend`` / ``mesh`` / ``backend_opts`` choose the execution path
    per `make_solver` ("numpy", "jax", "pallas" resident/blocked, mesh
    sharding), shared by every flush.

    ``resilience`` (a `resilience.ResilienceConfig`, DESIGN.md §10) arms
    the resilient flush path: per-request deadlines
    (``submit(..., deadline=|timeout=)``), retry with deterministic
    backoff through the PR-6 backend ladder, per-(matrix, rung) circuit
    breakers, admission-control load shedding, and the unified SPT3xx
    incident report (``service.report()``).  A production resilience
    config usually passes ``sleep=time.sleep`` so backoff really waits;
    the default config never sleeps (virtual-clock friendly).
    """
    from . import serve

    if clock is None:
        import time

        clock = time.monotonic
    cache = serve.ProgramCache(capacity=capacity, disk_dir=disk_dir, cfg=cfg,
                               schedule=schedule)
    svc = serve.SolveService(cache, max_batch=max_batch,
                             max_delay=max_delay, clock=clock, timer=timer,
                             backend=backend, mesh=mesh,
                             resilience=resilience, **backend_opts)
    for mid, m in (matrices or {}).items():
        svc.register(mid, m)
    return svc


def solve_numpy(prog: Program, b: np.ndarray) -> np.ndarray:
    """Reference numpy executor; accepts ``[n]`` or ``[n, B]`` like `solve`."""
    return execute_numpy(prog, b)


def reference_solve(mat: TriCSR, b: np.ndarray) -> np.ndarray:
    return serial_solve(mat, b)


def report(prog: Program) -> dict:
    st, cfg = prog.stats, prog.config
    out = {
        "name": st.name,
        "n": st.n,
        "nnz": st.nnz,
        # which scheduler strategy produced this program (DESIGN.md §11);
        # auto compiles also expose the per-candidate predictions
        "schedule": getattr(st, "schedule", "paper"),
        "cycles": st.cycles,
        # packed-encoding accounting (PR 4) — benchmark CSVs and docs read
        # these here instead of recomputing them from the Program by hand
        "emitted_cycles": st.emitted_cycles,
        "planes": prog.planes,
        "instr_bytes": prog.instr_bytes(),
        "throughput_gops": round(st.throughput_gops(cfg), 3),
        "peak_gops": round(st.peak_throughput_gops(cfg), 3),
        "pe_utilization": round(st.utilization(), 4),
        "load_balance_cv_pct": round(st.load_balance_cv(), 1),
        "compile_s": round(st.compile_seconds, 4),
        "dm_escapes": st.dm_escapes,
        **{k: round(v, 4) for k, v in st.nop_breakdown().items()},
        "constraints": st.constraints,
        "conflicts": st.conflicts,
        "reuse_events": st.reuse_events,
    }
    if getattr(st, "schedule_costs", None):
        out["schedule_costs"] = st.schedule_costs
    return out


def compile_split(mat: TriCSR, cfg: AccelConfig | None = None,
                  max_indegree: int = 64):
    """Beyond-paper path: split heavy nodes (core.transform), then compile.

    Returns (program, split_result); solve with `solve_split`, which
    accepts single (``[n]``) and batched (``[n, B]``) right-hand sides.
    """
    from .transform import split_heavy_nodes

    split = split_heavy_nodes(mat, max_indegree=max_indegree)
    return compile_program(split.mat, cfg), split


def solve_split(prog: Program, split, b: np.ndarray, mesh=None,
                backend: str = "jax", **backend_opts) -> np.ndarray:
    """Solve through a node-splitting transform; ``b`` is ``[n]`` or ``[n, B]``.

    `SplitResult.expand_rhs` / `extract` preserve a trailing batch axis, so
    node splitting composes with the batched executors, with the
    multi-device sharded path (``mesh=``), and with the Pallas kernel's
    placements (``backend="pallas"`` + `make_solver` knobs, including the
    row-blocked large-n regime).
    """
    eb = split.expand_rhs(np.asarray(b))
    if mesh is not None or backend != "jax":
        x = solve_batch(prog, eb, mesh=mesh, backend=backend, **backend_opts)
        return split.extract(x[:, 0] if eb.ndim == 1 else x)
    return split.extract(execute_jax(prog, eb))


def baseline_coarse(mat: TriCSR, base: AccelConfig | None = None) -> Program:
    cfg = base or AccelConfig()
    import dataclasses

    return compile_program(
        mat, dataclasses.replace(cfg, dataflow="coarse", icr=False, psum_cache=False)
    )


def baseline_fine(mat: TriCSR, cfg: FineConfig | None = None) -> FineStats:
    return schedule_fine(mat, cfg)
