"""Sharded, atomic, async checkpointing with restart/resume semantics.

Layout:  <dir>/step_<n>/host_<h>.npz  + <dir>/step_<n>/COMMITTED
  * every host writes only the addressable shards it owns (multi-host safe);
  * a step directory is valid iff the COMMITTED marker exists (atomic rename
    of a tmp dir -> crash-safe partial writes are ignored on restore);
  * `CheckpointManager` runs saves on a background thread (training never
    blocks on I/O), keeps the newest `keep` checkpoints, and `latest_step`
    drives restart-after-failure (see distributed.fault_tolerance).

Arrays are flattened by pytree path into .npz entries; restore rebuilds
into an example pytree (shape/dtype-checked).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import numpy as np

import jax

__all__ = ["save_checkpoint", "restore_checkpoint", "CheckpointManager"]


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(
    directory: str,
    step: int,
    tree,
    host_id: int = 0,
    num_hosts: int = 1,
    meta: dict | None = None,
) -> str:
    """Write this host's shard of `tree` for `step`, atomically."""
    final = os.path.join(directory, f"step_{step:08d}")
    if num_hosts == 1:
        tmp = final + f".tmp{host_id}"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, f"host_{host_id}.npz"), **_flatten(tree))
        if meta is not None:
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, **meta}, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)  # atomic publish
    else:
        os.makedirs(final, exist_ok=True)
        np.savez(os.path.join(final, f"host_{host_id}.npz"), **_flatten(tree))
        if meta is not None and host_id == 0:
            with open(os.path.join(final, "meta.json"), "w") as f:
                json.dump({"step": step, **meta}, f)
    # commit marker written by host 0 last (multi-host: after a barrier in
    # the launcher; single-host: after the atomic rename above)
    if host_id == 0:
        with open(os.path.join(final, "COMMITTED"), "w") as f:
            f.write(str(step))
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        full = os.path.join(directory, name)
        if name.startswith("step_") and os.path.exists(
            os.path.join(full, "COMMITTED")
        ):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    example_tree,
    step: int | None = None,
    host_id: int = 0,
):
    """Restore into the structure of `example_tree`; returns (tree, step).

    `step=None` restores the newest COMMITTED checkpoint; returns
    (example_tree, None) when nothing is available (fresh start).
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        return example_tree, None
    path = os.path.join(directory, f"step_{step:08d}", f"host_{host_id}.npz")
    data = np.load(path)
    flat_paths = jax.tree_util.tree_flatten_with_path(example_tree)
    leaves = []
    for pth, leaf in flat_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(flat_paths[1], leaves)
    return tree, step


class CheckpointManager:
    """Async save + retention, non-blocking for the train loop."""

    def __init__(self, directory: str, keep: int = 3,
                 host_id: int = 0, num_hosts: int = 1):
        self.directory = directory
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def save_async(self, step: int, tree, meta: dict | None = None) -> None:
        self.wait()  # at most one in-flight save
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device

        def work():
            save_checkpoint(
                self.directory, step, host_tree, self.host_id,
                self.num_hosts, meta,
            )
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def restore(self, example_tree, step: int | None = None):
        return restore_checkpoint(self.directory, example_tree, step, self.host_id)

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and "." not in n
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )
