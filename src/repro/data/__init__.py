"""Data substrate."""

from .pipeline import SyntheticLMDataset, make_train_iterator  # noqa: F401
