"""Deterministic synthetic LM data pipeline (host-sharded, prefetching).

Offline container => no real corpora; the pipeline synthesizes a *learnable*
token stream (orderk-Markov chains with per-document transition tables) so
training loss decreases measurably — needed for the end-to-end example run.

Production shape: each host materializes only its shard of the global batch
(`host_slice`), batches are indexed by step for exact restart reproducibility
(checkpoint stores only the step counter), and a background thread prefetches.
"""

from __future__ import annotations

import queue
import threading

import numpy as np

__all__ = ["SyntheticLMDataset", "make_train_iterator"]


class SyntheticLMDataset:
    """Step-indexed, deterministic, host-shardable synthetic corpus."""

    def __init__(
        self,
        vocab: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        n_tables: int = 8,
        branch: int = 4,
    ):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        rng = np.random.default_rng(seed)
        # per-"document class" sparse Markov transitions: each token has
        # `branch` plausible successors -> cross-entropy floor ~= log(branch)
        self.tables = rng.integers(
            0, vocab, size=(n_tables, vocab, branch), dtype=np.int32
        )

    def batch(self, step: int, host_id: int = 0, num_hosts: int = 1) -> dict:
        """Materialize this host's slice of global batch `step`."""
        assert self.global_batch % num_hosts == 0
        per_host = self.global_batch // num_hosts
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + host_id
        )
        toks = np.empty((per_host, self.seq_len + 1), dtype=np.int32)
        table_ids = rng.integers(0, len(self.tables), size=per_host)
        toks[:, 0] = rng.integers(0, self.vocab, size=per_host)
        choices = rng.integers(0, self.tables.shape[-1],
                               size=(per_host, self.seq_len))
        for t in range(self.seq_len):
            toks[:, t + 1] = self.tables[
                table_ids, toks[:, t], choices[:, t]
            ]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_train_iterator(
    dataset: SyntheticLMDataset,
    start_step: int = 0,
    host_id: int = 0,
    num_hosts: int = 1,
    prefetch: int = 2,
):
    """Background-thread prefetching iterator, resumable at `start_step`."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(
                    (step, dataset.batch(step, host_id, num_hosts)), timeout=0.5
                )
                step += 1
            except queue.Full:
                continue

    th = threading.Thread(target=worker, daemon=True)
    th.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
