"""Mixture-of-Experts FFN with dp-grouped scatter dispatch (EP-shardable).

Communication-aware formulation (see EXPERIMENTS.md §Perf, arctic-480b):
tokens are processed in DP groups [G, Tg, d] where G = the data-parallel
world size, so

  * routing, ranking (grouped cumsum) and the dispatch scatter stay LOCAL
    to each data shard — no cross-device movement of activations on the
    dispatch side (a global gather `xt[pairs]` measured 30 GB all-gathers
    per layer on arctic-480b: GSPMD replicates arbitrary gathers over a
    sharded dim);
  * expert buffers [G, E, C, d] are sharded (data, model): the expert GEMMs
    contract against model-sharded expert weights with ZERO weight
    movement;
  * the combine all-gathers the (bf16) expert outputs over the model axis
    once, after which the per-token gather is again local.

Capacity is per (group, expert): C = Tg*k/E * capacity_factor, standard
GShard grouped-drop semantics.  `dropless=True` (decode) sets C = Tg*k.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .layers import RuntimeFlags, init_linear, linear, shard

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key, cfg) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(key, 4)
    n_in = 3 if cfg.mlp == "swiglu" else 2
    p = {
        "router": init_linear(ks[0], d, e, scale=0.02),
        "w1": jax.random.normal(ks[1], (e, d, ff), jnp.float32) * d ** -0.5,
        "w2": jax.random.normal(ks[2], (e, ff, d), jnp.float32) * ff ** -0.5,
    }
    if n_in == 3:
        p["w3"] = jax.random.normal(ks[3], (e, d, ff), jnp.float32) * d ** -0.5
    return p


def _dp_groups(flags: RuntimeFlags, t: int) -> int:
    if flags.mesh is None:
        return 1
    g = int(np.prod([flags.mesh.shape[a] for a in flags.dp]))
    return g if t % g == 0 else 1


def moe_ffn(p, x: jnp.ndarray, cfg, flags: RuntimeFlags | None = None,
            dropless: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    t = b * s
    fl = flags or RuntimeFlags()
    g = _dp_groups(fl, t)
    tg = t // g

    xt = shard(x.reshape(g, tg, d), fl, "dp", None, None)
    logits = linear(p["router"], xt).astype(jnp.float32)       # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # [G, Tg, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros(e, jnp.float32).at[top_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    cap = tg * k if dropless else int(max(1, tg * k / e * cfg.capacity_factor))

    eid = top_e.reshape(g, tg * k)                             # k-minor pairs
    wts = top_p.reshape(g, tg * k)
    onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)           # [G, Tg*k, E]
    rank = jnp.cumsum(onehot, axis=1) - onehot                 # grouped rank
    slot = jnp.take_along_axis(rank, eid[..., None], axis=-1)[..., 0]
    keep = slot < cap
    slot_t = jnp.where(keep, slot, cap)                        # cap == trash
    xrep = jnp.repeat(xt, k, axis=1)                           # [G, Tg*k, d]
    xrep = jnp.where(keep[..., None], xrep, 0).astype(x.dtype)

    w1 = p["w1"].astype(x.dtype)
    w2 = p["w2"].astype(x.dtype)
    w3 = p.get("w3")
    w3 = w3.astype(x.dtype) if w3 is not None else None

    if g > 1:
        # EXPLICIT expert parallelism via shard_map: GSPMD cannot derive
        # the MoE movement pattern from scatter/gather ops — every jnp-level
        # formulation we measured replicated activations (30 GB+ all-gathers
        # per layer on arctic-480b).  Device (i, j) owns dp-group i and the
        # j-th expert slice: dispatch scatter and expert GEMMs are fully
        # LOCAL; the only communication is one bf16 all-gather of expert
        # outputs over the model axis (its transpose is a reduce-scatter).
        out = _moe_shard_map(
            fl, xrep, eid, slot_t, keep, wts, w1, w2, w3, cap, cfg.mlp, tg, k
        )
    else:
        buf = jnp.zeros((e, cap + 1, d), x.dtype)
        buf = buf.at[eid[0], slot_t[0]].add(xrep[0], mode="drop")
        ye = _expert_ffn(buf, w1, w2, w3, cfg.mlp)
        y = ye[eid[0], slot_t[0]].astype(jnp.float32) * wts[0][:, None]
        y = jnp.where(keep[0][:, None], y, 0.0)
        out = y.reshape(1, tg, k, d).sum(axis=2)

    return out.reshape(b, s, d).astype(x.dtype), aux


def _expert_ffn(buf, w1, w2, w3, kind):
    """buf: [E_local, C, d] -> [E_local, C, d]; plain batched GEMMs."""
    h1 = jnp.einsum("ecd,edf->ecf", buf, w1)
    if w3 is not None and kind == "swiglu":
        h = jax.nn.silu(h1) * jnp.einsum("ecd,edf->ecf", buf, w3)
    else:
        h = jax.nn.gelu(h1)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _moe_shard_map(fl, xrep, eid, slot_t, keep, wts, w1, w2, w3, cap, kind,
                   tg, k):
    from jax.sharding import PartitionSpec as P

    mesh = fl.mesh
    dp = tuple(fl.dp)
    d = xrep.shape[-1]
    e = w1.shape[0]
    e_loc = e // int(mesh.shape["model"])

    def body(xrep_l, eid_l, slot_l, keep_l, wts_l, w1_l, w2_l, w3_l):
        # shapes: xrep_l [1, Tg*k, d]; w*_l [e_loc, ...]
        j = jax.lax.axis_index("model")
        e0 = j * e_loc
        mine = (eid_l[0] >= e0) & (eid_l[0] < e0 + e_loc) & keep_l[0]
        el = jnp.where(mine, eid_l[0] - e0, 0)
        sl = jnp.where(mine, slot_l[0], cap)
        buf = jnp.zeros((e_loc, cap + 1, d), xrep_l.dtype)
        buf = buf.at[el, sl].add(
            jnp.where(mine[:, None], xrep_l[0], 0), mode="drop"
        )
        ye = _expert_ffn(buf, w1_l, w2_l, w3_l, kind)
        ye_all = jax.lax.all_gather(ye, "model", axis=0, tiled=True)
        y = ye_all[eid_l[0], slot_l[0]].astype(jnp.float32)
        y = jnp.where(keep_l[0][:, None], y * wts_l[0][:, None], 0.0)
        return y.reshape(1, tg, k, d).sum(axis=2)

    args = [xrep, eid, slot_t, keep, wts, w1, w2]
    specs = [P(dp, None, None), P(dp, None), P(dp, None), P(dp, None),
             P(dp, None), P("model", None, None), P("model", None, None)]
    if w3 is not None:
        args.append(w3)
        specs.append(P("model", None, None))
    else:
        args.append(jnp.zeros((e, 0, 0), xrep.dtype))
        specs.append(P("model", None, None))

    from jax.experimental.shard_map import shard_map

    fn = shard_map(
        lambda *a: body(*a[:7], a[7] if w3 is not None else None),
        mesh=mesh, in_specs=tuple(specs), out_specs=P(dp, None, None),
        check_rep=False,
    )
    return fn(*args)
