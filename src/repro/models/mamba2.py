"""Mamba2 (SSD) block — executed with the medium-granularity chunked scan.

The SSD recurrence h_t = exp(a_t) h_{t-1} + b_t x_t is a unit-bidiagonal
SpTRSV (DESIGN.md §1); the chunked execution in `repro.kernels.ssd_scan`
is the paper's dataflow: chunk = coarse allocation, intra-chunk matmuls =
fine edge computation, carried chunk state = psum feedback.

Structure per block (simplified faithful Mamba2):
  in_proj -> [z (gate), xBC, dt]; depthwise causal conv on xBC; split into
  x (per-head values), B (input proj of state), C (output proj); per-head
  scalar decay a = -softplus(dt + bias) * A; y = SSD(x, B, C, a); gated
  RMSNorm; out_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ops import linear_recurrence

from .layers import RuntimeFlags, init_linear, linear, rms_norm, shard

__all__ = ["init_mamba2", "mamba2_block", "mamba2_decode", "init_mamba2_state"]


def _dims(cfg):
    d_inner = 2 * cfg.d_model
    nh = cfg.ssm_heads
    hd = d_inner // nh             # value head dim
    ds = cfg.ssm_state             # state width per head (key dim)
    return d_inner, nh, hd, ds


def init_mamba2(key, cfg) -> dict:
    d = cfg.d_model
    d_inner, nh, hd, ds = _dims(cfg)
    ks = jax.random.split(key, 6)
    conv_ch = d_inner + 2 * nh * ds
    return {
        "in_proj": init_linear(ks[0], d, 2 * d_inner + 2 * nh * ds + nh),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32) * 0.2,
        "a_log": jnp.zeros((nh,), jnp.float32),       # log A (per head)
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_g": jnp.ones((d_inner,), jnp.float32),
        "out_proj": init_linear(ks[2], d_inner, d, scale=d_inner ** -0.5),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: [B, L, C]; w: [K, C]."""
    kw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (kw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
        for i in range(kw)
    )
    return jax.nn.silu(out), xp[:, -(kw - 1):, :] if kw > 1 else None


def _split(p, cfg, u):
    d_inner, nh, hd, ds = _dims(cfg)
    zxbcdt = linear(p["in_proj"], u)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : 2 * d_inner + 2 * nh * ds]
    dt = zxbcdt[..., -nh:]
    return z, xbc, dt


def mamba2_block(
    p, u: jnp.ndarray, cfg, flags: RuntimeFlags,
    conv_state=None, ssm_state=None,
) -> tuple[jnp.ndarray, tuple]:
    """u: [B, L, d] -> (out [B, L, d], (conv_state, ssm_state))."""
    b, l, _ = u.shape
    d_inner, nh, hd, ds = _dims(cfg)
    z, xbc, dt = _split(p, cfg, u)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], conv_state)
    x = xbc[..., :d_inner].reshape(b, l, nh, hd)
    bmat = xbc[..., d_inner : d_inner + nh * ds].reshape(b, l, nh, ds)
    cmat = xbc[..., d_inner + nh * ds :].reshape(b, l, nh, ds)
    # head sharding happens on the merged B*H dim inside linear_recurrence
    # (zamba2's 40 heads don't divide a 16-way model axis; B*H always does)

    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # [B,L,nh]
    a = -jnp.exp(p["a_log"])[None, None, :] * dt_s                     # log-decay
    w = jnp.broadcast_to(a[..., None], (b, l, nh, ds))                 # per-key

    # discretized input: x_bar = dt * x ; recurrence S += (B dt x)
    k_in = bmat
    v_in = x * dt_s[..., None].astype(x.dtype)
    y, ssm_state = linear_recurrence(
        cmat, k_in, v_in, w, s0=ssm_state,
        chunk=flags.ssm_chunk, inclusive=True,
        use_pallas=flags.use_pallas, interpret=flags.interpret, flags=flags,
    )
    y = y + x * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, l, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_g"], cfg.norm_eps)
    return linear(p["out_proj"], y), (conv_state, ssm_state)


def init_mamba2_state(cfg, batch: int, dtype=jnp.float32):
    d_inner, nh, hd, ds = _dims(cfg)
    conv_ch = d_inner + 2 * nh * ds
    return (
        jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        jnp.zeros((batch, nh, ds, hd), jnp.float32),
    )


def mamba2_decode(p, u, cfg, flags, conv_state, ssm_state):
    """Single-step decode: u [B, 1, d]; O(1) state update."""
    return mamba2_block(p, u, cfg, flags, conv_state, ssm_state)
