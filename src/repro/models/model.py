"""Model zoo assembly: init / train_forward / prefill / decode_step per family.

Families: dense, moe, vlm, encdec (transformer machinery) and ssm, hybrid
(recurrent machinery on the medium-granularity chunked scan).

All stacks use `lax.scan` over layer-stacked parameter pytrees so the HLO
stays compact for the 512-device dry-run; per-layer activation
checkpointing (`flags.remat`) keeps training memory at O(sqrt-ish).

Modality frontends are STUBS per the assignment: `encdec` consumes
precomputed frame embeddings, `vlm` consumes precomputed patch embeddings
(see launch/dryrun.py `input_specs`).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import mamba2 as m2
from . import rwkv6 as rw
from .layers import (
    RuntimeFlags,
    attention,
    attention_decode,
    init_attention,
    init_embedding,
    init_linear,
    init_mlp,
    linear,
    mlp,
    rms_norm,
    shard,
)
from .moe import init_moe, moe_ffn

__all__ = ["init_params", "train_forward", "prefill", "decode_step",
           "init_cache", "RuntimeFlags"]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


# =====================================================================
# init
# =====================================================================
def _init_dense_layer(cfg):
    def go(key):
        ka, km = jax.random.split(key)
        return {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_attention(ka, cfg),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": init_mlp(km, cfg),
        }

    return go


def _init_moe_layer(cfg):
    def go(key):
        ka, km, kd = jax.random.split(key, 3)
        p = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_attention(ka, cfg),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "moe": init_moe(km, cfg),
        }
        if cfg.moe_dense_residual:
            p["dense_mlp"] = init_mlp(kd, cfg)
        return p

    return go


def init_params(key, cfg: ModelConfig) -> dict:
    kemb, klay, kout, kx1, kx2 = jax.random.split(key, 5)
    params: dict[str, Any] = {
        "emb": init_embedding(kemb, cfg.vocab, cfg.d_model),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(kout, cfg.d_model, cfg.vocab, scale=0.02)

    if cfg.family == "dense":
        params["layers"] = _stack_init(_init_dense_layer(cfg), klay, cfg.n_layers)
    elif cfg.family == "moe":
        params["layers"] = _stack_init(_init_moe_layer(cfg), klay, cfg.n_layers)
    elif cfg.family == "vlm":
        g = cfg.cross_attn_every
        ng, per = cfg.n_layers // g, g - 1
        def grp(key):
            k1, k2 = jax.random.split(key)
            return {
                "self": _stack_init(_init_dense_layer(cfg), k1, per),
                "cross": _init_dense_layer(cfg)(k2),
            }
        params["groups"] = _stack_init(grp, klay, ng)
        params["vis_proj"] = init_linear(kx1, cfg.vision_dim, cfg.d_model)
    elif cfg.family == "encdec":
        def dec_layer(key):
            k1, k2, k3 = jax.random.split(key, 3)
            p = _init_dense_layer(cfg)(k1)
            p["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["cross"] = init_attention(k2, cfg)
            return p
        params["enc_layers"] = _stack_init(_init_dense_layer(cfg), kx1, cfg.enc_layers)
        params["enc_ln_f"] = jnp.ones((cfg.d_model,), jnp.float32)
        params["layers"] = _stack_init(dec_layer, klay, cfg.n_layers)
    elif cfg.family == "ssm":
        def rwkv_layer(key):
            k1, k2 = jax.random.split(key)
            return {
                "ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "time": rw.init_rwkv_time_mix(k1, cfg),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32),
                "chan": rw.init_rwkv_channel_mix(k2, cfg),
            }
        params["layers"] = _stack_init(rwkv_layer, klay, cfg.n_layers)
    elif cfg.family == "hybrid":
        g = cfg.hybrid_attn_every
        ng, per = cfg.n_layers // g, g
        def mamba_layer(key):
            return {
                "ln": jnp.ones((cfg.d_model,), jnp.float32),
                "mamba": m2.init_mamba2(key, cfg),
            }
        def grp(key):
            k1 = key
            return {"mamba": _stack_init(mamba_layer, k1, per)}
        params["groups"] = _stack_init(grp, klay, ng)
        params["shared"] = _init_dense_layer(cfg)(kx2)  # ONE shared block
    else:
        raise ValueError(cfg.family)
    return params


# =====================================================================
# forward blocks
# =====================================================================
def _dense_block(lp, x, cfg, flags, positions=None, kv_x=None, causal=True,
                 use_rope=True):
    h, kv = attention(
        lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, flags,
        positions=positions, kv_x=kv_x, causal=causal, use_rope=use_rope,
    )
    x = x + h
    x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg.mlp, flags)
    return x, kv


def _moe_block(lp, x, cfg, flags):
    h, kv = attention(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, flags)
    x = x + h
    z = rms_norm(x, lp["ln2"], cfg.norm_eps)
    mo, aux = moe_ffn(lp["moe"], z, cfg, flags)
    if cfg.moe_dense_residual:
        mo = mo + mlp(lp["dense_mlp"], z, cfg.mlp, flags)
    return x + mo, kv, aux


def _maybe_remat(fn, flags):
    return jax.checkpoint(fn) if flags.remat else fn


def _backbone(params, x, cfg, flags: RuntimeFlags, collect_cache=False):
    """Run the family backbone over a full sequence.

    Returns (hidden, cache_pytree, aux_loss).
    """
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense",):
        def blk(x, lp):
            y, kv = _dense_block(lp, x, cfg, flags)
            return y, (kv if collect_cache else None)
        x, caches = jax.lax.scan(_maybe_remat(blk, flags), x, params["layers"])
        return x, {"kv": caches}, aux_total

    if cfg.family == "moe":
        def blk(x, lp):
            y, kv, aux = _moe_block(lp, x, cfg, flags)
            return y, ((kv if collect_cache else None), aux)
        x, (caches, auxes) = jax.lax.scan(_maybe_remat(blk, flags), x, params["layers"])
        return x, {"kv": caches}, aux_total + auxes.mean()

    if cfg.family == "vlm":
        vis = params["_vis_embed"]  # injected by caller
        def grp(x, gp):
            def blk(x, lp):
                y, kv = _dense_block(lp, x, cfg, flags)
                return y, (kv if collect_cache else None)
            x, self_kv = jax.lax.scan(blk, x, gp["self"])
            y, cross_kv = _dense_block(
                gp["cross"], x, cfg, flags, kv_x=vis, causal=False, use_rope=False
            )
            return y, (self_kv, (cross_kv if collect_cache else None))
        x, (self_caches, cross_caches) = jax.lax.scan(
            _maybe_remat(grp, flags), x, params["groups"]
        )
        return x, {"kv": self_caches, "cross_kv": cross_caches}, aux_total

    if cfg.family == "encdec":
        enc = params["_enc_out"]
        def blk(x, lp):
            h, kv = attention(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, flags
            )
            x = x + h
            h, _ = attention(
                lp["cross"], rms_norm(x, lp["ln_x"], cfg.norm_eps), cfg, flags,
                kv_x=enc, causal=False, use_rope=False,
            )
            x = x + h
            x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg.mlp, flags)
            return x, (kv if collect_cache else None)
        x, caches = jax.lax.scan(_maybe_remat(blk, flags), x, params["layers"])
        return x, {"kv": caches}, aux_total

    if cfg.family == "ssm":
        def blk(x, lp):
            h, (tshift, wkv) = rw.rwkv_time_mix(
                lp["time"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, flags
            )
            x = x + h
            h, cshift = rw.rwkv_channel_mix(
                lp["chan"], rms_norm(x, lp["ln2"], cfg.norm_eps)
            )
            x = x + h
            st = (tshift, wkv, cshift) if collect_cache else None
            return x, st
        x, states = jax.lax.scan(_maybe_remat(blk, flags), x, params["layers"])
        return x, {"state": states}, aux_total

    if cfg.family == "hybrid":
        shared = params["shared"]
        def grp(x, gp):
            def blk(x, lp):
                h, (cst, sst) = m2.mamba2_block(
                    lp["mamba"], rms_norm(x, lp["ln"], cfg.norm_eps), cfg, flags
                )
                return x + h, ((cst, sst) if collect_cache else None)
            x, states = jax.lax.scan(blk, x, gp["mamba"])
            y, kv = _dense_block(shared, x, cfg, flags)
            return y, (states, (kv if collect_cache else None))
        x, (states, kv) = jax.lax.scan(_maybe_remat(grp, flags), x, params["groups"])
        return x, {"state": states, "kv": kv}, aux_total

    raise ValueError(cfg.family)


def _embed(params, tokens, cfg):
    x = params["emb"]["emb"][tokens].astype(_dtype(cfg))
    return x


def _unembed(params, x, cfg, flags=None):
    fl = flags or RuntimeFlags()
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["emb"]["emb"].T.astype(x.dtype)
    else:
        logits = linear(params["lm_head"], x)
    # vocab-sharded logits: the softmax/logsumexp reduces locally then
    # all-reduces a [B, S] scalar field instead of materializing [B, S, V]
    logits = shard(logits, fl, "dp", None, "model")
    return logits.astype(jnp.float32)


def _run_frontends(params, cfg, flags, extra, batch):
    """Inject stubbed modality embeddings into the param pytree (as consts)."""
    params = dict(params)
    if cfg.family == "vlm":
        vis = extra["vision"].astype(_dtype(cfg))
        params["_vis_embed"] = linear(params["vis_proj"], vis)
    if cfg.family == "encdec":
        frames = extra["frames"].astype(_dtype(cfg))
        def eblk(x, lp):
            y, _ = _dense_block(lp, x, cfg, flags, causal=False, use_rope=True)
            return y, None
        enc, _ = jax.lax.scan(eblk, frames, params["enc_layers"])
        params["_enc_out"] = rms_norm(enc, params["enc_ln_f"], cfg.norm_eps)
    return params


# =====================================================================
# public entry points
# =====================================================================
def train_forward(
    params, tokens, labels, cfg: ModelConfig, flags: RuntimeFlags,
    extra: dict | None = None,
) -> tuple[jnp.ndarray, dict]:
    """tokens/labels: [B, S] int32.  Returns (loss, metrics)."""
    params = _run_frontends(params, cfg, flags, extra or {}, tokens.shape[0])
    x = _embed(params, tokens, cfg)
    x, _, aux = _backbone(params, x, cfg, flags, collect_cache=False)
    logits = _unembed(params, x, cfg, flags)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold).mean()
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux,
                  "ppl": jnp.exp(jnp.clip(nll, max=20.0))}


def prefill(
    params, tokens, cfg: ModelConfig, flags: RuntimeFlags,
    extra: dict | None = None, pad_to: int | None = None,
):
    """Full-sequence forward collecting decode state.  Returns (logits, cache).

    For attention families the KV cache is padded to `pad_to` so decode can
    append; recurrent families return O(1) states.
    """
    params = _run_frontends(params, cfg, flags, extra or {}, tokens.shape[0])
    x = _embed(params, tokens, cfg)
    x, cache, _ = _backbone(params, x, cfg, flags, collect_cache=True)
    logits = _unembed(params, x[:, -1:], cfg, flags)

    if pad_to is not None and "kv" in cache and cache["kv"] is not None:
        seq = tokens.shape[1]
        def pad_kv(kv):
            pad = pad_to - seq
            # kv: [..., B, S, H, D] (scan-stacked leading axes)
            pads = [(0, 0)] * (kv.ndim - 3) + [(0, pad), (0, 0), (0, 0)]
            return jnp.pad(kv, pads)
        cache["kv"] = jax.tree.map(pad_kv, cache["kv"])
    cache["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
    if cfg.family == "vlm":
        cache["_vis_embed"] = params["_vis_embed"]
    if cfg.family == "encdec":
        cache["_enc_out"] = params["_enc_out"]
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """Empty decode cache (for decode-from-scratch / dry-run serve_step)."""
    dt = dtype or _dtype(cfg)
    hd, hkv = cfg.hd, cfg.n_kv_heads
    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    kv = lambda n: {
        "k": jnp.zeros((n, batch, max_seq, hkv, hd), dt),
        "v": jnp.zeros((n, batch, max_seq, hkv, hd), dt),
    }
    if cfg.family in ("dense", "moe", "encdec"):
        cache["kv"] = kv(cfg.n_layers)
    if cfg.family == "encdec":
        cache["_enc_out"] = jnp.zeros((batch, cfg.enc_frames, cfg.d_model), dt)
    if cfg.family == "vlm":
        ng, per = cfg.n_layers // cfg.cross_attn_every, cfg.cross_attn_every - 1
        cache["kv"] = jax.tree.map(
            lambda a: a.reshape(ng, per, *a.shape[1:]), kv(ng * per)
        )
        cache["cross_kv"] = {
            "k": jnp.zeros((ng, batch, cfg.vision_tokens, hkv, hd), dt),
            "v": jnp.zeros((ng, batch, cfg.vision_tokens, hkv, hd), dt),
        }
    if cfg.family == "ssm":
        t, w, c = rw.init_rwkv_state(cfg, batch, dt)
        st = lambda a: jnp.broadcast_to(a[None], (cfg.n_layers, *a.shape))
        cache["state"] = (st(t), st(w), st(c))
    if cfg.family == "hybrid":
        ng, per = cfg.n_layers // cfg.hybrid_attn_every, cfg.hybrid_attn_every
        cst, sst = m2.init_mamba2_state(cfg, batch, dt)
        st = lambda a: jnp.broadcast_to(a[None, None], (ng, per, *a.shape))
        cache["state"] = (st(cst), st(sst))
        cache["kv"] = jax.tree.map(
            lambda a: a.reshape(ng, *a.shape[1:]), kv(ng)
        )
    return cache


def decode_step(
    params, token, cache, cfg: ModelConfig, flags: RuntimeFlags,
):
    """One-token decode. token: [B, 1] int32. Returns (logits, new_cache)."""
    pos = cache["pos"]
    x = _embed(params, token, cfg)
    new_cache = dict(cache)

    if cfg.family in ("dense", "moe"):
        def blk(x, lp_kv):
            lp, kv = lp_kv
            h, kv = attention_decode(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), kv, pos, cfg
            )
            x = x + h
            if cfg.family == "moe":
                z = rms_norm(x, lp["ln2"], cfg.norm_eps)
                mo, _ = moe_ffn(lp["moe"], z, cfg, flags, dropless=True)
                if cfg.moe_dense_residual:
                    mo = mo + mlp(lp["dense_mlp"], z, cfg.mlp, flags)
                x = x + mo
            else:
                x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg.mlp, flags)
            return x, kv
        x, kv = jax.lax.scan(blk, x, (params["layers"], cache["kv"]))
        new_cache["kv"] = kv

    elif cfg.family == "vlm":
        vis_kv = cache["cross_kv"]
        def grp(x, gkv):
            gp, kv, ckv = gkv
            def blk(x, lp_kv):
                lp, kv = lp_kv
                h, kv = attention_decode(
                    lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), kv, pos, cfg
                )
                x = x + h
                x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg.mlp, flags)
                return x, kv
            x, kv = jax.lax.scan(blk, x, (gp["self"], kv))
            lp = gp["cross"]
            h, _ = attention_decode(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), ckv,
                jnp.asarray(cfg.vision_tokens - 1, jnp.int32), cfg,
                update_cache=False,
            )
            x = x + h
            x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg.mlp, flags)
            return x, kv
        x, kv = jax.lax.scan(grp, x, (params["groups"], cache["kv"], vis_kv))
        new_cache["kv"] = kv

    elif cfg.family == "encdec":
        enc = cache["_enc_out"]
        def blk(x, lp_kv):
            lp, kv = lp_kv
            h, kv = attention_decode(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), kv, pos, cfg
            )
            x = x + h
            h, _ = attention(
                lp["cross"], rms_norm(x, lp["ln_x"], cfg.norm_eps), cfg, flags,
                kv_x=enc, causal=False, use_rope=False,
            )
            x = x + h
            x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg.mlp, flags)
            return x, kv
        x, kv = jax.lax.scan(blk, x, (params["layers"], cache["kv"]))
        new_cache["kv"] = kv

    elif cfg.family == "ssm":
        def blk(x, lp_st):
            lp, (tsh, wkv, csh) = lp_st
            h, (tsh, wkv) = rw.rwkv_time_mix(
                lp["time"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, flags,
                shift_state=tsh, wkv_state=wkv,
            )
            x = x + h
            h, csh = rw.rwkv_channel_mix(
                lp["chan"], rms_norm(x, lp["ln2"], cfg.norm_eps), shift_state=csh
            )
            return x + h, (tsh, wkv, csh)
        x, state = jax.lax.scan(blk, x, (params["layers"], cache["state"]))
        new_cache["state"] = state

    elif cfg.family == "hybrid":
        shared = params["shared"]
        def grp(x, gp_st):
            gp, (cst, sst), kv = gp_st
            def blk(x, lp_st):
                lp, (c1, s1) = lp_st
                h, (c1, s1) = m2.mamba2_decode(
                    lp["mamba"], rms_norm(x, lp["ln"], cfg.norm_eps), cfg, flags,
                    c1, s1,
                )
                return x + h, (c1, s1)
            x, st = jax.lax.scan(blk, x, (gp["mamba"], (cst, sst)))
            h, kv = attention_decode(
                shared["attn"], rms_norm(x, shared["ln1"], cfg.norm_eps), kv, pos, cfg
            )
            x = x + h
            x = x + mlp(shared["mlp"], rms_norm(x, shared["ln2"], cfg.norm_eps), cfg.mlp, flags)
            return x, (st, kv)
        x, (state, kv) = jax.lax.scan(
            grp, x, (params["groups"], cache["state"], cache["kv"])
        )
        new_cache["state"] = state
        new_cache["kv"] = kv
    else:
        raise ValueError(cfg.family)

    new_cache["pos"] = pos + 1
    logits = _unembed(params, x, cfg, flags)
    return logits, new_cache
