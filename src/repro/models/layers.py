"""Shared neural building blocks (pure-JAX, pytree params, init/apply pairs).

Conventions:
  * params are nested dicts of jnp arrays; init functions take a PRNG key
    and return the pytree; apply functions are pure;
  * compute dtype comes from the config (bf16 on TPU); params are stored in
    f32 and cast at use ("master weights" live in the optimizer state);
  * layers are written to be stacked with `jax.lax.scan` over a leading
    layer axis (homogeneous stacks compile to compact HLO — essential for
    the 512-device dry-run).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import gqa_attention

__all__ = [
    "RuntimeFlags",
    "rms_norm",
    "layer_norm",
    "init_linear",
    "linear",
    "init_embedding",
    "rope",
    "init_attention",
    "attention",
    "attention_decode",
    "init_mlp",
    "mlp",
]


@dataclasses.dataclass(frozen=True)
class RuntimeFlags:
    """Execution-path switches threaded through every model."""

    use_pallas: bool = False      # pallas kernels (TPU prod / interpret tests)
    # None = auto: native compile on TPU, interpreter elsewhere
    # (kernels.common.default_interpret — same convention as every kernel)
    interpret: bool | None = None
    remat: bool = True            # activation checkpointing per layer
    attn_block_q: int = 512       # flash attention tiles
    # 4096 is the measured memory-term balance for the 32k prefill cells
    # (bigger blocks = fewer online-softmax carry round-trips; EXPERIMENTS
    # §Perf starcoder2 iteration); the Pallas kernel uses its own VMEM tile
    attn_block_k: int = 4096
    # medium-granularity scan chunk; 512 is the measured roofline balance
    # point on the train_4k cells (EXPERIMENTS.md §Perf, zamba2 iteration)
    ssm_chunk: int = 512
    # distribution: set by the launchers.  GSPMD does NOT propagate the
    # model axis through scan-over-layers reliably (measured 16x redundant
    # compute without these) — so blocks place explicit constraints.
    mesh: object = None           # jax.sharding.Mesh | None
    dp: tuple = ("data",)         # data-parallel axis names ('pod','data')


def shard(x: jnp.ndarray, flags: "RuntimeFlags", *spec) -> jnp.ndarray:
    """with_sharding_constraint when a mesh is configured; no-op otherwise.

    `spec` entries: "dp" expands to flags.dp; None / "model" pass through.
    """
    if flags.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    expanded = tuple(flags.dp if s == "dp" else s for s in spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(flags.mesh, P(*expanded))
    )


def _cast(p, dtype):
    return jax.tree.map(lambda a: a.astype(dtype) if a.dtype == jnp.float32 else a, p)


# ---------------------------------------------------------------- norms
def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(dt)


# ---------------------------------------------------------------- linear
def init_linear(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}


def linear(p, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"].astype(x.dtype)


def init_embedding(key, vocab: int, d: int):
    return {"emb": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


# ---------------------------------------------------------------- rope
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [B, L, H, D]; positions: [B, L] int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, L, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def rope_folded(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding on FOLDED [B*H, L, D] tensors; positions [B*H, L].

    Head-structured elementwise math on [B, L, H, D] replicates whenever H
    doesn't divide the model axis (GSPMD 'involuntary full
    rematerialization', measured as 15 GB all-gathers per layer on
    arctic-480b) — in merged-BH space the sharding is always even.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [Z, L, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
def init_attention(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, scale=(cfg.n_heads * hd) ** -0.5),
    }


def _split_heads(x, n_heads, hd):
    b, l, _ = x.shape
    return x.reshape(b, l, n_heads, hd)


def attention(
    p,
    x: jnp.ndarray,              # [B, L, d]
    cfg,
    flags: RuntimeFlags,
    positions: jnp.ndarray | None = None,
    kv_x: jnp.ndarray | None = None,   # cross-attention source (encoder/vision)
    causal: bool = True,
    use_rope: bool = True,
) -> tuple[jnp.ndarray, dict]:
    """Full-sequence attention (train / prefill).  Returns (out, kv_cache).

    All head-structured math runs in FOLDED [B*H, L, D] space, which shards
    evenly for any head count (DESIGN.md §Perf): fold immediately after the
    projections, RoPE on folded tensors, GQA broadcast in the merged dim,
    unfold only for the output projection and the returned KV cache.
    """
    from repro.kernels.flash_attention.ops import (
        constrain_folded,
        gqa_attention_folded,
    )

    b, l, _ = x.shape
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    src = x if kv_x is None else kv_x
    lk = src.shape[1]
    fold = lambda t, h, ln: (
        t.reshape(b, ln, h, hd).transpose(0, 2, 1, 3).reshape(b * h, ln, hd)
    )
    qf = constrain_folded(fold(linear(p["wq"], x), hq, l), flags, b * hq)
    kf = constrain_folded(fold(linear(p["wk"], src), hkv, lk), flags,
                          b * hkv, is_kv=True)
    vf = constrain_folded(fold(linear(p["wv"], src), hkv, lk), flags,
                          b * hkv, is_kv=True)
    if use_rope and kv_x is None:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(l)[None, :], (b, l))
        posf = lambda h: jnp.broadcast_to(
            positions[:, None, :], (b, h, l)
        ).reshape(b * h, l)
        qf = rope_folded(qf, posf(hq), cfg.rope_theta)
        kf = rope_folded(kf, posf(hkv), cfg.rope_theta)
    of = gqa_attention_folded(
        qf, kf, vf, batch=b,
        causal=causal and kv_x is None,
        use_pallas=flags.use_pallas,
        interpret=flags.interpret,
        block_q=flags.attn_block_q,
        block_k=flags.attn_block_k,
        flags=flags,
    )
    of = constrain_folded(of, flags, b * hq)
    o3 = of.reshape(b, hq, l, hd).transpose(0, 2, 1, 3).reshape(b, l, hq * hd)
    o3 = shard(o3, flags, "dp", None, "model")
    out = linear(p["wo"], o3)
    out = shard(out, flags, "dp", None, None)
    # unfold the (roped) kv for the decode cache
    k4 = kf.reshape(b, hkv, lk, hd).transpose(0, 2, 1, 3)
    v4 = vf.reshape(b, hkv, lk, hd).transpose(0, 2, 1, 3)
    return out, {"k": k4, "v": v4}


def attention_decode(
    p,
    x: jnp.ndarray,        # [B, 1, d]
    cache: dict,           # {"k","v": [B, S, Hkv, D]}
    pos: jnp.ndarray,      # [] int32 — current position
    cfg,
    update_cache: bool = True,
) -> tuple[jnp.ndarray, dict]:
    """Single-token decode against a pre-allocated KV cache."""
    b = x.shape[0]
    hd = cfg.hd
    q = _split_heads(linear(p["wq"], x), cfg.n_heads, hd)
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    if update_cache:
        k_new = _split_heads(linear(p["wk"], x), cfg.n_kv_heads, hd)
        v_new = _split_heads(linear(p["wv"], x), cfg.n_kv_heads, hd)
        k_new = rope(k_new, positions, cfg.rope_theta)
        q = rope(q, positions, cfg.rope_theta)
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=1),
        }
    k, v = cache["k"], cache["v"]
    s_len = k.shape[1]
    group = cfg.n_heads // cfg.n_kv_heads
    kq = jnp.repeat(k, group, axis=2)
    vq = jnp.repeat(v, group, axis=2)
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                        kq.astype(jnp.float32))
    valid = jnp.arange(s_len)[None, None, None, :] <= pos
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, vq.astype(jnp.float32)).astype(x.dtype)
    out = linear(p["wo"], o.reshape(b, 1, cfg.n_heads * hd))
    return out, cache


# ---------------------------------------------------------------- mlp
def init_mlp(key, cfg) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "w1": init_linear(ks[0], d, ff),
            "w3": init_linear(ks[1], d, ff),
            "w2": init_linear(ks[2], ff, d, scale=ff ** -0.5),
        }
    return {
        "w1": init_linear(ks[0], d, ff),
        "w2": init_linear(ks[2], ff, d, scale=ff ** -0.5),
    }


def mlp(p, x: jnp.ndarray, kind: str, flags: RuntimeFlags | None = None) -> jnp.ndarray:
    fl = flags or RuntimeFlags(mesh=None)
    if kind == "swiglu":
        h = jax.nn.silu(linear(p["w1"], x)) * linear(p["w3"], x)
    else:
        h = jax.nn.gelu(linear(p["w1"], x))
    h = shard(h, fl, "dp", None, "model")
    out = linear(p["w2"], h)
    return shard(out, fl, "dp", None, None)
