"""RWKV6 "Finch" block — attention-free, data-dependent per-channel decay.

The WKV recurrence S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T with exclusive
output + u-bonus maps directly onto the medium-granularity chunked scan
(`linear_recurrence(inclusive=False, u_bonus=u)`).

Simplifications vs the released model (documented in DESIGN.md §5): the
low-rank "LoRA" token-shift interpolators are replaced by single learned
mixing coefficients per channel, and the decay LoRA by a direct projection
— the dataflow (token shift -> r/k/v/w/g -> WKV -> gated groupnorm ->
output) and all tensor shapes match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ops import linear_recurrence

from .layers import RuntimeFlags, init_linear, linear, rms_norm, shard

__all__ = [
    "init_rwkv_time_mix", "rwkv_time_mix",
    "init_rwkv_channel_mix", "rwkv_channel_mix",
    "init_rwkv_state",
]


def _dims(cfg):
    nh, ds = cfg.ssm_heads, cfg.ssm_state
    return nh, ds, nh * ds  # heads, key width, inner width (== d_model)


def init_rwkv_time_mix(key, cfg) -> dict:
    d = cfg.d_model
    nh, ds, inner = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "mix": jnp.full((5, d), 0.5, jnp.float32),  # r,k,v,w,g token-shift mixes
        "wr": init_linear(ks[0], d, inner),
        "wk": init_linear(ks[1], d, inner),
        "wv": init_linear(ks[2], d, inner),
        "ww": init_linear(ks[3], d, inner, scale=1e-2),
        "wg": init_linear(ks[4], d, inner),
        "w_bias": jnp.full((inner,), -6.0, jnp.float32),
        "u_bonus": jnp.zeros((nh, ds), jnp.float32),
        "ln_g": jnp.ones((inner,), jnp.float32),
        "wo": init_linear(ks[5], inner, d, scale=inner ** -0.5),
    }


def rwkv_time_mix(
    p, x: jnp.ndarray, cfg, flags: RuntimeFlags,
    shift_state=None, wkv_state=None,
) -> tuple[jnp.ndarray, tuple]:
    """x: [B, L, d] -> (out, (shift_state [B,1,d], wkv_state [B,H,K,V]))."""
    b, l, d = x.shape
    nh, ds, inner = _dims(cfg)
    prev = (
        jnp.zeros((b, 1, d), x.dtype) if shift_state is None
        else shift_state.astype(x.dtype)
    )
    x_prev = jnp.concatenate([prev, x[:, :-1]], axis=1)   # token shift
    mix = p["mix"].astype(x.dtype)
    xs = [x + (x_prev - x) * mix[i][None, None, :] for i in range(5)]
    r = linear(p["wr"], xs[0]).reshape(b, l, nh, ds)
    k = linear(p["wk"], xs[1]).reshape(b, l, nh, ds)
    v = linear(p["wv"], xs[2]).reshape(b, l, nh, ds)
    w_raw = linear(p["ww"], xs[3]).astype(jnp.float32) + p["w_bias"]
    # data-dependent decay in (0, 1): log-decay = -exp(w) (RWKV6 convention)
    w = -jnp.exp(w_raw).reshape(b, l, nh, ds)
    g = jax.nn.silu(linear(p["wg"], xs[4]))

    y, wkv_state = linear_recurrence(
        r, k, v, w, s0=wkv_state, u_bonus=p["u_bonus"],
        chunk=flags.ssm_chunk, inclusive=False,
        use_pallas=flags.use_pallas, interpret=flags.interpret, flags=flags,
    )
    y = y.reshape(b, l, inner)
    y = rms_norm(y, p["ln_g"], cfg.norm_eps) * g
    return linear(p["wo"], y), (x[:, -1:, :], wkv_state)


def init_rwkv_channel_mix(key, cfg) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "mix": jnp.full((2, cfg.d_model), 0.5, jnp.float32),
        "wk": init_linear(ks[0], cfg.d_model, cfg.d_ff),
        "wv": init_linear(ks[1], cfg.d_ff, cfg.d_model, scale=cfg.d_ff ** -0.5),
    }


def rwkv_channel_mix(p, x, shift_state=None):
    b, l, d = x.shape
    prev = (
        jnp.zeros((b, 1, d), x.dtype) if shift_state is None
        else shift_state.astype(x.dtype)
    )
    x_prev = jnp.concatenate([prev, x[:, :-1]], axis=1)
    mix = p["mix"].astype(x.dtype)
    xk = x + (x_prev - x) * mix[0][None, None, :]
    h = jnp.square(jax.nn.relu(linear(p["wk"], xk)))
    return linear(p["wv"], h), x[:, -1:, :]


def init_rwkv_state(cfg, batch: int, dtype=jnp.float32):
    nh, ds, inner = _dims(cfg)
    return (
        jnp.zeros((batch, 1, cfg.d_model), dtype),   # time-mix shift
        jnp.zeros((batch, nh, ds, ds), jnp.float32),  # wkv state
        jnp.zeros((batch, 1, cfg.d_model), dtype),   # channel-mix shift
    )
