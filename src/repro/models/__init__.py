"""Model zoo: composable JAX model definitions for the 10 assigned archs."""

from .layers import RuntimeFlags  # noqa: F401
from .model import (  # noqa: F401
    decode_step,
    init_cache,
    init_params,
    prefill,
    train_forward,
)
