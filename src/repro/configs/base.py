"""Model configuration schema + registry for `--arch <id>` selection."""

from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = ["ModelConfig", "register", "get_config", "list_archs"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    mlp: str = "swiglu"        # swiglu | gelu
    rope_theta: float = 10000.0
    # --- MoE ---
    moe_experts: int = 0
    moe_topk: int = 0
    moe_dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0         # per-head state width (Mamba2 d_state / RWKV key)
    ssm_heads: int = 0
    ssm_conv: int = 4          # depthwise causal conv width (Mamba2)
    hybrid_attn_every: int = 0  # zamba2: shared attn block period (layers/group)
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_frames: int = 1500     # stubbed conv-frontend output length
    # --- VLM (llama-3.2-vision) ---
    cross_attn_every: int = 0  # cross-attn layer period within the decoder
    vision_tokens: int = 1601  # stubbed patch-embedding count per image
    vision_dim: int = 1280     # stubbed frontend embedding width
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    sub_quadratic: bool = False  # supports long_500k shapes

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=256,
            moe_experts=min(self.moe_experts, 4),
            moe_topk=min(self.moe_topk, 2),
            capacity_factor=8.0,  # effectively dropless at smoke-test sizes
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=4 if self.ssm_heads else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_frames=32 if self.enc_layers else 0,
            hybrid_attn_every=3 if self.hybrid_attn_every else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            vision_tokens=16 if self.cross_attn_every else 0,
            vision_dim=32 if self.cross_attn_every else 0,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-flops in the roofline)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd, hq, hkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
        mlp = (3 if self.mlp == "swiglu" else 2) * d * ff
        per_layer = 0
        if self.family in ("dense", "vlm", "encdec"):
            per_layer = attn + mlp
        elif self.family == "moe":
            router = d * self.moe_experts
            per_layer = attn + self.moe_experts * mlp + router
            if self.moe_dense_residual:
                per_layer += mlp
        elif self.family == "ssm":
            k = self.ssm_state
            h = self.ssm_heads
            per_layer = 5 * d * (h * k) + d * ff * 2  # r,k,v,w,g + channel mix
        elif self.family == "hybrid":
            k = self.ssm_state
            nh = self.ssm_heads or self.n_heads
            inner = 2 * d
            per_layer = d * 2 * inner + inner * 2 * nh * k + inner * d + mlp // 4
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = self.n_layers * per_layer + emb
        if self.family == "encdec":
            total += self.enc_layers * (attn + mlp) + self.n_layers * attn  # cross
        if self.family == "vlm" and self.cross_attn_every:
            total += (self.n_layers // self.cross_attn_every) * attn
        if self.family == "hybrid" and self.hybrid_attn_every:
            total += attn + mlp  # one shared block
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        mlp = (3 if self.mlp == "swiglu" else 2) * d * ff
        inactive = self.n_layers * (self.moe_experts - self.moe_topk) * mlp
        return int(self.param_count() - inactive)


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    from . import _load_all  # late import to populate registry

    _load_all()
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    from . import _load_all

    _load_all()
    return sorted(_REGISTRY)
