"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""

from .base import ModelConfig, register


@register("granite-moe-1b-a400m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab=49155,
        mlp="swiglu",
        moe_experts=32,
        moe_topk=8,
    )
