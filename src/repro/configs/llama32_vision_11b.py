"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Transformer BACKBONE only per the assignment: the vision frontend is a stub
(`input_specs()` provides precomputed patch embeddings); every
`cross_attn_every`-th decoder layer cross-attends to them.
"""

from .base import ModelConfig, register


@register("llama-3.2-vision-11b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=128256,
        mlp="swiglu",
        rope_theta=5e5,
        cross_attn_every=5,   # 8 cross-attn layers in 40
        vision_tokens=1601,
        vision_dim=1280,
    )
