"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-135M family; hf] — llama-arch small."""

from .base import ModelConfig, register


@register("smollm-360m")
def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab=49152,
        mlp="swiglu",
        tie_embeddings=True,
    )
