"""StarCoder2-7B [arXiv:2402.19173; hf] — dense GQA+RoPE code LM."""

from .base import ModelConfig, register


@register("starcoder2-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab=49152,
        mlp="gelu",          # starcoder2 uses gelu MLPs
        rope_theta=1e5,
    )
