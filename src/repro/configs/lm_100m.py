"""~100M-param llama-style LM for the end-to-end training example
(examples/train_lm.py).  Not part of the 10 assigned archs."""

from .base import ModelConfig, register


@register("lm-100m")
def config() -> ModelConfig:
    return ModelConfig(
        name="lm-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab=32768,
        mlp="swiglu",
        tie_embeddings=True,
    )
