"""RWKV6 "Finch" 1.6B [arXiv:2404.05892; unverified] — attention-free,
data-dependent decay.  Executed with the medium-granularity chunked scan
(the paper technique's sequence-model instantiation, DESIGN.md §1/§3).
Sub-quadratic: runs the long_500k shapes.
"""

from .base import ModelConfig, register


@register("rwkv6-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        n_layers=24,
        d_model=2048,
        n_heads=0,           # attention-free
        n_kv_heads=0,
        d_ff=7168,
        vocab=65536,
        mlp="gelu",          # channel-mix uses squared-relu; see models/rwkv6
        ssm_state=64,        # per-head key width
        ssm_heads=32,        # d_model / 64
        sub_quadratic=True,
    )
