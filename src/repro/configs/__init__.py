"""Architecture registry: one module per assigned arch (+ the paper's own).

Use `get_config("<arch-id>")` or `--arch <id>` on the launchers.
"""

from .base import ModelConfig, get_config, list_archs, register  # noqa: F401

# the 10 assigned architectures (the dry-run grid); extra registry entries
# (lm-100m, ...) are example/aux configs
ASSIGNED_ARCHS = (
    "starcoder2-7b", "phi3-medium-14b", "smollm-360m", "granite-8b",
    "llama-3.2-vision-11b", "zamba2-2.7b", "rwkv6-1.6b", "whisper-base",
    "granite-moe-1b-a400m", "arctic-480b",
)

_LOADED = False


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        arctic_480b,
        lm_100m,
        granite_8b,
        granite_moe_1b,
        llama32_vision_11b,
        phi3_medium_14b,
        rwkv6_1b6,
        smollm_360m,
        starcoder2_7b,
        whisper_base,
        zamba2_2b7,
    )
