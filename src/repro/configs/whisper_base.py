"""Whisper-base [arXiv:2212.04356; unverified] — enc-dec; conv frontend STUB.

`input_specs()` provides precomputed frame embeddings [B, frames, d_model]
per the assignment; the encoder is bidirectional, the decoder causal with
cross-attention.
"""

from .base import ModelConfig, register


@register("whisper-base")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="encdec",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab=51865,
        mlp="gelu",
        enc_layers=6,
        enc_frames=1500,
    )
