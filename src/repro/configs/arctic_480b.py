"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base; hf].

Dense-MoE hybrid: every layer has a dense residual FFN in PARALLEL with a
128-expert top-2 MoE FFN.
"""

from .base import ModelConfig, register


@register("arctic-480b")
def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab=32000,
        mlp="swiglu",
        moe_experts=128,
        moe_topk=2,
        moe_dense_residual=True,
    )
