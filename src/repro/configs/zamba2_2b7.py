"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attn blocks.

Hybrid: 54 Mamba2 layers; one SHARED transformer block (attention + MLP)
applied every `hybrid_attn_every` layers (Zamba2's weight-shared global
block, simplified: we share the full block weights across its applications;
the per-application LoRA deltas of the original are omitted — DESIGN.md §5).
Sub-quadratic: runs the long_500k shapes.
"""

from .base import ModelConfig, register


@register("zamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab=32000,
        mlp="gelu",
        ssm_state=64,
        ssm_heads=40,        # 2*d_model / headdim=128
        hybrid_attn_every=6, # 9 shared-block applications over 54 layers
        sub_quadratic=True,
    )
