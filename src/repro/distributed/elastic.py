"""Elastic re-meshing: recompute the largest valid mesh after failures.

Policy: the "model" (TP/EP) axis is load-bearing — parameter shards assume
its exact size — so it is preserved; capacity shrinks along the DP axes
("pod" first, then "data").  The returned plan says which mesh to rebuild,
the new global batch (per-replica batch is kept constant), and whether a
checkpoint restore is required (always, after in-flight step loss).
"""

from __future__ import annotations

import dataclasses

__all__ = ["ElasticPlan", "plan_remesh"]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    chips_used: int
    chips_idle: int
    new_global_batch: int
    restore_required: bool = True

    @property
    def dp_size(self) -> int:
        out = 1
        for n, s in zip(self.axis_names, self.mesh_shape):
            if n in ("pod", "data"):
                out *= s
        return out


def plan_remesh(
    healthy_chips: int,
    model_axis: int = 16,
    chips_per_pod: int = 256,
    per_replica_batch: int = 16,
    min_data_axis: int = 1,
) -> ElasticPlan:
    """Largest (pod, data, model) mesh runnable on `healthy_chips`.

    Raises if even a single model-parallel group no longer fits.
    """
    if healthy_chips < model_axis * min_data_axis:
        raise RuntimeError(
            f"cannot re-mesh: {healthy_chips} chips < one model group "
            f"({model_axis})"
        )
    pods = max(1, healthy_chips // chips_per_pod)
    while pods > 1:
        data = chips_per_pod // model_axis
        if pods * data * model_axis <= healthy_chips:
            break
        pods -= 1
    if pods > 1:
        data = chips_per_pod // model_axis
        shape: tuple[int, ...] = (pods, data, model_axis)
        names: tuple[str, ...] = ("pod", "data", "model")
    else:
        data = max(min_data_axis, healthy_chips // model_axis)
        shape = (data, model_axis)
        names = ("data", "model")
    used = 1
    for s in shape:
        used *= s
    dp = used // model_axis
    return ElasticPlan(
        mesh_shape=shape,
        axis_names=names,
        chips_used=used,
        chips_idle=healthy_chips - used,
        new_global_batch=dp * per_replica_batch,
    )
