"""Sharding rules: params, activations, caches (DP / TP / EP / SP + pod axis).

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
  * DP  — batch over ("pod", "data")
  * TP  — attention heads / FFN hidden / vocab over "model" (GSPMD handles
          non-divisible head counts, e.g. starcoder2's 36 heads on 16 ways,
          by padding)
  * EP  — MoE expert axis over "model"
  * SP  — long-context decode (global_batch=1): KV-cache/state *sequence*
          over "data" instead of the unshardable batch axis

Rules are (path-substring, partition-of-trailing-dims) pairs, most specific
first; leading stacked-layer axes are padded with None automatically.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "dp_axes",
    "param_shardings",
    "batch_sharding",
    "cache_shardings",
    "rhs_sharding",
    "with_dp_constraint",
]


def dp_axes(mesh: Mesh):
    """Data-parallel mesh axes (includes 'pod' when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


# (substring, trailing-dims partition) — order matters.
_PARAM_RULES: list[tuple[str, tuple]] = [
    # embeddings / unembedding
    ("emb/emb", ("model", None)),
    ("lm_head/w", (None, "model")),
    ("vis_proj/w", (None, None)),
    # MoE: expert-parallel over model axis
    ("moe/router/w", (None, None)),
    ("moe/w1", ("model", None, None)),
    ("moe/w2", ("model", None, None)),
    ("moe/w3", ("model", None, None)),
    # attention projections (also matches cross/ and shared/ blocks)
    ("wq/w", (None, "model")),
    ("wk/w", (None, "model")),
    ("wv/w", (None, "model")),
    ("wo/w", ("model", None)),
    # RWKV channel-mix reuses wk/wv names but transposed roles
    ("chan/wk/w", (None, "model")),
    ("chan/wv/w", ("model", None)),
    # MLPs
    ("mlp/w1/w", (None, "model")),
    ("mlp/w3/w", (None, "model")),
    ("mlp/w2/w", ("model", None)),
    ("dense_mlp/w1/w", (None, "model")),
    ("dense_mlp/w3/w", (None, "model")),
    ("dense_mlp/w2/w", ("model", None)),
    # Mamba2
    ("in_proj/w", (None, "model")),
    ("out_proj/w", ("model", None)),
    ("conv_w", (None, "model")),
    # RWKV time-mix
    ("time/ww/w", (None, "model")),
    ("time/wr/w", (None, "model")),
    ("time/wg/w", (None, "model")),
    ("time/wo/w", ("model", None)),
]
# NOTE: "chan/wv/w" is shadowed by the generic "wv/w" rule above unless we
# check specific rules first — handled by sorting below.
_PARAM_RULES.sort(key=lambda r: -len(r[0]))


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _spec_for(path: str, ndim: int) -> P:
    for pat, trailing in _PARAM_RULES:
        if pat in path:
            if len(trailing) > ndim:  # scalar-ish leaf
                return P()
            lead = (None,) * (ndim - len(trailing))
            return P(*lead, *trailing)
    return P()  # replicate (norms, biases, scalars)


def spec_fits(mesh: Mesh, shape, spec: P) -> bool:
    """Explicit jit arg shardings require exact divisibility per dim."""
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size != 0:
            return False
    return True


def param_shardings(mesh: Mesh, params):
    """Rule-based shardings with divisibility fallback to replication.

    Fallback examples in the zoo: whisper's 51865 vocab and granite-moe's
    49155 vocab don't divide 16 (replicated embeddings, ~100-200MB);
    mamba2's fused in_proj output (2*d_inner + 2*nh*ds + nh = 15400) is
    deliberately NOT padded — the projection is replicated instead (its
    activations still shard via the merged-B*H constraint downstream).
    """

    def one(path, leaf):
        spec = _spec_for(_path_str(path), leaf.ndim)
        if not spec_fits(mesh, leaf.shape, spec):
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def rhs_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding for an ``[n, B]`` multi-RHS matrix: rows replicated, the B
    column axis over ALL mesh axes.

    The SpTRSV batch axis is embarrassingly parallel (the compiled
    instruction stream depends only on L), so any mesh topology flattens
    into one big batch axis — this is the placement `repro.core.shard` uses
    for the multi-device batched solver.
    """
    return NamedSharding(mesh, P(None, mesh.axis_names))


def batch_sharding(mesh: Mesh, batch_size: int):
    """Sharding for [B, S] token/label arrays."""
    dp = dp_axes(mesh)
    if batch_size % dp_size(mesh) == 0:
        return NamedSharding(mesh, P(dp, None))
    return NamedSharding(mesh, P(None, None))


def _kv_spec(ndim: int, b_ok: bool, dp) -> P:
    """[..., B, S, H, D] KV cache: batch over dp + SEQUENCE over model.

    Sequence-split KV (flash-decoding style) instead of kv-head split: the
    zoo's kv-head counts (4..10) don't divide the 16-way model axis, and
    GSPMD padding would multiply cache memory up to 4x.  The softmax over
    the sharded seq dim reduces with small all-reduces.  When batch doesn't
    divide dp (long_500k, B=1) the sequence shards over ALL axes — pure SP.
    """
    lead = (None,) * (ndim - 4)
    if b_ok:
        return P(*lead, dp, "model", None, None)
    return P(*lead, None, (*dp, "model"), None, None)


def cache_shardings(mesh: Mesh, cfg, cache, batch: int):
    """Shardings for a decode cache pytree built by models.init_cache."""
    dp = dp_axes(mesh)
    b_ok = batch % dp_size(mesh) == 0

    def one(path, leaf):
        path_s = _path_str(path)
        nd = leaf.ndim
        if nd == 0:
            return NamedSharding(mesh, P())
        if "kv/" in path_s or "cross_kv/" in path_s:
            spec = _kv_spec(nd, b_ok, dp)
            if not spec_fits(mesh, leaf.shape, spec):
                # e.g. vlm cross-attn: 1601 vision tokens don't divide the
                # model axis -> keep batch sharding, replicate the rest
                spec = P(*([None] * (nd - 4)), dp if b_ok else None,
                         None, None, None)
            if not spec_fits(mesh, leaf.shape, spec):
                spec = P()
            return NamedSharding(mesh, spec)
        if "_enc_out" in path_s or "_vis" in path_s:
            spec = P(dp if b_ok else None, None, None)
            return NamedSharding(mesh, spec if spec_fits(mesh, leaf.shape, spec)
                                 else P())
        if "state/" in path_s:
            bspec = dp if b_ok else None
            if cfg.family == "hybrid":
                # state/0 conv [G,P,B,kw,C]; state/1 ssm [G,P,B,nh,ds,hd]
                if "state/0" in path_s:
                    spec = P(None, None, bspec, None, "model")
                else:
                    spec = P(None, None, bspec, "model", None, None)
            elif "state/1" in path_s:
                # rwkv: state/0,2 shift [L,B,1,d]; state/1 wkv [L,B,nh,ds,ds]
                spec = P(None, bspec, "model", None, None)
            else:
                spec = P(None, bspec, None, None)
            if not spec_fits(mesh, leaf.shape, spec):
                # fall back: batch-only, then full replication
                spec = P(*([None] * (nd - leaf.ndim)),
                         *[bspec if i == (2 if cfg.family == "hybrid" else 1)
                           else None for i in range(nd)])
            if not spec_fits(mesh, leaf.shape, spec):
                spec = P()
            return NamedSharding(mesh, spec)
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache)


def with_dp_constraint(x, mesh: Mesh):
    """Constrain a [B, ...] activation to DP sharding."""
    spec = P(dp_axes(mesh), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
