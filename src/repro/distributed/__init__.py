"""Distributed substrate: sharding rules, elasticity, fault tolerance."""

from .sharding import (  # noqa: F401
    batch_sharding,
    cache_shardings,
    param_shardings,
    with_dp_constraint,
)
from .fault_tolerance import HeartbeatMonitor, StragglerPolicy  # noqa: F401
from .elastic import ElasticPlan, plan_remesh  # noqa: F401
