"""Fault tolerance: heartbeat failure detection + straggler mitigation.

At 1000+ node scale, node failure is routine (MTBF of the *fleet* is
minutes-to-hours) and stragglers dominate tail latency.  This module holds
the pure control-plane logic — host-agnostic and fully unit-testable; the
launcher (`repro.launch.train`) wires it to the run loop and the
`CheckpointManager` + `elastic.plan_remesh` recovery path:

    failure detected  -> abort step -> plan_remesh(healthy) ->
    restore latest checkpoint -> resume at recorded step (data pipeline is
    step-indexed so no samples are lost or repeated)

Straggler policy follows the "tolerate, don't block" approach: per-step
durations are tracked per host; hosts slower than `factor` x the rolling
median for `patience` consecutive steps are flagged, first for data-shard
rebalancing, then for eviction (treated as a failure).

Both classes are clock-injectable: every timestamp flows through the
``clock`` callable handed to the constructor (default ``time.monotonic``)
or through explicit ``at=`` arguments — no wall-clock call sits inside the
decision logic, so the timeout and eviction paths are deterministically
unit-testable with a fake clock (`tests/test_fault_tolerance.py`).
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque

__all__ = ["HeartbeatMonitor", "StragglerPolicy"]


class HeartbeatMonitor:
    """Tracks per-host liveness; a host is failed after `timeout_s` silence."""

    def __init__(self, hosts: list[int], timeout_s: float = 60.0,
                 clock=time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self._last = {h: now for h in hosts}
        self._failed: set[int] = set()

    def beat(self, host: int, at: float | None = None) -> None:
        if host in self._failed:
            return  # failed hosts must rejoin via `rejoin`
        self._last[host] = self._clock() if at is None else at

    def check(self, at: float | None = None) -> list[int]:
        """Returns newly failed hosts."""
        now = self._clock() if at is None else at
        newly = [
            h
            for h, t in self._last.items()
            if h not in self._failed and now - t > self.timeout_s
        ]
        self._failed.update(newly)
        return newly

    def rejoin(self, host: int, at: float | None = None) -> None:
        self._failed.discard(host)
        self._last[host] = self._clock() if at is None else at

    def last_seen(self, host: int) -> float:
        """Timestamp of the host's most recent heartbeat (clock domain)."""
        return self._last[host]

    @property
    def healthy(self) -> list[int]:
        return sorted(set(self._last) - self._failed)

    @property
    def failed(self) -> list[int]:
        return sorted(self._failed)


@dataclasses.dataclass
class StragglerVerdict:
    rebalance: list[int]   # slow: shift data share away
    evict: list[int]       # hopeless: treat as failed
    at: float = 0.0        # verdict timestamp (policy clock domain)


class StragglerPolicy:
    """Rolling-median step-time policy with hysteresis.

    ``clock`` only stamps verdicts for incident records — the flag/evict
    decisions depend purely on the recorded step durations, so the policy
    is deterministic under any clock.
    """

    def __init__(self, factor: float = 1.5, patience: int = 5,
                 window: int = 50, evict_factor: float = 3.0,
                 clock=time.monotonic):
        self.factor = factor
        self.evict_factor = evict_factor
        self.patience = patience
        self._clock = clock
        self._times: dict[int, deque] = defaultdict(lambda: deque(maxlen=window))
        self._strikes: dict[int, int] = defaultdict(int)

    def record_step(self, durations: dict[int, float],
                    at: float | None = None) -> StragglerVerdict:
        if not durations:
            raise ValueError("record_step needs at least one host duration")
        med = sorted(durations.values())[len(durations) // 2]
        rebalance, evict = [], []
        for h, d in durations.items():
            self._times[h].append(d)
            if d > self.evict_factor * med:
                self._strikes[h] += 2
            elif d > self.factor * med:
                self._strikes[h] += 1
            else:
                self._strikes[h] = max(0, self._strikes[h] - 1)
            if self._strikes[h] >= 2 * self.patience:
                evict.append(h)
            elif self._strikes[h] >= self.patience:
                rebalance.append(h)
        return StragglerVerdict(rebalance=rebalance, evict=evict,
                                at=self._clock() if at is None else at)

    def host_share(self, hosts: list[int], flagged: list[int],
                   discount: float = 0.5) -> dict[int, float]:
        """Data-share weights after rebalancing away from stragglers."""
        w = {h: (discount if h in flagged else 1.0) for h in hosts}
        z = sum(w.values())
        return {h: v / z for h, v in w.items()}
