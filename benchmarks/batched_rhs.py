"""Batched multi-RHS throughput: amortize the VLIW stream across RHS.

The compiled instruction stream depends only on L, so one pass can solve B
right-hand sides at once (executor state `[n, B]` / `[P, B]` / `[P, S, B]`).
This sweep measures solves/sec and effective GOPS of the batched JAX
executor for B in {1, 4, 16, 32, 64, 256} against the sequential-loop baseline
(B independent `api.solve` calls through the same cached executor), i.e.
exactly the amortization a preconditioner apply or batched serving sees.
"""

from __future__ import annotations

import numpy as np

from repro.core import api
from repro.core.matrices import generate

from .common import emit, timeit

MATRICES = ["band_cz", "ckt_rajat04", "chem_bp", "ckt_add20"]
BATCHES = [1, 4, 16, 32, 64, 256]


def run() -> list[dict]:
    rows = []
    for name in MATRICES:
        mat = generate(name)
        prog = api.compile(mat)
        flops = 2 * mat.nnz - mat.n
        rng = np.random.default_rng(0)
        bmat = rng.standard_normal((mat.n, max(BATCHES))).astype(np.float32)

        seq_solver = api.make_solver(prog)
        for B in BATCHES:
            bsub = np.ascontiguousarray(bmat[:, :B])

            def sequential():
                return [np.asarray(seq_solver(bsub[:, i])) for i in range(B)]

            bat_solver = api.make_solver(prog, batch=B)

            def batched():
                return np.asarray(bat_solver(bsub))

            repeat = 1 if B >= 64 else 3  # same count for both sides
            t_seq = timeit(sequential, repeat=repeat)
            t_bat = timeit(batched, repeat=repeat)
            rows.append({
                "name": name,
                "batch": B,
                "seq_solves_per_s": round(B / t_seq, 1),
                "batched_solves_per_s": round(B / t_bat, 1),
                "speedup": round(t_seq / t_bat, 2),
                "seq_gops": round(B * flops / t_seq / 1e9, 4),
                "batched_gops": round(B * flops / t_bat / 1e9, 4),
                "batched_us_per_call": round(t_bat * 1e6, 1),
            })
    return rows


def main() -> None:
    rows = run()
    emit(rows, "batched_rhs")
    sp = [r["speedup"] for r in rows if r["batch"] >= 16]
    print(f"# batched executor speedup at B>=16: "
          f"min {min(sp):.1f}x / mean {np.mean(sp):.1f}x vs sequential loop")


if __name__ == "__main__":
    main()
