"""Fig. 11/12 + Table IV proxy: this-work modeled throughput vs host-CPU
implementations (serial python, vectorized level-scheduled numpy, JAX
executor wall-clock).

The paper's absolute CPU/GPU/DPU-v2 numbers need their hardware; offline we
report (a) the modeled accelerator GOPS (cycle-accurate at 150 MHz, the
paper's own methodology) and (b) measured wall-clock GOPS of real host
solvers as reference points.
"""

from __future__ import annotations

import numpy as np

from repro.core import api
from repro.core.csr import random_rhs
from repro.core.dag import compute_levels
from repro.core.executor import make_jax_executor
from repro.core.matrices import generate

from .common import emit, timeit

MATRICES = ["band_cz", "chem_bp", "ckt_rajat04", "ckt_add20", "band_dw2048",
            "grid_activsg", "wide_c36", "ckt_add32", "grid_gemat", "ckt_big8k"]


def _serial_python(mat, b):
    x = np.zeros(mat.n)
    rp, ci, v = mat.rowptr, mat.colidx, mat.values
    for i in range(mat.n):
        s = 0.0
        for j in range(rp[i], rp[i + 1] - 1):
            s += v[j] * x[ci[j]]
        x[i] = (b[i] - s) / v[rp[i + 1] - 1]
    return x


def _level_sched_numpy(mat, b, levels, order, bounds):
    """Vectorized level-scheduling (the CPU coarse dataflow)."""
    x = np.zeros(mat.n)
    rp, ci, v = mat.rowptr, mat.colidx, mat.values
    for k in range(len(bounds) - 1):
        rows = order[bounds[k]:bounds[k + 1]]
        for i in rows:  # rows within a level are independent
            lo, hi = rp[i], rp[i + 1] - 1
            x[i] = (b[i] - v[lo:hi] @ x[ci[lo:hi]]) / v[hi]
    return x


def run() -> list[dict]:
    rows = []
    for name in MATRICES:
        mat = generate(name)
        b = random_rhs(mat, 1)
        flops = 2 * mat.nnz - mat.n

        prog = api.compile(mat)
        modeled_gops = prog.stats.throughput_gops(prog.config)

        t_serial = timeit(_serial_python, mat, b, repeat=1)
        levels = compute_levels(mat)
        order = np.argsort(levels, kind="stable")
        width = np.bincount(levels)
        bounds = np.concatenate([[0], np.cumsum(width)])
        t_level = timeit(_level_sched_numpy, mat, b, levels, order, bounds)

        solver = make_jax_executor(prog)
        bj = b.astype(np.float32)
        t_jax = timeit(lambda: np.asarray(solver(bj)))

        rows.append({
            "name": name,
            "nnz": mat.nnz,
            "modeled_accel_gops": round(modeled_gops, 3),
            "serial_py_gops": round(flops / t_serial / 1e9, 4),
            "level_numpy_gops": round(flops / t_level / 1e9, 4),
            "jax_exec_gops": round(flops / t_jax / 1e9, 4),
            "compile_time_s": round(prog.stats.compile_seconds, 4),
            "exec_us_per_call": round(t_jax * 1e6, 1),
        })
    return rows


def main() -> None:
    rows = run()
    emit(rows, "fig11_platform_comparison")
    avg = np.mean([r["modeled_accel_gops"] for r in rows])
    print(f"# modeled accelerator average throughput: {avg:.2f} GOPS "
          f"(paper: 6.5 GOPS avg, up to 14.5)")


if __name__ == "__main__":
    main()
