"""Health-check overhead of the hardened solve path (DESIGN.md §7).

Per suite matrix and batch width, times the plain cached solver
(`api.make_solver`) against the default-on `api.robust_solver` (input
NaN/Inf validation + non-finite output check + relative-residual check
against the retained CSR) on the same jax backend.  Columns:

    plain_us, robust_us   — best-of-repeat per-solve wall clock
    check_us              — the health checks alone (input NaN/Inf scan +
                            output finiteness + residual matvec), timed
                            directly so run-to-run jax variance does not
                            swamp the subtraction
    overhead_pct          — check_us / plain_us * 100; the acceptance bar
                            is <= 10% on the default path
    residual              — relative ∞-norm residual of the checked solve

``--smoke`` (wired into tier-1 via `tests/test_robust.py`) runs the
fault-injection harness (`core.robust.run_fault_injection`) on one small
psum-heavy matrix across every fault class and asserts zero silent wrong
answers, then prints a one-matrix overhead reading.
"""

from __future__ import annotations

import sys

import numpy as np

from repro.core import api
from repro.core.matrices import generate
from repro.core.robust import FAULT_CLASSES, relative_residual, run_fault_injection

from .common import emit, timeit

BENCH_SET = ["band_cz", "chem_bp", "ckt_rajat04", "band_dw2048",
             "grid_activsg"]
SMOKE_MATRIX = "ckt_rajat04"  # small, with live psum slot traffic


def overhead_rows(names: list[str], batches=(1, 8),
                  repeat: int = 15) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for name in names:
        mat = generate(name)
        prog = api.compile(mat)
        for bsz in batches:
            b = rng.standard_normal((mat.n, bsz)) if bsz > 1 \
                else rng.standard_normal(mat.n)
            inner = api.make_solver(prog, batch=bsz if bsz > 1 else None)
            # materialize to host like the robust path does, else the
            # async-dispatch jax call times as ~0 and the ratio is noise
            plain = lambda rhs: np.asarray(inner(rhs))  # noqa: E731
            robust = api.robust_solver(prog, mat, backend="jax")
            plain_s = timeit(plain, b, repeat=repeat)
            robust_s = timeit(robust, b, repeat=repeat)
            x = plain(b)
            b64 = np.asarray(b, dtype=np.float64)

            def checks():
                np.isfinite(b64).all()                 # input validation
                np.isfinite(x).all()                   # output finiteness
                robust.residual(x, b64)                # residual matvec

            check_s = timeit(checks, repeat=repeat)
            rows.append({
                "name": name,
                "n": mat.n,
                "nnz": mat.nnz,
                "batch": bsz,
                "plain_us": round(plain_s * 1e6, 1),
                "robust_us": round(robust_s * 1e6, 1),
                "check_us": round(check_s * 1e6, 1),
                "overhead_pct": round(100.0 * check_s / plain_s, 1),
                "residual": float(f"{relative_residual(mat, robust(b), b):.2e}"),
            })
    return rows


def fault_rows(name: str, trials_per_class: int = 3,
               seed: int = 0) -> list[dict]:
    mat = generate(name)
    trials = run_fault_injection(mat, trials_per_class=trials_per_class,
                                 seed=seed)
    per_class: dict[str, dict] = {}
    for t in trials:
        agg = per_class.setdefault(t["fault"], {
            "name": name, "fault": t["fault"], "trials": 0,
            "detected": 0, "degraded": 0, "silent_wrong": 0,
        })
        agg["trials"] += 1
        agg["detected"] += t["detected"] != "none"
        agg["degraded"] += bool(t["degraded_to"])
        agg["silent_wrong"] += t["silent_wrong"]
    return [per_class[c] for c in FAULT_CLASSES if c in per_class]


def run(smoke: bool = False) -> list[dict]:
    if smoke:
        return fault_rows(SMOKE_MATRIX, trials_per_class=2)
    return overhead_rows(BENCH_SET)


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    if "--smoke" in argv:
        rows = run(smoke=True)
        wrong = sum(r["silent_wrong"] for r in rows)
        assert wrong == 0, f"{wrong} silent wrong answer(s) slipped through"
        ov = overhead_rows([SMOKE_MATRIX], batches=(1,), repeat=3)[0]
        print(f"# smoke: {sum(r['trials'] for r in rows)} injected faults "
              f"over {len(rows)} classes, 0 silent wrong answers; "
              f"health-check overhead {ov['overhead_pct']}% on "
              f"{SMOKE_MATRIX}")
        return
    rows = overhead_rows(BENCH_SET)
    emit(rows, "robust_overhead")
    worst = max(r["overhead_pct"] for r in rows)
    print(f"# worst health-check overhead {worst}% (bar: <= 10%)")
    frows = fault_rows(SMOKE_MATRIX)
    emit(frows, "robust_faults")
    print("# every injected fault class detected or degraded to a correct "
          "answer — zero silent wrong answers")


if __name__ == "__main__":
    main()
