"""Sharded batched multi-RHS: solves/sec vs device count, fixed per-device B.

The batch axis of `api.solve_batch` is embarrassingly parallel, so weak
scaling over devices (B = ndev * B_PER_DEVICE) should hold solve latency
roughly flat while total solves/sec grows with the device count.  Each
device count runs in its own subprocess because
``--xla_force_host_platform_device_count`` must be set before jax
initializes; the worker times the sharded solver (`mesh=`) against the
single-device batched executor at the same total B.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

DEVICE_COUNTS = [1, 2, 4, 8]
B_PER_DEVICE = 16
MATRICES = ["band_cz", "ckt_add20"]


def worker(ndev: int) -> None:
    """Runs inside the subprocess (XLA_FLAGS already set by the parent)."""
    import numpy as np

    import jax

    from repro.core import api, shard
    from repro.core.matrices import generate

    from .common import timeit

    assert len(jax.devices()) == ndev, (len(jax.devices()), ndev)
    mesh = shard.batch_mesh()
    B = ndev * B_PER_DEVICE
    rows = []
    for name in MATRICES:
        mat = generate(name)
        prog = api.compile(mat)
        flops = 2 * mat.nnz - mat.n
        bmat = np.random.default_rng(0).standard_normal(
            (mat.n, B)).astype(np.float32)

        sharded = api.make_solver(prog, batch=B, mesh=mesh)
        local = api.make_solver(prog, batch=B)
        t_sh = timeit(lambda: np.asarray(sharded(bmat)))
        t_lo = timeit(lambda: np.asarray(local(bmat)))
        rows.append({
            "name": name,
            "devices": ndev,
            "batch": B,
            "sharded_solves_per_s": round(B / t_sh, 1),
            "single_device_solves_per_s": round(B / t_lo, 1),
            "sharded_gops": round(B * flops / t_sh / 1e9, 4),
            "sharded_us_per_call": round(t_sh * 1e6, 1),
        })
    print(json.dumps(rows))


def run() -> list[dict]:
    rows = []
    for ndev in DEVICE_COUNTS:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.sharded_batch",
             "--worker", str(ndev)],
            env=env, capture_output=True, text=True, timeout=900,
            cwd=os.path.join(os.path.dirname(__file__), ".."),
        )
        if r.returncode != 0:
            raise RuntimeError(f"worker ndev={ndev} failed:\n{r.stderr[-2000:]}")
        rows.extend(json.loads(r.stdout.strip().splitlines()[-1]))
    return rows


def main() -> None:
    from .common import emit

    rows = run()
    emit(rows, "sharded_batch")
    for name in MATRICES:
        per = {r["devices"]: r["sharded_solves_per_s"]
               for r in rows if r["name"] == name}
        base = per[min(per)]
        scale = " ".join(f"{d}dev={per[d] / base:.2f}x" for d in sorted(per))
        print(f"# {name}: solves/sec vs 1 device: {scale}")


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--worker":
        worker(int(sys.argv[2]))
    else:
        main()
