"""Fig. 10 + instruction-traffic accounting: per-benchmark instruction mix
(exec / Bnop / Pnop / Dnop / Lnop [+ Snop, our spill-reload extension]) and
the solve-path instruction HBM traffic of the packed single-word VLIW
encoding (DESIGN.md §Perf, "Instruction encoding").

Traffic columns per benchmark:

  * ``bytes_per_lane_cycle``   — streamed instruction bytes per lane per
    emitted cycle (packed word(s) + pre-gathered f32 value; 8 B in the
    single-plane regime vs the 24 B of the historical five-plane layout);
  * ``instr_traffic_kib``      — total instruction HBM traffic of one solve
    (`Program.instr_bytes()`);
  * ``unpacked_traffic_kib``   — what the same solve streamed before
    packing + stall-row elision (five int32 planes + value, every hardware
    cycle);
  * ``traffic_ratio``          — unpacked / packed (>= 3x by construction:
    3x from the word packing, more where stall rows were elided);
  * ``stall_rows_elided``      — all-NOP cycles dropped at emission
    (``stats.cycles - stats.emitted_cycles``).

``--smoke`` runs a three-matrix subset without writing CSVs — wired into
the tier-1 test suite (`tests/test_packed.py`) so traffic-accounting
regressions fail fast, not just in benchmark runs.
"""

from __future__ import annotations

import sys

from repro.core import api
from repro.core.matrices import generate

from .common import FIG9_SET, emit

# bytes/lane-cycle of the pre-packing layout: five int32 planes (op, val_idx
# gather aside, src, out, ctl, slot) + one f32 pre-gathered value
UNPACKED_BYTES_PER_LANE_CYCLE = 24

SMOKE_SET = ["band_cz", "ckt_rajat04", "chem_bp"]


def run(smoke: bool = False) -> list[dict]:
    rows = []
    for name in (SMOKE_SET if smoke else FIG9_SET):
        prog = api.compile(generate(name))
        st = prog.stats
        bd = st.nop_breakdown()
        packed = prog.instr_bytes()
        unpacked = st.cycles * prog.num_cus * UNPACKED_BYTES_PER_LANE_CYCLE
        rows.append({
            "name": name,
            **{k: round(v, 4) for k, v in bd.items()},
            "utilization_pct": round(100 * bd["exec"], 2),
            "cycles": st.cycles,
            "emitted_cycles": st.emitted_cycles,
            "stall_rows_elided": st.cycles - st.emitted_cycles,
            "planes": prog.planes,
            "bytes_per_lane_cycle": prog.instr_bytes_per_lane_cycle(),
            "instr_traffic_kib": round(packed / 1024, 1),
            "unpacked_traffic_kib": round(unpacked / 1024, 1),
            "traffic_ratio": round(unpacked / packed, 2),
        })
    return rows


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    rows = run(smoke=smoke)
    if smoke:
        worst = min(r["traffic_ratio"] for r in rows)
        print(f"# smoke: {len(rows)} matrices, worst traffic ratio "
              f"{worst:.2f}x (packed vs 24 B/lane-cycle unpacked)")
        return
    emit(rows, "fig10_instruction_breakdown")
    best = max(r["utilization_pct"] for r in rows)
    ratio = max(r["traffic_ratio"] for r in rows)
    print(f"# peak PE utilization: {best:.1f}% (paper reports up to 75.3%)")
    print(f"# instruction traffic: {rows[0]['bytes_per_lane_cycle']} B/lane-"
          f"cycle packed; best reduction {ratio:.2f}x vs unpacked")


if __name__ == "__main__":
    main()
