"""Fig. 10: per-benchmark instruction breakdown (exec / Bnop / Pnop / Dnop /
Lnop [+ Snop, our spill-reload extension])."""

from __future__ import annotations

from repro.core import api
from repro.core.matrices import generate

from .common import FIG9_SET, emit


def run() -> list[dict]:
    rows = []
    for name in FIG9_SET:
        st = api.compile(generate(name)).stats
        bd = st.nop_breakdown()
        rows.append({
            "name": name,
            **{k: round(v, 4) for k, v in bd.items()},
            "utilization_pct": round(100 * bd["exec"], 2),
            "cycles": st.cycles,
        })
    return rows


def main() -> None:
    rows = run()
    emit(rows, "fig10_instruction_breakdown")
    best = max(r["utilization_pct"] for r in rows)
    print(f"# peak PE utilization: {best:.1f}% (paper reports up to 75.3%)")


if __name__ == "__main__":
    main()
