"""Serve-chaos benchmark: goodput + tail latency under injected faults.

    PYTHONPATH=src python -m benchmarks.serve_chaos            # full, CSV
    PYTHONPATH=src python -m benchmarks.serve_chaos --record   # + BENCH_serve_chaos.json
    PYTHONPATH=src python -m benchmarks.serve_chaos --smoke    # tier-1 guard

Measures what the resilient serving layer (DESIGN.md §10) actually buys
under fire.  One seeded fault campaign per class — backend exceptions,
non-finite outputs, simulated hangs, overload bursts, and expired
deadlines — runs an open-loop request stream against a resilient
`SolveService` on a virtual clock, with faults injected into the entry
backend rung.  Per class we report *goodput* (fraction of offered
requests answered correctly), typed failures and sheds (never silent),
p50/p99 completion latency on the virtual timeline, retries, degraded
flushes, and incident volume.  Every completed answer is residual-checked
against the retained matrix, so the ``silent_wrong`` column is a
measurement, not an assumption.

The fault-free row doubles as the overhead gate: the same stream runs
with resilience off and on (measured flush wall time, best of
``--repeat``), and ``overhead_pct`` must stay within a few percent —
deadlines, breakers, and admission checks are bookkeeping, not solving.

``--smoke`` (wired into tier-1 via `tests/test_resilience.py`) runs the
chaos sweep plus `robust.run_service_fault_injection` across seeds and
asserts zero silent wrong answers, zero deadlocks, and bounded overhead.
``--record`` appends a dated entry to ``BENCH_serve_chaos.json``
(schema pinned by ``scripts/check_bench.py``).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.csr import serial_solve
from repro.core.errors import RobustnessError
from repro.core.matrices import banded, generate
from repro.core.resilience import (
    AdmissionConfig,
    BreakerConfig,
    ResilienceConfig,
    RetryPolicy,
)
from repro.core.robust import SERVICE_FAULT_CLASSES, run_service_fault_injection
from repro.core.serve import ManualClock, ProgramCache, SolveService

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serve_chaos.json")
BENCH_SCHEMA = "sptrsv-bench-serve-chaos"
BENCH_VERSION = 1

# the measured campaign classes (superset of "none", the overhead row)
FAULTS = ("none", "backend_exception", "backend_nonfinite", "backend_hang",
          "overload_burst", "expired_deadline")
FLUSH_TIMEOUT_S = 0.25


def _resilience(fault: str, seed: int) -> ResilienceConfig:
    burst = fault == "overload_burst"
    return ResilienceConfig(
        retry=RetryPolicy(max_retries=1, base_delay_s=0.005, seed=seed),
        breaker=BreakerConfig(window_s=50.0, min_samples=4,
                              failure_threshold=0.75, cooldown_s=5.0),
        admission=AdmissionConfig(max_pending_per_matrix=6 if burst else None,
                                  max_pending_total=10 if burst else None),
        flush_timeout_s=FLUSH_TIMEOUT_S)


def _inject(svc: SolveService, clock: ManualClock, fault: str, rng,
            rate: float):
    """Wrap the service's entry ("numpy") rung with seeded faults."""
    if fault in ("none", "overload_burst", "expired_deadline"):
        return
    orig = svc._stage_solver

    def wrapped(stage, prog, k, mat):
        fn = orig(stage, prog, k, mat)
        if stage != "numpy":
            return fn

        def chaotic(bmat):
            if rng.random() < rate:
                if fault == "backend_exception":
                    raise RuntimeError("injected backend fault")
                if fault == "backend_hang":
                    clock.advance(FLUSH_TIMEOUT_S * 2)  # simulated stall
                    return np.asarray(fn(bmat))
                x = np.asarray(fn(bmat)).copy()       # backend_nonfinite
                x.flat[int(rng.integers(x.size))] = np.nan
                return x
            return np.asarray(fn(bmat))
        return chaotic

    svc._stage_solver = wrapped


def _drive(mat, fault: str, requests: int, seed: int,
           resilient: bool = True):
    """One open-loop campaign on the virtual clock; returns row pieces."""
    rng = np.random.default_rng(seed * 7919 + len(fault))
    clock = ManualClock()
    svc = SolveService(ProgramCache(capacity=4), max_batch=4, max_delay=0.05,
                       clock=clock, timer=time.perf_counter, backend="numpy",
                       resilience=_resilience(fault, seed)
                       if resilient else None)
    svc.register(mat.name, mat)
    svc.submit(mat.name, np.zeros(mat.n, np.float32))  # warm compile
    svc.drain()
    warm_flushes = len(svc.stats.flushes)
    _inject(svc, clock, fault, rng, rate=0.5)

    tickets = []
    for _ in range(requests):
        k = int(rng.integers(1, 9 if fault == "overload_burst" else 4))
        b = rng.standard_normal((mat.n, k)).astype(np.float32)
        kw = {}
        if fault == "expired_deadline":
            r = rng.random()
            if r < 0.25:
                kw["timeout"] = -0.1          # already expired at submit
            elif r < 0.5:
                kw["timeout"] = 0.01          # tight: races the flush
        arrival = clock.now
        tickets.append((svc.submit(mat.name, b, **kw), arrival, b))
        clock.advance(float(rng.uniform(0.0, 0.04)))
        svc.pump()
    clock.advance(1.0)
    svc.pump()
    svc.drain()
    return svc, tickets, warm_flushes


def _residual_ok(mat, x, b, tol: float = 1e-3) -> bool:
    x2 = np.asarray(x, np.float64).reshape(mat.n, -1)
    b2 = np.asarray(b, np.float64).reshape(mat.n, -1)
    dense = mat.to_dense()
    r = b2 - dense @ x2
    denom = max(float(np.abs(b2).max()), 1e-30)
    return bool(np.isfinite(x2).all()) and \
        float(np.abs(r).max()) / denom <= tol


def bench_fault(mat, fault: str, requests: int, seed: int) -> dict:
    svc, tickets, _ = _drive(mat, fault, requests, seed)
    completed = failed = shed = silent = not_done = 0
    lat = []
    for ticket, arrival, b in tickets:
        if ticket.shed:
            shed += 1
            continue
        if not ticket.done:
            not_done += 1
            continue
        if ticket.failed:
            failed += 1 if isinstance(ticket.error, RobustnessError) else 0
            silent += 0 if isinstance(ticket.error, RobustnessError) else 1
            continue
        if _residual_ok(mat, ticket.result(), b):
            completed += 1
            lat.append(ticket.completed_at - arrival)
        else:
            silent += 1
    st = svc.stats
    lat_arr = np.asarray(lat) if lat else np.asarray([0.0])
    return {
        "fault": fault,
        "requests": requests,
        "goodput": round(completed / requests, 3),
        "completed": completed,
        "failed_typed": failed,
        "shed": shed,
        "silent_wrong": silent + not_done,
        "p50_virtual_ms": round(float(np.percentile(lat_arr, 50)) * 1e3, 2),
        "p99_virtual_ms": round(float(np.percentile(lat_arr, 99)) * 1e3, 2),
        "retries": st.retries,
        "degraded_flushes": st.degraded_flushes,
        "incidents": len(svc.incidents) + svc.incidents.dropped,
    }


def measure_overhead(mat, requests: int, seed: int, repeat: int) -> float:
    """Fault-free end-to-end serve wall time: resilient vs plain.

    Per-flush timer sums are µs-scale and noise-dominated on small
    matrices, so this times the whole submit/pump/drain stream (virtual
    clock — no sleeps), interleaves the two configs, and takes the best
    of ``repeat`` runs each."""
    cache = ProgramCache(capacity=2)

    def once(resilient: bool) -> float:
        rng = np.random.default_rng(seed)
        clock = ManualClock()
        svc = SolveService(cache, max_batch=4, max_delay=0.05, clock=clock,
                           backend="numpy",
                           resilience=_resilience("none", seed)
                           if resilient else None)
        svc.register(mat.name, mat)
        svc.submit(mat.name, np.zeros(mat.n, np.float32))  # warm
        svc.drain()
        cols = rng.standard_normal((mat.n, requests, 3)).astype(np.float32)
        t0 = time.perf_counter()
        for i in range(requests):
            svc.submit(mat.name, cols[:, i])
            clock.advance(0.02)
            svc.pump()
        clock.advance(1.0)
        svc.pump()
        svc.drain()
        return time.perf_counter() - t0

    once(False), once(True)  # warm both paths (trace + allocator)
    # paired adjacent runs + median-of-ratios: host drift (frequency
    # scaling, noisy neighbours) hits both halves of a pair equally
    ratios = []
    for i in range(max(repeat, 3)):
        if i % 2 == 0:
            p, r = once(False), once(True)
        else:
            r, p = once(True), once(False)
        ratios.append(r / p)
    return (float(np.median(ratios)) - 1.0) * 100.0


def run(requests: int, seed: int, repeat: int, matrix: str) -> tuple:
    mat = generate(matrix) if matrix else banded(96, 6, 0.5, seed=3,
                                                 name="chaos-bench")
    rows = [bench_fault(mat, fault, requests, seed) for fault in FAULTS]
    overhead = measure_overhead(mat, requests, seed, repeat)
    return rows, overhead, mat


def record_trajectory(rows, overhead_pct: float, seed: int,
                      label: str) -> None:
    """Append a dated entry to the BENCH_serve_chaos.json trajectory."""
    doc = {"schema": BENCH_SCHEMA, "version": BENCH_VERSION, "entries": []}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            doc = json.load(f)
    doc["entries"].append({
        "recorded": time.strftime("%Y-%m-%d"),
        "label": label,
        "host": "cpu-interpret",
        "seed": seed,
        "overhead_pct": round(overhead_pct, 2),
        "rows": rows,
    })
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# trajectory entry #{len(doc['entries'])} -> {BENCH_JSON}")


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--record", action="store_true",
                    help="append results to BENCH_serve_chaos.json")
    ap.add_argument("--label", default="serve-chaos")
    ap.add_argument("--matrix", default="")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args(argv)
    requests = args.requests or (16 if args.smoke else 48)
    if args.smoke:
        args.repeat = max(args.repeat, 5)

    rows, overhead, _ = run(requests, args.seed, args.repeat, args.matrix)

    if args.smoke:
        for r in rows:
            assert r["silent_wrong"] == 0, (
                f"{r['fault']}: {r['silent_wrong']} silent wrong answers")
            assert r["completed"] + r["failed_typed"] + r["shed"] \
                == r["requests"], f"{r['fault']}: ticket accounting leaks"
        assert rows[0]["goodput"] == 1.0, "fault-free goodput must be 1.0"
        assert overhead <= 5.0, (
            f"resilience overhead {overhead:.1f}% > 5% on fault-free serve")
        for res in run_service_fault_injection(seed=args.seed, requests=10):
            assert res["silent_wrong"] == 0 and not res["deadlocked"], res
        worst = min(r["goodput"] for r in rows)
        print(f"# smoke: {len(rows)} fault classes, goodput {worst}-1.0, "
              f"0 silent wrong, 0 deadlocks, resilience overhead "
              f"{overhead:.1f}% (bar: <= 5%), harness classes "
              f"{len(SERVICE_FAULT_CLASSES)} clean")
        return

    emit(rows, "serve_chaos")
    print(f"# fault-free resilience overhead {overhead:.2f}% "
          f"(acceptance bar: <= 5%)")
    if args.record:
        record_trajectory(rows, overhead, args.seed, args.label)


if __name__ == "__main__":
    main()
