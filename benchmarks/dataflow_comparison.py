"""Fig. 9a: throughput of coarse / fine / medium (this work) dataflows.

The medium dataflow here matches the paper's Fig. 9a configuration: ICR on,
psum caching OFF (the caching ablation is Fig. 9b/c -> psum_sweep.py).
"""

from __future__ import annotations

import dataclasses

from repro.core import api
from repro.core.matrices import generate
from repro.core.program import AccelConfig
from repro.core.schedule import compile_program

from .common import FIG9_SET, emit


def run() -> list[dict]:
    rows = []
    base = AccelConfig()
    for name in FIG9_SET:
        mat = generate(name)
        med = compile_program(
            mat, dataclasses.replace(base, psum_cache=False)
        ).stats
        coa = api.baseline_coarse(mat).stats
        fin = api.baseline_fine(mat)
        rows.append({
            "name": name,
            "n": mat.n,
            "nnz": mat.nnz,
            "coarse_cycles": coa.cycles,
            "fine_cycles_eff": round(fin.effective_cycles, 1),
            "medium_cycles": med.cycles,
            "coarse_gops": round(coa.throughput_gops(base), 3),
            "fine_gops": round(fin.throughput_gops(base.clock_mhz), 3),
            "medium_gops": round(med.throughput_gops(base), 3),
            "peak_gops": round(med.peak_throughput_gops(base), 2),
        })
    return rows


def main() -> None:
    emit(run(), "fig9a_dataflow_comparison")


if __name__ == "__main__":
    main()
