"""Static-analysis cost: analyzer wall-time and verify_ir compile overhead.

Per suite matrix, times (DESIGN.md §8):

    compile_ms            — plain `compile_dag` wall clock (best of repeat)
    verify_ms             — `compile_dag(verify_ir=True)` wall clock
    verify_overhead_pct   — (verify - compile) / compile * 100; acceptance
                            bar <= 10% compile-time overhead on the
                            default configuration
    analyze_ms            — `analysis.analyze_program` (hazards + lints)
                            on the compiled artifact
    errors/warns/infos    — diagnostic counts of the analyzed program
                            (errors must be 0 on every suite matrix)

``--smoke`` (wired into tier-1 via `tests/test_analysis.py`) runs the
IR-level fault-injection harness (`core.robust.run_ir_fault_injection`)
on one psum-heavy matrix, asserts every applicable fault class is caught
by its per-pass verifier, and prints a one-matrix overhead reading
against the 10% bar.
"""

from __future__ import annotations

import sys
import time

from repro.core import api
from repro.core.analysis import analyze_program
from repro.core.matrices import generate
from repro.core.robust import run_ir_fault_injection

from .common import emit, timeit

BENCH_SET = ["band_cz", "chem_bp", "ckt_rajat04", "band_dw2048",
             "grid_activsg"]
SMOKE_MATRIX = "ckt_rajat04"  # small, with live psum slot traffic

OVERHEAD_BAR_PCT = 10.0


def overhead_rows(names: list[str], repeat: int = 9) -> list[dict]:
    rows = []
    for name in names:
        mat = generate(name)
        # interleave the two timings: the overhead is a ratio of two
        # wall-clocks, and pairing each sample keeps drifting machine
        # load from landing on only one side of the division
        prog = api.compile(mat)  # warm caches for both paths
        api.compile(mat, verify_ir=True)
        compile_s = verify_s = float("inf")
        for _ in range(repeat):
            t0 = time.perf_counter()
            api.compile(mat)
            compile_s = min(compile_s, time.perf_counter() - t0)
            t0 = time.perf_counter()
            api.compile(mat, verify_ir=True)
            verify_s = min(verify_s, time.perf_counter() - t0)
        analyze_s = timeit(lambda: analyze_program(prog), repeat=repeat)
        report = analyze_program(prog)
        rows.append({
            "name": name,
            "n": mat.n,
            "nnz": mat.nnz,
            "compile_ms": round(compile_s * 1e3, 2),
            "verify_ms": round(verify_s * 1e3, 2),
            "verify_overhead_pct": round(
                100.0 * (verify_s - compile_s) / compile_s, 1),
            "analyze_ms": round(analyze_s * 1e3, 2),
            "errors": len(report.errors),
            "warns": len(report.warnings),
            "infos": len(report.infos),
        })
    return rows


def fault_rows(name: str, seed: int = 0) -> list[dict]:
    mat = generate(name)
    return [{"name": name, **r}
            for r in run_ir_fault_injection(mat, seed=seed)]


def run(smoke: bool = False) -> list[dict]:
    if smoke:
        return fault_rows(SMOKE_MATRIX)
    return overhead_rows(BENCH_SET)


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    if "--smoke" in argv:
        rows = run(smoke=True)
        missed = [r["fault"] for r in rows
                  if r["applicable"] and not r["caught"]]
        assert not missed, f"IR faults missed by the verifiers: {missed}"
        ov = overhead_rows([SMOKE_MATRIX], repeat=3)[0]
        assert ov["errors"] == 0, f"clean compile reported errors: {ov}"
        print(f"# smoke: {sum(r['applicable'] for r in rows)} applicable "
              f"IR fault class(es) all caught by the per-pass verifiers; "
              f"verify_ir overhead {ov['verify_overhead_pct']}% on "
              f"{SMOKE_MATRIX} (bar: <= {OVERHEAD_BAR_PCT:.0f}%)")
        return
    rows = overhead_rows(BENCH_SET)
    emit(rows, "analysis_overhead")
    worst = max(r["verify_overhead_pct"] for r in rows)
    print(f"# worst verify_ir compile overhead {worst}% "
          f"(bar: <= {OVERHEAD_BAR_PCT:.0f}%)")
    frows = fault_rows(SMOKE_MATRIX)
    emit(frows, "analysis_faults")
    caught = sum(r["caught"] for r in frows)
    print(f"# {caught}/{sum(r['applicable'] for r in frows)} applicable "
          f"IR fault classes caught by the per-pass contract verifiers")


if __name__ == "__main__":
    main()
