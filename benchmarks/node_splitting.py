"""Beyond-paper: medium-node splitting vs plain medium / fine dataflows on
load-imbalanced DAGs (the paper's §V-E open problem)."""

from __future__ import annotations

from repro.core import api
from repro.core.matrices import generate

from .common import emit

MATRICES = ["hub_wall", "hub_wall_big", "hub_small", "hub_mid",
            "ckt_rajat04", "chem_bp", "band_dw2048"]


def run() -> list[dict]:
    rows = []
    for name in MATRICES:
        mat = generate(name)
        flops = 2 * mat.nnz - mat.n
        base = api.compile(mat)
        prog, split = api.compile_split(mat, max_indegree=64)
        fine = api.baseline_fine(mat)
        cfg = base.config
        gops = lambda cycles: flops / (cycles * cfg.clock_period_s) / 1e9
        rows.append({
            "name": name,
            "aux_nodes": split.n_aux,
            "medium_gops": round(base.stats.throughput_gops(cfg), 2),
            "split_gops": round(gops(prog.stats.cycles), 2),
            "fine_gops": round(fine.throughput_gops(), 2),
            "speedup_vs_medium": round(base.stats.cycles / prog.stats.cycles, 2),
            "load_cv_before": round(base.stats.load_balance_cv(), 1),
            "load_cv_after": round(prog.stats.load_balance_cv(), 1),
        })
    return rows


def main() -> None:
    emit(run(), "beyond_node_splitting")


if __name__ == "__main__":
    main()
