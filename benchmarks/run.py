"""Benchmark harness driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig9a ...  # subset

Each module prints a CSV (also saved to results/bench/) whose rows carry
``name,<metrics>``; wall-clock entries are reported as ``*_us_per_call``.
"""

from __future__ import annotations

import sys
import time

from . import (
    analysis_overhead,
    batched_rhs,
    compiler_scaling,
    dag_workloads,
    large_n,
    node_splitting,
    dataflow_comparison,
    icr_ablation,
    instruction_breakdown,
    platform_comparison,
    psum_sweep,
    robust_overhead,
    schedule_frontier,
    serve_chaos,
    serve_load,
    sharded_batch,
    suite_stats,
)

MODULES = {
    "fig9a": dataflow_comparison,
    "fig9bc": psum_sweep,
    "fig9def": icr_ablation,
    "fig10": instruction_breakdown,
    "fig11": platform_comparison,
    "table3": suite_stats,
    "table4": compiler_scaling,
    "beyond": node_splitting,
    "batched": batched_rhs,
    "sharded": sharded_batch,
    "large_n": large_n,
    "dagwork": dag_workloads,
    "robust": robust_overhead,
    "analysis": analysis_overhead,
    "serve": serve_load,
    "chaos": serve_chaos,
    "frontier": schedule_frontier,
}


def main() -> None:
    wanted = sys.argv[1:] or list(MODULES)
    for key in wanted:
        mod = MODULES[key]
        print(f"\n===== {key}: {mod.__doc__.splitlines()[0]} =====")
        t0 = time.perf_counter()
        mod.main()
        print(f"# {key} done in {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
