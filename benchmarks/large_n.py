"""Large-n scaling: solves/sec of the Pallas memory placements vs n.

The VMEM-resident Pallas kernel needs the whole ``x[n_pad, B]`` +
``b[n_pad, B]`` solve state on-chip, capping solvable n well below the
paper's 85k-node DAGs on a real TPU.  The row-blocked placement keeps x/b
in HBM behind a sliding VMEM window (`kernels/sptrsv/ops.plan_window`), so
its VMEM footprint is set by the window, not by n.  This sweep walks a
banded-matrix size ladder and records, per n:

  * solves/sec of the batched JAX `lax.scan` executor (reference),
  * solves/sec of the Pallas kernel in ``resident`` and ``blocked``
    placements (same batch width, same cached-executor discipline),
  * the planned window/stride and the VMEM solve-state bytes of each
    placement — the memory ratio is the point of the exercise.

On a CPU host both Pallas placements run in interpreter mode (auto-detect),
so their wall-clock is a correctness/overlap proxy; re-run on a real TPU
slice for kernel numbers.  ``BENCH_LARGE_N=band_wide4k,band_big16k`` picks
the ladder (default stops at 16k; add ``band_huge64k`` for the paper-scale
rung — its compile alone takes ~1 min).
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import api
from repro.core.executor import make_jax_executor, make_pallas_executor
from repro.kernels.sptrsv import ops as sptrsv_ops

from .common import emit, timeit

DEFAULT_LADDER = ["band_cz", "band_wide4k", "band_big16k"]
BATCH = 16
CYCLES_PER_BLOCK = 128


def main() -> None:
    ladder = [s for s in os.environ.get(
        "BENCH_LARGE_N", ",".join(DEFAULT_LADDER)).split(",") if s]
    rows = []
    rng = np.random.default_rng(0)
    for name in ladder:
        mat = api.matrix(name)
        prog = api.compile(mat)
        bmat = rng.standard_normal((mat.n, BATCH)).astype(np.float32)
        plan = sptrsv_ops.plan_window(prog, CYCLES_PER_BLOCK)
        if not plan.feasible:
            print(f"# {name}: blocked placement infeasible ({plan.reason})")
            continue

        jax_solver = make_jax_executor(prog, batch=BATCH)
        solvers = {"jax_scan": jax_solver}
        for placement in ("resident", "blocked"):
            solvers[placement] = make_pallas_executor(
                prog, batch=BATCH, cycles_per_block=CYCLES_PER_BLOCK,
                placement=placement,
            )

        row = {
            "name": name, "n": mat.n, "nnz": mat.nnz, "batch": BATCH,
            "window": plan.window, "stride": plan.stride,
            "num_blocks": plan.num_blocks,
            "resident_state_bytes": 2 * (mat.n + 1) * BATCH * 4,
            "blocked_state_bytes": plan.state_bytes(BATCH),
            # packed single-word encoding: double-buffered instruction VMEM
            # (shared by both placements; was 3x larger with 5 planes)
            "instr_buffer_bytes": sptrsv_ops.instr_buffer_bytes(
                prog, CYCLES_PER_BLOCK),
            "instr_traffic_kib": round(prog.instr_bytes() / 1024, 1),
        }
        for label, solver in solvers.items():
            dt = timeit(lambda: np.asarray(solver(bmat)))
            row[f"{label}_solves_per_s"] = round(BATCH / dt, 1)
            row[f"{label}_us_per_call"] = round(dt * 1e6, 1)
        rows.append(row)
    emit(rows, "large_n")


if __name__ == "__main__":
    main()
