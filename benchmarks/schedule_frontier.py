"""Scheduling-strategy frontier: per-matrix cycles across every strategy.

    PYTHONPATH=src python -m benchmarks.schedule_frontier            # CSV
    PYTHONPATH=src python -m benchmarks.schedule_frontier --record   # + JSON
    PYTHONPATH=src python -m benchmarks.schedule_frontier --smoke    # tier-1

Compiles every suite matrix with ``schedule="auto"`` (DESIGN.md §11): the
compiler runs each registered strategy — the paper's psum-cache scheduler
plus the level-set and list-scheduler alternatives — scores each dense
trace with the analytic cost model, and keeps the predicted-cheapest.
Because the cost model's cycle count is exact (it *is* the dense trace
length), the recorded frontier doubles as the measured one: per matrix
the row carries every strategy's cycles / stall rows / psum spills, the
strategy auto picked, its measured ``stats.cycles``, and whether that
strictly beat the paper baseline.

``--record`` appends a dated entry to the ``BENCH_schedule.json``
trajectory file (schema checked by ``scripts/check_bench.py``).
``--smoke`` (wired into tier-1 via `tests/test_strategies.py`) runs a
small subset and asserts auto is never worse than the paper schedule and
wins where the frontier says it must.
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import api
from repro.core.matrices import generate, suite_names

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_schedule.json")
BENCH_SCHEMA = "sptrsv-bench-schedule"
BENCH_VERSION = 1

STRATEGY_NAMES = ("paper", "level", "locality", "cpath", "eager")
# ckt_fpga must be an auto win (list schedulers beat the paper's resume
# order on psum-bound circuit DAGs); band_cz is an order-forced tie.
SMOKE_SET = ("band_cz", "ckt_fpga")


def bench_matrix(name: str) -> dict:
    """One frontier row: every strategy's predicted cost + auto's pick."""
    mat = generate(name)
    prog = api.compile(mat, schedule="auto")
    st = prog.stats
    costs = st.schedule_costs
    row: dict = {"name": name, "n": int(mat.n), "nnz": int(mat.nnz)}
    for s in STRATEGY_NAMES:
        c = costs[s]
        row[f"{s}_cycles"] = int(c["cycles"])
        row[f"{s}_stalls"] = int(c["stall_rows"])
        row[f"{s}_spills"] = int(c["psum_spills"])
    row["auto_pick"] = st.schedule
    row["auto_cycles"] = int(st.cycles)
    row["auto_win"] = int(st.cycles < costs["paper"]["cycles"])
    assert st.cycles == costs[st.schedule]["cycles"], (
        f"{name}: cost model diverged from measured cycles")
    assert st.cycles <= costs["paper"]["cycles"], (
        f"{name}: auto picked a schedule worse than the paper baseline")
    return row


def record_trajectory(rows: list[dict], label: str) -> None:
    """Append a dated entry to the BENCH_schedule.json trajectory file."""
    doc = {"schema": BENCH_SCHEMA, "version": BENCH_VERSION, "entries": []}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            doc = json.load(f)
    doc["entries"].append({
        "recorded": time.strftime("%Y-%m-%d"),
        "label": label,
        "host": "cpu-interpret" if not _on_tpu() else "tpu",
        "wins": sum(r["auto_win"] for r in rows),
        "rows": rows,
    })
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# trajectory entry #{len(doc['entries'])} -> {BENCH_JSON}")


def _on_tpu() -> bool:
    import jax

    return jax.devices()[0].platform == "tpu"


def run(smoke: bool = False, max_n: int = 3000, names=None) -> list[dict]:
    names = names or (SMOKE_SET if smoke else suite_names(max_n=max_n))
    return [bench_matrix(n) for n in names]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--record", action="store_true",
                    help="append results to BENCH_schedule.json")
    ap.add_argument("--label", default="schedule-frontier")
    ap.add_argument("--matrices", default="")
    ap.add_argument("--max-n", type=int, default=3000)
    args = ap.parse_args(argv)
    names = tuple(args.matrices.split(",")) if args.matrices else None
    rows = run(smoke=args.smoke, max_n=args.max_n, names=names)
    wins = sum(r["auto_win"] for r in rows)
    if args.smoke:
        assert any(r["auto_win"] for r in rows), (
            "smoke set contains no auto win — the frontier collapsed")
        print(f"# smoke: {len(rows)} matrices, auto never worse than "
              f"paper, {wins} strict win(s)")
        return
    emit(rows, "schedule_frontier")
    print(f"# auto strictly beats the paper schedule on {wins}/{len(rows)} "
          f"matrices (never worse on any; acceptance bar: >= 1/3)")
    if args.record:
        record_trajectory(rows, args.label)


if __name__ == "__main__":
    main()
