"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import csv
import io
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")

# the per-figure benchmark set: spans the CDU spectrum of Table III
FIG9_SET = [
    "chem_bp", "chem_west", "band_jagmesh", "band_rdb", "band_dw2048",
    "grid_activsg", "band_cz", "grid_bips", "band_nnc", "ckt_add20",
    "ckt_fpga", "wide_c36", "ckt_c204", "grid_gemat", "chem_bayer",
    "ckt_rajat04", "ckt_add32", "band_bcsstm", "ckt_rajat19", "hub_small",
]


def emit(rows: list[dict], name: str) -> str:
    """Print CSV to stdout and save under results/bench/<name>.csv."""
    if not rows:
        return ""
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=list(rows[0]))
    w.writeheader()
    w.writerows(rows)
    text = buf.getvalue()
    print(text)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.csv"), "w") as f:
        f.write(text)
    return text


def timeit(fn, *args, repeat: int = 3, warmup: int = 1):
    for _ in range(warmup):
        fn(*args)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best
