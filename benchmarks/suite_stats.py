"""Table III: structural statistics of the benchmark suite (CDU nodes /
edges / levels, load balance, peak throughput, compile time)."""

from __future__ import annotations

from repro.core import api
from repro.core.dag import analyze
from repro.core.matrices import generate, suite_names
from repro.core.program import AccelConfig

from .common import emit


def run(max_n: int | None = 40000) -> list[dict]:
    rows = []
    cfg = AccelConfig()
    for name in suite_names(max_n):
        mat = generate(name)
        info = analyze(mat, num_cus=cfg.num_cus)
        prog = api.compile(mat)
        st = prog.stats
        rows.append({
            **info.row(),
            "load_balance_cv": round(st.load_balance_cv(), 1),
            "peak_gops": round(st.peak_throughput_gops(cfg), 2),
            "this_work_gops": round(st.throughput_gops(cfg), 2),
            "compile_time_s": round(st.compile_seconds, 4),
        })
    return rows


def main() -> None:
    emit(run(), "table3_suite_stats")


if __name__ == "__main__":
    main()
