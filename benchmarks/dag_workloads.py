"""DAG-workload frontends vs the lower-triangular baseline (DESIGN.md §6).

The staged compiler's frontend boundary opens the stack to SpTRSV-like
workloads beyond Lx=b; this benchmark runs, per suite matrix:

  * ``lower``          — the classic Lx=b baseline;
  * ``upper``          — Ux=b with U = Lᵀ through the CSC-row-reversal
    frontend (`core/frontends/upper.py`);
  * ``transpose_pair`` — the full incomplete-Cholesky application
    x = Lᵀ \\ (L \\ b) from ONE `api.compile_pair` (cycles column = the
    backward sweep; the forward sweep equals ``lower``);
  * ``circuit``        — a DPU-v2-style weighted-accumulate circuit
    (`core/frontends/dagcirc.py`) matched to the matrix's node count.

Columns: modeled schedule metrics (cycles, emitted rows, GOPS at the
paper's 150 MHz, utilization, packed planes + instruction traffic — all
straight from `api.report`, which now carries the PR-4 encoding fields)
plus ``max_err``, the numpy-executor round-trip error against the
scipy/numpy oracle of each workload.

``--smoke`` runs a small subset without writing CSVs — wired into tier-1
(`tests/test_frontends.py`) so frontend regressions fail fast.
"""

from __future__ import annotations

import sys

import numpy as np

from repro.core import api
from repro.core.csr import serial_solve, serial_solve_upper, transpose_upper
from repro.core.frontends.dagcirc import random_circuit
from repro.core.matrices import generate

from .common import emit

BENCH_SET = ["band_cz", "ckt_rajat04", "chem_bp", "band_dw2048",
             "grid_activsg", "wide_c36"]
SMOKE_SET = ["band_cz", "ckt_rajat04"]


def _row(workload: str, prog, max_err: float) -> dict:
    rep = api.report(prog)
    return {
        "workload": workload,
        "name": rep["name"],
        "n": rep["n"],
        "nnz": rep["nnz"],
        "cycles": rep["cycles"],
        "emitted_cycles": rep["emitted_cycles"],
        "planes": rep["planes"],
        "instr_kib": round(rep["instr_bytes"] / 1024, 1),
        "throughput_gops": rep["throughput_gops"],
        "pe_utilization": rep["pe_utilization"],
        "max_err": float(f"{max_err:.2e}"),
    }


def run(smoke: bool = False) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for name in (SMOKE_SET if smoke else BENCH_SET):
        mat = generate(name)
        b = rng.standard_normal(mat.n)

        prog = api.compile(mat)
        err = np.abs(api.solve_numpy(prog, b) - serial_solve(mat, b)).max()
        rows.append(_row("lower", prog, err))

        u = transpose_upper(mat)
        cw = api.compile_upper(u)
        err = np.abs(cw.solve(b, backend="numpy")
                     - serial_solve_upper(u, b)).max()
        rows.append(_row("upper", cw.program, err))

        pair = api.compile_pair(mat)
        y = serial_solve(mat, b)
        ref = serial_solve_upper(u, y)
        err = np.abs(pair.solve(b, backend="numpy") - ref).max()
        rows.append(_row("transpose_pair", pair.backward.program, err))

        circ = random_circuit(mat.n, max_fan_in=6, seed=mat.n,
                              locality=max(32, mat.n // 16),
                              name=f"circ_{name}")
        ccw = api.compile_circuit(circ)
        uvec = rng.standard_normal(circ.n)
        err = np.abs(ccw.solve(uvec, backend="numpy") - circ.eval(uvec)).max()
        rows.append(_row("circuit", ccw.program, err))
    return rows


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    rows = run(smoke=smoke)
    if smoke:
        worst = max(r["max_err"] for r in rows)
        print(f"# smoke: {len(rows)} workload rows, worst oracle error "
              f"{worst:.2e}")
        return
    emit(rows, "dag_workloads")
    per_wl = {}
    for r in rows:
        per_wl.setdefault(r["workload"], []).append(r["cycles"])
    base = per_wl.pop("lower")
    for wl, cyc in sorted(per_wl.items()):
        rel = np.mean([c / b for c, b in zip(cyc, base)])
        print(f"# {wl}: mean cycles {rel:.2f}x the lower-tri baseline")
    print("# all workloads share the Program format: every executor, the "
          "batched/sharded paths and the packed encoding ran them unchanged")


if __name__ == "__main__":
    main()
