"""Fig. 9b/c: total + blocking cycles vs psum register-file capacity."""

from __future__ import annotations

import dataclasses

from repro.core.matrices import generate
from repro.core.program import AccelConfig
from repro.core.schedule import compile_program

from .common import emit

MATRICES = ["ckt_rajat04", "ckt_add20", "band_dw2048", "chem_bp",
            "grid_activsg", "wide_c36", "ckt_rajat19", "hub_small"]
CAPACITIES = [0, 1, 2, 4, 8, 16]


def run() -> list[dict]:
    rows = []
    for name in MATRICES:
        mat = generate(name)
        base = None
        for cap in CAPACITIES:
            cfg = AccelConfig(psum_words=max(cap, 1), psum_cache=cap > 0)
            st = compile_program(mat, cfg).stats
            blocking = st.dnop + st.pnop + st.bnop + st.snop
            if base is None:
                base = (st.cycles, max(blocking, 1))
            rows.append({
                "name": name,
                "psum_words": cap,
                "cycles": st.cycles,
                "cycles_norm": round(st.cycles / base[0], 4),
                "blocking": blocking,
                "blocking_norm": round(blocking / base[1], 4),
                "pnop": st.pnop,
                "dm_escapes": st.dm_escapes,
            })
    return rows


def main() -> None:
    emit(run(), "fig9bc_psum_sweep")


if __name__ == "__main__":
    main()
