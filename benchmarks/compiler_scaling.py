"""§IV-D / Table IV: compiler complexity O(nnz * d) empirical check.

Fits compile-time against nnz across a size ladder of one archetype; the
fitted exponent should be ~1 (linear in nnz for bounded max in-degree d).
Also emits a per-pass timing table from ``stats.pass_stats`` so the
pipeline stage that dominates compile time is visible at every size.
"""

from __future__ import annotations

import numpy as np

from repro.core import api
from repro.core.matrices import banded

from .common import emit


def run() -> tuple[list[dict], list[dict]]:
    rows = []
    pass_rows = []
    pts = []
    for i, n in enumerate([512, 1024, 2048, 4096, 8192, 16384]):
        mat = banded(n, 24, 0.5, 99 + i, f"scale_{n}")
        prog = api.compile(mat)
        t = prog.stats.compile_seconds
        pts.append((mat.nnz, t))
        rows.append({
            "n": n,
            "nnz": mat.nnz,
            "compile_s": round(t, 4),
            "cycles": prog.stats.cycles,
            "us_per_nnz": round(1e6 * t / mat.nnz, 3),
        })
        pass_rows.append(pass_timing_row(prog, n))
    nnz = np.log([p[0] for p in pts])
    tt = np.log([max(p[1], 1e-9) for p in pts])
    slope = float(np.polyfit(nnz, tt, 1)[0])
    rows.append({"n": "fit", "nnz": "-", "compile_s": "-",
                 "cycles": "-", "us_per_nnz": f"exponent={slope:.2f}"})
    return rows, pass_rows


def pass_timing_row(prog, n) -> dict:
    """One per-pass timing row: ms per pipeline stage + dominant share."""
    seconds = {ps.name: ps.seconds for ps in prog.stats.pass_stats}
    total = sum(seconds.values()) or 1e-9
    row = {"n": n}
    for name, secs in seconds.items():
        row[f"{name}_ms"] = round(1e3 * secs, 3)
    top = max(seconds, key=seconds.get)
    row["dominant"] = f"{top}={100 * seconds[top] / total:.0f}%"
    return row


def main() -> None:
    rows, pass_rows = run()
    emit(rows, "table4_compiler_scaling")
    emit(pass_rows, "table4_pass_timing")


if __name__ == "__main__":
    main()
