"""§IV-D / Table IV: compiler complexity O(nnz * d) empirical check.

Fits compile-time against nnz across a size ladder of one archetype; the
fitted exponent should be ~1 (linear in nnz for bounded max in-degree d).
"""

from __future__ import annotations

import numpy as np

from repro.core import api
from repro.core.matrices import banded

from .common import emit


def run() -> list[dict]:
    rows = []
    pts = []
    for i, n in enumerate([512, 1024, 2048, 4096, 8192, 16384]):
        mat = banded(n, 24, 0.5, 99 + i, f"scale_{n}")
        prog = api.compile(mat)
        t = prog.stats.compile_seconds
        pts.append((mat.nnz, t))
        rows.append({
            "n": n,
            "nnz": mat.nnz,
            "compile_s": round(t, 4),
            "cycles": prog.stats.cycles,
            "us_per_nnz": round(1e6 * t / mat.nnz, 3),
        })
    nnz = np.log([p[0] for p in pts])
    tt = np.log([max(p[1], 1e-9) for p in pts])
    slope = float(np.polyfit(nnz, tt, 1)[0])
    rows.append({"n": "fit", "nnz": "-", "compile_s": "-",
                 "cycles": "-", "us_per_nnz": f"exponent={slope:.2f}"})
    return rows


def main() -> None:
    emit(run(), "table4_compiler_scaling")


if __name__ == "__main__":
    main()
