"""Fig. 9d/e/f: ICR effect on bank constraints, conflicts, and data reuse."""

from __future__ import annotations

import dataclasses

from repro.core.matrices import generate
from repro.core.program import AccelConfig
from repro.core.schedule import compile_program

from .common import FIG9_SET, emit


def run() -> list[dict]:
    rows = []
    for name in FIG9_SET:
        mat = generate(name)
        on = compile_program(mat, AccelConfig(icr=True)).stats
        off = compile_program(mat, AccelConfig(icr=False)).stats
        rows.append({
            "name": name,
            "constraints_icr": on.constraints,
            "constraints_noicr": off.constraints,
            "conflicts_icr": on.conflicts,
            "conflicts_noicr": off.conflicts,
            "reuse_icr": on.reuse_events,
            "reuse_noicr": off.reuse_events,
            "cycles_icr": on.cycles,
            "cycles_noicr": off.cycles,
        })
    return rows


def main() -> None:
    emit(run(), "fig9def_icr_ablation")


if __name__ == "__main__":
    main()
