"""Serve-load benchmark: sustained solves/sec + latency under Poisson load.

    PYTHONPATH=src python -m benchmarks.serve_load             # full, CSV
    PYTHONPATH=src python -m benchmarks.serve_load --record    # + BENCH_serve.json
    PYTHONPATH=src python -m benchmarks.serve_load --smoke     # tier-1 guard

Open-loop load against the production solve service (DESIGN.md §9): a
seeded Poisson arrival process offers single-column solve requests at a
rate of ``--offered-batch`` (B) arrivals per ``--max-delay`` window, so
buckets mostly fill to B before their deadline.  Arrivals live on a
virtual timeline (the service's injectable clock — no sleeps anywhere);
solve cost is *measured* wall time per flush (``timer=perf_counter``),
and a single-server queueing replay of the flush log turns the two into
sustained throughput and per-request latency:

    completion(flush_i) = max(flush_time_i, server_free) + measured_dt_i
    latency(request)    = completion(last flush of its ticket) - arrival

Reported per matrix: micro-batched sustained solves/sec (requests over
total measured solve time), the sequential per-request baseline (every
request solved alone through the width-1 cached executor), their ratio,
and p50/p99 latency.  ``--record`` appends a dated entry to the
``BENCH_serve.json`` trajectory file (schema checked by
``scripts/check_bench.py``) so re-anchors see a curve, not just CSVs.

``--smoke`` (wired into tier-1 via `tests/test_serve.py`) runs a small
two-matrix load and asserts the micro-batched path beats the sequential
baseline and that every request completed exactly once.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import api
from repro.core.matrices import generate
from repro.core.serve import ManualClock, ProgramCache, SolveService

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
BENCH_SCHEMA = "sptrsv-bench-serve"
BENCH_VERSION = 1

FULL_SET = ("band_cz", "ckt_fpga", "chem_bp", "grid_activsg", "band_jagmesh")
SMOKE_SET = ("band_cz", "chem_bp")


def _run_service(mat, requests: int, offered_batch: int, max_delay: float,
                 seed: int, backend: str):
    """Drive one matrix's Poisson stream; returns (tickets+arrivals, stats)."""
    cache = ProgramCache(capacity=4)
    clock = ManualClock()
    svc = SolveService(cache, max_batch=offered_batch, max_delay=max_delay,
                       clock=clock, timer=time.perf_counter, backend=backend)
    svc.register(mat.name, mat)
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(max_delay / offered_batch,
                                         size=requests))
    cols = rng.standard_normal((mat.n, requests)).astype(np.float32)

    # warm: compile + trace every padded width a flush can hit, outside
    # the measured stream (production fleets serve warm programs)
    warm = SolveService(cache, max_batch=offered_batch, max_delay=max_delay,
                        clock=ManualClock(), backend=backend)
    warm.register(mat.name, mat)
    for k in range(1, offered_batch + 1):
        for _ in range(k):
            warm.submit(mat.name, cols[:, 0])
        warm.drain()

    tickets = []
    for i in range(requests):
        clock.now = float(arrivals[i])
        tickets.append((svc.submit(mat.name, cols[:, i]), float(arrivals[i])))
    clock.advance(max_delay)
    svc.pump()
    svc.drain()
    return tickets, svc.stats, cols


def _queue_replay(stats):
    """Single-server completion time per flush index (see module doc)."""
    completion = {}
    server_free = 0.0
    for f in stats.flushes:
        done = max(f.at, server_free) + f.service_s
        completion[f.index] = done
        server_free = done
    return completion


def _sequential_baseline(mat, cols, backend: str, repeat: int = 1) -> float:
    """Total seconds to solve every column alone (width-1 executor)."""
    prog = ProgramCache(capacity=1).get(mat)
    if backend == "numpy":
        solve = lambda b: api.solve_numpy(prog, b)  # noqa: E731
    else:
        solve = api.make_solver(prog)
    solve(cols[:, 0])  # warm the trace
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        for i in range(cols.shape[1]):
            solve(cols[:, i])
        best = min(best, time.perf_counter() - t0)
    return best


def bench_matrix(name: str, requests: int, offered_batch: int,
                 max_delay: float, seed: int, backend: str) -> dict:
    mat = generate(name)
    tickets, stats, cols = _run_service(mat, requests, offered_batch,
                                        max_delay, seed, backend)
    assert all(t.done for t, _ in tickets), f"{name}: unfinished tickets"
    completion = _queue_replay(stats)
    lat = np.asarray([completion[max(t.flush_indices)] - arr
                      for t, arr in tickets])
    busy = sum(f.service_s for f in stats.flushes)
    seq_s = _sequential_baseline(mat, cols, backend)
    batched = requests / busy if busy > 0 else float("inf")
    sequential = requests / seq_s if seq_s > 0 else float("inf")
    mean_cols = (sum(f.columns for f in stats.flushes)
                 / max(1, stats.flush_count()))
    return {
        "name": name,
        "n": mat.n,
        "requests": requests,
        "offered_batch": offered_batch,
        "batched_solves_per_s": round(batched, 1),
        "sequential_solves_per_s": round(sequential, 1),
        "speedup": round(batched / sequential, 2),
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
        "mean_batch_cols": round(mean_cols, 1),
        "flushes_full": stats.flushes_full,
        "flushes_deadline": stats.flushes_deadline + stats.flushes_drain,
    }


def record_trajectory(rows: list[dict], offered_batch: int,
                      label: str) -> None:
    """Append a dated entry to the BENCH_serve.json trajectory file."""
    doc = {"schema": BENCH_SCHEMA, "version": BENCH_VERSION, "entries": []}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            doc = json.load(f)
    doc["entries"].append({
        "recorded": time.strftime("%Y-%m-%d"),
        "label": label,
        "host": "cpu-interpret" if not _on_tpu() else "tpu",
        "offered_batch": offered_batch,
        "rows": rows,
    })
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# trajectory entry #{len(doc['entries'])} -> {BENCH_JSON}")


def _on_tpu() -> bool:
    import jax

    return jax.devices()[0].platform == "tpu"


def run(smoke: bool = False, requests: int | None = None,
        offered_batch: int = 16, max_delay: float = 5e-3, seed: int = 0,
        backend: str = "jax", names=None) -> list[dict]:
    names = names or (SMOKE_SET if smoke else FULL_SET)
    requests = requests or (64 if smoke else 256)
    return [bench_matrix(n, requests, offered_batch, max_delay, seed, backend)
            for n in names]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--record", action="store_true",
                    help="append results to BENCH_serve.json")
    ap.add_argument("--label", default="serve-load")
    ap.add_argument("--matrices", default="")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--offered-batch", type=int, default=16)
    ap.add_argument("--max-delay", type=float, default=5e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="jax",
                    choices=("jax", "numpy", "pallas"))
    args = ap.parse_args(argv)
    names = tuple(args.matrices.split(",")) if args.matrices else None
    rows = run(smoke=args.smoke, requests=args.requests or None,
               offered_batch=args.offered_batch, max_delay=args.max_delay,
               seed=args.seed, backend=args.backend, names=names)
    if args.smoke:
        for r in rows:
            assert r["speedup"] >= 1.5, (
                f"{r['name']}: micro-batching only {r['speedup']}x the "
                f"sequential baseline")
        print(f"# smoke: {len(rows)} matrices served, micro-batched "
              f"throughput {min(r['speedup'] for r in rows)}-"
              f"{max(r['speedup'] for r in rows)}x sequential at "
              f"B={args.offered_batch}")
        return
    emit(rows, "serve_load")
    worst = min(r["speedup"] for r in rows)
    print(f"# worst micro-batched/sequential speedup {worst}x at "
          f"B={args.offered_batch} (acceptance bar: >= 5x)")
    if args.record:
        record_trajectory(rows, args.offered_batch, args.label)


if __name__ == "__main__":
    main()
