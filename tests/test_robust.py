"""Hardened solve path: validator, taxonomy, ladder, fault injection.

Covers the four layers of DESIGN.md §7: `verify_program` (clean on every
frontend's output, and each structural invariant violated in isolation is
caught and named), the exception taxonomy (every leaf keeps its historical
builtin), the unified backend-dispatch rejections (one test per rejected
combination), CSR validation as structured `MatrixValidationError`s that
survive ``python -O``, the `RobustSolver` degradation ladder (oracle-equal
results after every forced degradation stage, deterministic deadlines on
an injected clock, bounded retries, incident trails), and the end-to-end
fault-injection smoke tier of `benchmarks/robust_overhead.py`.
"""

import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import api
from repro.core.csr import TriCSR, from_coo, random_rhs, serial_solve, transpose_upper
from repro.core.errors import (
    BackendExecutionError,
    BackendOptionsError,
    MatrixValidationError,
    NumericalHealthError,
    PlacementInfeasibleError,
    ProgramCorruptionError,
    RobustnessError,
    UnknownBackendError,
)
from repro.core.frontends.dagcirc import random_circuit
from repro.core.matrices import generate
from repro.core.program import (
    PS_LOAD,
    PS_STORE_RESET,
    PS_SWAP,
    decode_instructions,
    pack_instructions,
)
from repro.core.robust import (
    FAULT_CLASSES,
    LADDER,
    FaultInjector,
    RobustSolver,
    relative_residual,
    run_fault_injection,
    verify_program,
)
from repro.kernels.sptrsv import ops

TOL = dict(rtol=1e-5, atol=1e-5)  # jax rungs compute in float32


@pytest.fixture(scope="module")
def band():
    mat = generate("band_cz")
    return mat, api.compile(mat)


@pytest.fixture(scope="module")
def ckt():
    mat = generate("ckt_rajat04")  # psum-heavy, blocked-infeasible
    return mat, api.compile(mat)


def _repack(prog, op, src, ctl, slot):
    return dataclasses.replace(
        prog, instr=pack_instructions(op, src, ctl, slot, planes=prog.planes))


# ===================================================== verify_program: clean
def test_verify_clean_on_lower(band, ckt):
    verify_program(band[1])
    verify_program(ckt[1])


def test_verify_clean_on_upper_and_circuit(band):
    verify_program(api.compile_upper(transpose_upper(band[0])).program)
    circ = random_circuit(160, max_fan_in=5, seed=4, locality=48)
    verify_program(api.compile_circuit(circ).program)


# ============================================ verify_program: each invariant
def test_verify_rejects_nonfinite_stream(band):
    bad = FaultInjector(0).corrupt_stream(band[1], k=2, mode="nan")
    with pytest.raises(ProgramCorruptionError, match="non-finite"):
        verify_program(bad)


def test_verify_rejects_val_idx_out_of_bounds(band):
    bad = dataclasses.replace(band[1], val_idx=band[1].val_idx.copy())
    bad.val_idx[3, 0] = bad.stream.size + 7
    with pytest.raises(ProgramCorruptionError, match="bounds"):
        verify_program(bad)


def test_verify_rejects_nonzero_nop_lane(band):
    prog = band[1]
    op, src, ctl, slot = decode_instructions(prog.instr, prog.planes)
    t, p = np.argwhere(op == 0)[0]  # a NOP lane (pad rows guarantee some)
    src = src.copy()
    src[t, p] = 1  # bits flipped into a field the executor ignores on NOP
    with pytest.raises(ProgramCorruptionError, match="NOP lane"):
        verify_program(_repack(prog, op, src, ctl, slot))


def test_verify_rejects_src_beyond_n(band):
    prog = band[1]
    op, src, ctl, slot = decode_instructions(prog.instr, prog.planes)
    t, p = np.argwhere(op != 0)[0]
    src = src.copy()
    src[t, p] = prog.n + 5
    bad = _repack(prog, op, src, ctl, slot)
    bad = dataclasses.replace(bad, row_lo=None, row_hi=None)  # isolate check
    with pytest.raises(ProgramCorruptionError, match="reads row"):
        verify_program(bad)


def test_verify_rejects_duplicate_final(band):
    prog = band[1]
    op, src, ctl, slot = decode_instructions(prog.instr, prog.planes)
    finals = np.argwhere(op == 2)
    (t0, p0), (t1, p1) = finals[0], finals[1]
    src = src.copy()
    src[t1, p1] = src[t0, p0]  # row finalized twice, another never
    bad = dataclasses.replace(_repack(prog, op, src, ctl, slot),
                              row_lo=None, row_hi=None)
    with pytest.raises(ProgramCorruptionError, match="finalized"):
        verify_program(bad)


def test_verify_rejects_dependency_order_violation(band):
    """Reversing the cycle axis (metadata kept consistent) breaks topology."""
    prog = band[1]
    bad = dataclasses.replace(
        prog,
        instr=prog.instr[::-1].copy(),
        val_idx=prog.val_idx[::-1].copy(),
        row_lo=prog.row_lo[::-1].copy(),
        row_hi=prog.row_hi[::-1].copy(),
    )
    with pytest.raises(ProgramCorruptionError, match="dependency order"):
        verify_program(bad)


def test_verify_rejects_zero_final_reciprocal(band):
    prog = band[1]
    op, _, _, _ = decode_instructions(prog.instr, prog.planes)
    t, p = np.argwhere(op == 2)[0]
    bad = dataclasses.replace(prog, stream=prog.stream.copy())
    bad.stream[prog.val_idx[t, p]] = 0.0
    with pytest.raises(ProgramCorruptionError, match="zero diagonal"):
        verify_program(bad)


def test_verify_rejects_load_before_store(ckt):
    prog = ckt[1]
    from repro.core.executor import _psum_slots

    op, src, ctl, slot = decode_instructions(prog.instr, prog.planes)
    nslots = _psum_slots(prog)
    store = (ctl == PS_STORE_RESET) | (ctl == PS_SWAP)
    # inject a LOAD of a slot no earlier instruction on that CU has stored
    for t, p in np.argwhere((op != 0) & (ctl == 0)):
        stored = set(slot[:t, p][store[:t, p]].tolist())
        s = next((s for s in range(nslots) if s not in stored), None)
        if s is not None:
            break
    assert s is not None, "no injectable lane found"
    ctl, slot = ctl.copy(), slot.copy()
    ctl[t, p], slot[t, p] = PS_LOAD, s
    with pytest.raises(ProgramCorruptionError, match="psum lifetime"):
        verify_program(_repack(prog, op, src, ctl, slot))


def test_verify_rejects_slot_beyond_register_file(ckt):
    prog = ckt[1]
    bad = FaultInjector(1).corrupt_slots(prog, k=4)
    with pytest.raises(ProgramCorruptionError):
        verify_program(bad)  # slot range or lifetime, depending on rewrite


def test_verify_rejects_stale_row_envelope(band):
    prog = band[1]
    bad = dataclasses.replace(prog, row_lo=prog.row_lo.copy())
    t = int(np.argmax(prog.row_hi >= 0))
    bad.row_lo[t] += 1
    with pytest.raises(ProgramCorruptionError, match="row-envelope"):
        verify_program(bad)


# =========================================================== error taxonomy
@pytest.mark.parametrize("leaf,builtin", [
    (ProgramCorruptionError, ValueError),
    (MatrixValidationError, ValueError),
    (NumericalHealthError, ValueError),
    (BackendExecutionError, RuntimeError),
    (UnknownBackendError, ValueError),
    (BackendOptionsError, TypeError),
    (PlacementInfeasibleError, ValueError),
])
def test_taxonomy_keeps_historical_builtin(leaf, builtin):
    err = leaf("boom", detail={"k": 1})
    assert isinstance(err, RobustnessError) and isinstance(err, builtin)
    assert err.detail == {"k": 1}


def test_taxonomy_hierarchy():
    assert issubclass(UnknownBackendError, BackendExecutionError)
    assert issubclass(BackendOptionsError, BackendExecutionError)
    assert issubclass(PlacementInfeasibleError, BackendExecutionError)


# ============================================== backend dispatch rejections
def test_unknown_backend_rejected(band):
    b = random_rhs(band[0], seed=0)
    with pytest.raises(UnknownBackendError, match="bogus"):
        api.solve_batch(band[1], np.stack([b, b], 1), backend="bogus")


def test_jax_backend_rejects_pallas_options(band):
    b = random_rhs(band[0], seed=0)
    with pytest.raises(BackendOptionsError, match="cycles_per_block"):
        api.solve_batch(band[1], np.stack([b, b], 1), backend="jax",
                        cycles_per_block=64)


def test_infeasible_blocked_placement_rejected(ckt):
    with pytest.raises(PlacementInfeasibleError, match="infeasible"):
        ops.resolve_placement(ckt[1], 8, placement="blocked")


def test_robust_solver_rejects_unknown_backend(band):
    with pytest.raises(UnknownBackendError, match="bogus"):
        RobustSolver(band[1], band[0], backend="bogus")


# ========================================================== CSR validation
def _lower(n=6, seed=0):
    rng = np.random.default_rng(seed)
    rows = list(range(1, n))
    cols = [0] * (n - 1)
    return from_coo(n, rows, cols, rng.standard_normal(n - 1),
                    rng.standard_normal(n) + 3.0, name="probe")


def test_csr_zero_diagonal_named(band):
    mat = _lower()
    mat.values[mat.rowptr[3] - 1] = 0.0  # row 2's diagonal (stored last)
    with pytest.raises(MatrixValidationError, match=r"'probe', row 2.*zero"):
        mat.validate()


def test_csr_super_diagonal_named():
    bad = TriCSR(n=2, rowptr=np.array([0, 2, 3]),
                 colidx=np.array([1, 0, 1]), values=np.ones(3), name="sup")
    with pytest.raises(MatrixValidationError, match=r"'sup'.*super-diagonal"):
        bad.validate()


def test_csr_missing_diagonal_named():
    bad = TriCSR(n=2, rowptr=np.array([0, 1, 1]), colidx=np.array([0]),
                 values=np.ones(1), name="gap")
    with pytest.raises(MatrixValidationError, match=r"'gap', row 1.*missing"):
        bad.validate()


def test_csr_diag_position_named():
    bad = TriCSR(n=2, rowptr=np.array([0, 1, 3]),
                 colidx=np.array([0, 1, 0]), values=np.ones(3), name="pos")
    with pytest.raises(MatrixValidationError, match=r"'pos'.*stored last"):
        bad.validate()


def test_csr_unsorted_columns_named():
    bad = TriCSR(n=3, rowptr=np.array([0, 1, 2, 5]),
                 colidx=np.array([0, 1, 1, 0, 2]), values=np.ones(5),
                 name="uns")
    with pytest.raises(MatrixValidationError,
                       match=r"'uns', row 2.*unsorted"):
        bad.validate()


def test_from_coo_rejects_diagonal_entry():
    with pytest.raises(MatrixValidationError, match="strictly lower"):
        from_coo(3, [1, 2], [1, 0], [1.0, 1.0], np.ones(3), name="coo")


def test_csr_validation_survives_optimized_mode():
    """The structured checks are not ``assert``s: alive under python -O."""
    code = (
        "import sys; sys.path.insert(0, 'src')\n"
        "import numpy as np\n"
        "from repro.core.csr import TriCSR\n"
        "from repro.core.errors import MatrixValidationError\n"
        "bad = TriCSR(n=2, rowptr=np.array([0, 1, 1]),\n"
        "             colidx=np.array([0]), values=np.ones(1), name='opt')\n"
        "try:\n"
        "    bad.validate()\n"
        "except MatrixValidationError as e:\n"
        "    assert 'opt' in str(e); print('CAUGHT')\n"
    )
    out = subprocess.run([sys.executable, "-O", "-c", code],
                         capture_output=True, text=True,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0 and "CAUGHT" in out.stdout, out.stderr


# ======================================================= RobustSolver: happy
def test_robust_solve_matches_oracle(band):
    mat, prog = band
    rs = api.robust_solver(prog, mat, backend="jax")
    b = random_rhs(mat, seed=7)
    np.testing.assert_allclose(rs(b), serial_solve(mat, b), **TOL)
    assert rs.last_stage == "jax" and rs.last_incidents == []
    B = np.stack([random_rhs(mat, seed=s) for s in range(3)], axis=1)
    X = rs.solve(B)
    assert X.shape == (mat.n, 3)
    assert relative_residual(mat, X, B) < 1e-5


def test_ladder_entry_rungs(band):
    mat, prog = band
    assert RobustSolver(prog, mat, backend="numpy").ladder == \
        ("numpy", "reference")
    assert RobustSolver(prog, mat, backend="jax").ladder == \
        ("jax", "numpy", "reference")
    assert RobustSolver(prog, backend="jax").ladder == ("jax", "numpy")
    assert RobustSolver(prog, mat, backend="pallas").ladder == LADDER


@pytest.mark.parametrize("stage", ["jax", "numpy", "reference"])
def test_every_forced_stage_matches_oracle(band, stage):
    """Each rung alone returns the numpy-oracle answer (degradation-safe)."""
    mat, prog = band
    rs = RobustSolver(prog, mat, ladder=(stage,))
    b = random_rhs(mat, seed=11)
    np.testing.assert_allclose(rs(b), serial_solve(mat, b), **TOL)
    assert rs.last_stage == stage


# ================================================= RobustSolver: degradation
def test_build_failure_degrades_with_incident(ckt):
    """An infeasible blocked placement degrades; the incident names it."""
    mat, prog = ckt
    rs = RobustSolver(prog, mat, ladder=("pallas-blocked", "jax"))
    b = random_rhs(mat, seed=2)
    np.testing.assert_allclose(rs(b), serial_solve(mat, b), **TOL)
    assert rs.last_stage == "jax"
    (inc,) = [i for i in rs.last_incidents if i.stage == "pallas-blocked"]
    assert inc.kind == "build-failed" and "infeasible" in inc.message
    rs.solve(b)  # rung stays disabled: no repeated build attempt
    assert rs.last_incidents == []


def test_corrupt_program_degrades_to_reference(band):
    """Value-plane damage fails residual on every program rung; the
    reference rung (direct CSR solve) still returns the *correct* x."""
    mat, prog = band
    bad = FaultInjector(3).corrupt_stream(prog, k=3, mode="scale")
    rs = RobustSolver(bad, mat, verify=False, ladder=("jax", "numpy",
                                                      "reference"))
    b = random_rhs(mat, seed=5)
    np.testing.assert_allclose(rs(b), serial_solve(mat, b), **TOL)
    assert rs.last_stage == "reference"
    assert [(i.stage, i.kind) for i in rs.last_incidents] == \
        [("jax", "residual"), ("numpy", "residual")]


def test_exhausted_ladder_raises_with_incident_trail(band):
    mat, prog = band
    bad = FaultInjector(3).corrupt_stream(prog, k=3, mode="scale")
    rs = RobustSolver(bad, mat, verify=False, ladder=("jax", "numpy"))
    with pytest.raises(NumericalHealthError, match="all ladder stages") as ei:
        rs.solve(random_rhs(mat, seed=5))
    trail = ei.value.detail["incidents"]
    assert [t["kind"] for t in trail] == ["residual", "residual"]


def test_stage_deadline_disables_rung(band):
    mat, prog = band
    ticks = iter([0.0, 10.0,   # jax rung: elapsed 10s > deadline
                  10.0, 10.1,  # numpy rung: 0.1s, fine
                  20.0, 20.1])  # second solve goes straight to numpy
    rs = RobustSolver(prog, mat, stage_deadline_s=1.0,
                      clock=lambda: next(ticks),
                      ladder=("jax", "numpy"))
    b = random_rhs(mat, seed=9)
    np.testing.assert_allclose(rs(b), serial_solve(mat, b), **TOL)
    assert rs.last_stage == "numpy"
    assert [i.kind for i in rs.last_incidents] == ["deadline"]
    rs.solve(b)  # "jax" now persistently disabled
    assert rs.last_stage == "numpy" and rs.last_incidents == []


def test_bounded_retry_then_success(band):
    mat, prog = band
    calls = {"n": 0}

    class Flaky(RobustSolver):
        def _solver_for(self, stage, batch):
            inner = super()._solver_for(stage, batch)

            def flaky(b):
                calls["n"] += 1
                if calls["n"] == 1:
                    raise RuntimeError("transient lane fault")
                return inner(b)
            return flaky

    rs = Flaky(prog, mat, max_retries=1, ladder=("jax",))
    b = random_rhs(mat, seed=13)
    np.testing.assert_allclose(rs(b), serial_solve(mat, b), **TOL)
    assert calls["n"] == 2 and rs.last_stage == "jax"
    (inc,) = rs.last_incidents
    assert (inc.kind, inc.attempt, inc.error) == \
        ("exception", 1, "RuntimeError")


def test_retries_are_bounded(band):
    mat, prog = band

    class Broken(RobustSolver):
        def _solver_for(self, stage, batch):
            def boom(b):
                raise RuntimeError("permanent fault")
            return boom

    rs = Broken(prog, mat, max_retries=1, ladder=("jax",))
    with pytest.raises(BackendExecutionError) as ei:
        rs.solve(random_rhs(mat, seed=1))
    assert [t["attempt"] for t in ei.value.detail["incidents"]] == [1, 2]


# ==================================================== RobustSolver: inputs
def test_nonfinite_rhs_rejected(band):
    mat, prog = band
    rs = api.robust_solver(prog, mat)
    bad = random_rhs(mat, seed=0)
    bad[4] = np.nan
    with pytest.raises(NumericalHealthError, match="non-finite"):
        rs(bad)
    bad[4] = np.inf
    with pytest.raises(NumericalHealthError, match="non-finite"):
        rs(bad)


def test_wrong_shape_and_dtype_rejected(band):
    mat, prog = band
    rs = api.robust_solver(prog, mat)
    with pytest.raises(NumericalHealthError, match=r"\[n\] or \[n, B\]"):
        rs(np.zeros(mat.n + 1))
    with pytest.raises(NumericalHealthError, match="not numeric"):
        rs(np.array(["a"] * mat.n, dtype=object))


def test_construction_verifies_program(band):
    bad = FaultInjector(0).corrupt_stream(band[1], k=1, mode="nan")
    with pytest.raises(ProgramCorruptionError, match="non-finite"):
        RobustSolver(bad, band[0])


# ================================================ fault-injection smoke tier
def test_fault_injection_no_silent_wrong_answers(ckt):
    """Every fault class is detected or safely degraded — the PR's bar."""
    trials = run_fault_injection(ckt[0], ckt[1], trials_per_class=2, seed=0)
    assert {t["fault"] for t in trials} == set(FAULT_CLASSES)
    assert not any(t["silent_wrong"] for t in trials), trials
    by_class = {}
    for t in trials:
        by_class.setdefault(t["fault"], []).append(t["detected"])
    # structural and I/O faults are *detected*, never merely degraded
    for fault in ("psum_slot", "blob", "rhs_nan", "rhs_inf"):
        assert all(d != "none" for d in by_class[fault]), by_class[fault]


def test_benchmark_smoke_tier():
    from benchmarks.robust_overhead import run

    rows = run(smoke=True)
    assert rows, "smoke set is empty"
    assert sum(r["silent_wrong"] for r in rows) == 0
    assert {r["fault"] for r in rows} == set(FAULT_CLASSES)
