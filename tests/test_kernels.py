"""Per-kernel allclose sweeps vs the ref.py pure-jnp oracles (interpret)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core.csr import random_rhs, serial_solve
from repro.core.matrices import generate


# ------------------------------------------------------------------ sptrsv
@pytest.mark.parametrize("name,cpb", [
    ("chain_1k", 128), ("band_cz", 64), ("ckt_rajat04", 256), ("chem_bp", 32),
])
def test_sptrsv_kernel_vs_oracle(name, cpb):
    from repro.kernels.sptrsv import ops

    mat = generate(name)
    prog = api.compile(mat)
    b = random_rhs(mat, 3)
    x = ops.solve(prog, b, cycles_per_block=cpb, interpret=True)
    np.testing.assert_allclose(
        x, serial_solve(mat, b).astype(np.float32), rtol=2e-4, atol=2e-4
    )


def test_sptrsv_kernel_vs_program_oracle():
    from repro.kernels.sptrsv import ops, ref

    mat = generate("band_cz")
    prog = api.compile(mat)
    b = random_rhs(mat, 4)
    np.testing.assert_allclose(
        ops.solve(prog, b, interpret=True),
        ref.solve_program(prog, b),
        rtol=1e-5, atol=1e-5,
    )


# ------------------------------------------------------------------ ssd_scan
@pytest.mark.parametrize("B,L,H,K,V", [
    (1, 64, 1, 8, 8),
    (2, 128, 2, 16, 32),
    (2, 200, 3, 32, 48),   # L not a chunk multiple -> padding path
    (1, 320, 2, 64, 64),
])
@pytest.mark.parametrize("inclusive", [True, False])
def test_ssd_scan_shapes(B, L, H, K, V, inclusive):
    from repro.kernels.ssd_scan import ops
    from repro.kernels.ssd_scan.ref import scan_ref

    rng = np.random.default_rng(hash((B, L, H, K, V, inclusive)) % 2**31)
    q = jnp.asarray(rng.standard_normal((B, L, H, K)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, L, H, K)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((B, L, H, V)), jnp.float32)
    w = jnp.asarray(-rng.uniform(0, 0.2, (B, L, H, K)), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((B, H, K, V)), jnp.float32) * 0.1
    u = None if inclusive else jnp.asarray(
        rng.standard_normal((H, K)), jnp.float32) * 0.1

    for use_pallas in (False, True):
        y, sf = ops.linear_recurrence(
            q, k, v, w, s0, u, chunk=64, inclusive=inclusive,
            use_pallas=use_pallas, interpret=True,
        )
        merge = lambda x, d: x.transpose(0, 2, 1, 3).reshape(B * H, L, d)
        yr, sfr = scan_ref(
            merge(q, K), merge(k, K), merge(v, V),
            jnp.clip(merge(w, K), ops.MIN_LOG_DECAY, 0),
            s0.reshape(B * H, K, V), inclusive=inclusive,
        )
        yr = yr.reshape(B, H, L, V).transpose(0, 2, 1, 3)
        if u is not None:
            gate = jnp.einsum("blhk,hk,blhk->blh", q, u, k)
            yr = yr + gate[..., None] * v
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(
            np.asarray(sf).reshape(B * H, K, V), np.asarray(sfr),
            rtol=2e-4, atol=2e-4,
        )


def test_ssd_scan_bf16():
    from repro.kernels.ssd_scan import ops

    rng = np.random.default_rng(0)
    B, L, H, K, V = 1, 128, 2, 16, 16
    mk = lambda s: jnp.asarray(rng.standard_normal(s), jnp.bfloat16)
    q, k, v = mk((B, L, H, K)), mk((B, L, H, K)), mk((B, L, H, V))
    w = -jnp.abs(mk((B, L, H, K))) * 0.1
    y16, _ = ops.linear_recurrence(q, k, v, w, use_pallas=False)
    y32, _ = ops.linear_recurrence(
        q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), w.astype(jnp.float32), use_pallas=False,
    )
    assert y16.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(y16, np.float32), np.asarray(y32), rtol=0.1, atol=0.15
    )


def test_ssd_chunk_invariance():
    """Medium-granularity chunking must not change the math (chunk size is
    a pure performance knob — the psum feedback makes it exact)."""
    from repro.kernels.ssd_scan import ops

    rng = np.random.default_rng(5)
    B, L, H, K, V = 2, 256, 2, 16, 16
    q = jnp.asarray(rng.standard_normal((B, L, H, K)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, L, H, K)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((B, L, H, V)), jnp.float32)
    w = jnp.asarray(-rng.uniform(0, 0.2, (B, L, H, K)), jnp.float32)
    outs = [
        np.asarray(ops.linear_recurrence(q, k, v, w, chunk=c)[0])
        for c in (16, 64, 256)
    ]
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("B,Lq,Hq,Hkv,D,bq,bk", [
    (1, 128, 2, 2, 32, 64, 64),
    (2, 200, 8, 2, 64, 64, 128),     # ragged lengths + GQA
    (1, 96, 4, 1, 128, 32, 32),      # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(B, Lq, Hq, Hkv, D, bq, bk, causal):
    from repro.kernels.flash_attention.ops import gqa_attention

    rng = np.random.default_rng(hash((B, Lq, Hq, causal)) % 2**31)
    q = jnp.asarray(rng.standard_normal((B, Lq, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Lq, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Lq, Hkv, D)), jnp.float32)
    o_ref = gqa_attention(q, k, v, causal=causal, use_pallas=False)
    o_pal = gqa_attention(q, k, v, causal=causal, use_pallas=True,
                          interpret=True, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention.ops import gqa_attention

    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 128, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.bfloat16)
    o_ref = gqa_attention(q, k, v, use_pallas=False)
    o_pal = gqa_attention(q, k, v, use_pallas=True, interpret=True,
                          block_q=64, block_k=64)
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref, np.float32),
        rtol=0.05, atol=0.05,
    )


def test_attention_blocked_matches_exact():
    from repro.kernels.flash_attention.ref import attention_blocked, attention_ref

    rng = np.random.default_rng(7)
    for (bh, l, d, bk, causal) in [(4, 256, 32, 64, True), (2, 300, 64, 128, False),
                                   (1, 512, 16, 512, True)]:
        q = jnp.asarray(rng.standard_normal((bh, l, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((bh, l, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((bh, l, d)), jnp.float32)
        a = attention_ref(q, k, v, scale=d ** -0.5, causal=causal)
        b = attention_blocked(q, k, v, scale=d ** -0.5, causal=causal, block_k=bk)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_ssd_decode_fast_path_matches_chunked():
    from repro.kernels.ssd_scan import ops

    rng = np.random.default_rng(9)
    B, H, K, V = 2, 3, 16, 16
    s0 = jnp.asarray(rng.standard_normal((B, H, K, V)), jnp.float32) * 0.2
    # one-token step (fast path) vs the same step through the chunked path
    q = jnp.asarray(rng.standard_normal((B, 1, H, K)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, 1, H, K)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, 1, H, V)), jnp.float32)
    w = jnp.asarray(-rng.uniform(0, 0.2, (B, 1, H, K)), jnp.float32)
    for inclusive in (True, False):
        u = None if inclusive else jnp.asarray(
            rng.standard_normal((H, K)), jnp.float32) * 0.1
        y1, s1 = ops.linear_recurrence(q, k, v, w, s0, u, inclusive=inclusive)
        # chunked path forced by replicating the token to seq 8
        q8 = jnp.tile(q, (1, 8, 1, 1)); k8 = jnp.tile(k, (1, 8, 1, 1))
        v8 = jnp.tile(v, (1, 8, 1, 1)); w8 = jnp.tile(w, (1, 8, 1, 1))
        y8, _ = ops.linear_recurrence(q8, k8, v8, w8, s0, u,
                                      inclusive=inclusive, chunk=64)
        np.testing.assert_allclose(np.asarray(y1[:, 0]), np.asarray(y8[:, 0]),
                                   rtol=2e-4, atol=2e-4)
