"""Staged compiler pipeline: legacy equivalence + per-pass contracts.

The pipeline (`core/compiler/`) must reproduce the frozen pre-refactor
monolith (`tests/legacy_schedule.py`) bit-for-bit: identical packed
instruction stream, value stream, row envelopes and stats on the bundled
matrix suite — a fast subset in tier-1, the full suite marked ``slow``.
"""

import dataclasses
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))  # tests/legacy_schedule.py

import legacy_schedule  # noqa: E402

from repro.core import compiler  # noqa: E402
from repro.core.compiler import ir, sched  # noqa: E402
from repro.core.frontends.sptrsv import lower_tri  # noqa: E402
from repro.core.matrices import generate, suite_names  # noqa: E402
from repro.core.program import MAX_SLOT, SLOT_BITS, AccelConfig  # noqa: E402
from repro.core.schedule import allocate_nodes, compile_program  # noqa: E402

FAST_SET = ["band_cz", "ckt_rajat04", "chem_bp", "wide_c36", "hub_small"]
CFG_VARIANTS = [
    AccelConfig(),
    AccelConfig(psum_cache=False),
    AccelConfig(icr=False),
    AccelConfig(alloc="roundrobin"),
    AccelConfig(psum_words=2),
    AccelConfig(dataflow="coarse", icr=False, psum_cache=False),
]


def _stats_dict(st):
    d = dataclasses.asdict(st)
    d.pop("compile_seconds")        # timing — not part of the contract
    d.pop("pass_stats")             # pipeline-only observability
    per_cu = d.pop("per_cu_edges")
    return d, per_cu


def assert_programs_identical(a, b, ctx=""):
    assert np.array_equal(a.instr, b.instr), f"{ctx}: instr differs"
    assert np.array_equal(a.val_idx, b.val_idx), f"{ctx}: val_idx differs"
    assert np.array_equal(a.stream, b.stream), f"{ctx}: stream differs"
    assert np.array_equal(a.row_lo, b.row_lo), f"{ctx}: row_lo differs"
    assert np.array_equal(a.row_hi, b.row_hi), f"{ctx}: row_hi differs"
    assert a.num_slots == b.num_slots, ctx
    da, pa = _stats_dict(a.stats)
    db, pb = _stats_dict(b.stats)
    diff = {k: (da[k], db[k]) for k in da if da[k] != db[k]}
    assert not diff, f"{ctx}: stats differ: {diff}"
    assert np.array_equal(pa, pb), f"{ctx}: per_cu_edges differ"


@pytest.mark.parametrize("name", FAST_SET)
def test_pipeline_matches_legacy(name):
    mat = generate(name)
    for cfg in CFG_VARIANTS:
        legacy = legacy_schedule.compile_program(mat, cfg)
        staged = compile_program(mat, cfg)
        assert_programs_identical(legacy, staged, f"{name}/{cfg.dataflow}")


@pytest.mark.slow
def test_pipeline_matches_legacy_full_suite():
    """Acceptance: identical Program.instr/stats on the FULL bundled suite."""
    for name in suite_names():
        mat = generate(name)
        assert_programs_identical(
            legacy_schedule.compile_program(mat),
            compile_program(mat),
            name,
        )


def test_pipeline_records_all_passes():
    prog = compile_program(generate("band_cz"))
    names = [p.name for p in prog.stats.pass_stats]
    assert names == list(compiler.PASS_NAMES)
    by = {p.name: p for p in prog.stats.pass_stats}
    assert by["partition"].metrics["edges"] == prog.stats.nnz - prog.n
    assert by["psum_schedule"].metrics["hardware_cycles"] == prog.stats.cycles
    assert by["stall_elide"].metrics["emitted_cycles"] == prog.cycles
    assert by["pack_emit"].metrics["instr_bytes"] == prog.instr_bytes()
    assert by["icr_reorder"].metrics["reuse_events"] == prog.stats.reuse_events
    assert all(p.seconds >= 0 for p in prog.stats.pass_stats)


def test_pass_boundaries_compose():
    """Each stage's IR output feeds the next; spot-check the invariants."""
    mat = generate("ckt_rajat04")
    cfg = AccelConfig()
    dag = lower_tri(mat)
    pir = compiler.partition.run(dag)
    assert [len(c) for c in pir.consumers] == \
        np.bincount(dag.src, minlength=dag.n).tolist()
    air = compiler.assign.run(pir, cfg)
    assert sorted(i for ts in air.task_lists for i in ts) == list(range(mat.n))
    assert all(air.owner[i] == c
               for c, ts in enumerate(air.task_lists) for i in ts)
    sir = compiler.sched.run(air, cfg)
    assert sir.ops.shape[0] == sir.stats.cycles  # dense: incl. stall rows
    eir = compiler.elide.run(sir)
    assert eir.ops.shape[0] == sir.stats.emitted_cycles <= sir.stats.cycles
    assert np.all(eir.ops.max(axis=1) > 0)       # no all-NOP row survives
    prog = compiler.emit.run(eir, cfg)
    assert prog.cycles == eir.ops.shape[0]


def test_allocate_nodes_wrapper_unchanged():
    mat = generate("chem_bp")
    tasks = allocate_nodes(mat, AccelConfig())
    legacy = legacy_schedule.allocate_nodes(mat, AccelConfig())
    assert tasks == legacy


def test_frontend_contract_violations_rejected():
    bad_src = ir.ComputeDag("bad", 2, np.array([0, 1, 1]),
                            np.array([1]), np.array([1.0]), np.ones(2))
    with pytest.raises(ValueError, match="smaller node id"):
        bad_src.validate()
    zero_scale = ir.ComputeDag("bad", 2, np.array([0, 0, 1]),
                               np.array([0]), np.array([1.0]),
                               np.array([1.0, 0.0]))
    with pytest.raises(ValueError, match="finite and non-zero"):
        zero_scale.validate()
    dup = ir.ComputeDag("bad", 3, np.array([0, 0, 0, 2]),
                        np.array([0, 0]), np.ones(2), np.ones(3))
    with pytest.raises(ValueError, match="ascending"):
        dup.validate()


def test_psum_overflow_cap_derived_from_slot_field():
    """Satellite: the overflow-slot cap comes from the packed slot width
    (8 bits ⇒ 255 incl. overflow) and the error names the workload + CU."""
    assert sched.MAX_PSUM_SLOT == MAX_SLOT == (1 << SLOT_BITS) - 1
    cu = sched._CU(7, "band_cz", [0], psum_words=8)
    cu.free_over.clear()
    cu.next_over = MAX_SLOT  # last representable slot id: still fine
    assert cu.peek_over_slot() == MAX_SLOT
    cu.next_over = MAX_SLOT + 1
    with pytest.raises(RuntimeError) as exc:
        cu.peek_over_slot()
    msg = str(exc.value)
    assert "band_cz" in msg and "CU 7" in msg and str(MAX_SLOT) in msg
