"""Property-based tests (hypothesis) on the compiler/executor invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import api
from repro.core.csr import from_coo, serial_solve
from repro.core.program import AccelConfig
from repro.core.schedule import compile_program


@st.composite
def random_triangular(draw):
    n = draw(st.integers(min_value=2, max_value=90))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    density = draw(st.floats(min_value=0.0, max_value=0.5))
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for i in range(1, n):
        m = rng.random(i) < density
        for j in np.nonzero(m)[0]:
            rows.append(i)
            cols.append(int(j))
    vals = rng.uniform(-1, 1, len(rows))
    diag = rng.uniform(1.0, 2.0, n) * rng.choice([-1.0, 1.0], n)
    return from_coo(n, rows, cols, vals, diag, name=f"hyp_{seed}")


@st.composite
def accel_config(draw):
    return AccelConfig(
        num_cus=draw(st.sampled_from([4, 8, 16, 64])),
        psum_words=draw(st.sampled_from([1, 2, 8])),
        xi_words=draw(st.sampled_from([8, 64])),
        num_banks=draw(st.sampled_from([8, 64])),
        icr=draw(st.booleans()),
        psum_cache=draw(st.booleans()),
        alloc=draw(st.sampled_from(["least_edges", "roundrobin"])),
        icr_window=draw(st.sampled_from([2, 16])),
    )


@settings(max_examples=40, deadline=None)
@given(random_triangular(), accel_config(), st.integers(0, 1000))
def test_executor_matches_oracle(mat, cfg, bseed):
    """For ANY matrix and ANY hardware config the compiled program must
    reproduce the serial solve — the fundamental system invariant."""
    prog = compile_program(mat, cfg)
    rng = np.random.default_rng(bseed)
    b = rng.standard_normal(mat.n)
    got = api.solve_numpy(prog, b)
    ref = serial_solve(mat, b)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


@settings(max_examples=40, deadline=None)
@given(random_triangular(), accel_config())
def test_schedule_invariants(mat, cfg):
    prog = compile_program(mat, cfg)
    st_ = prog.stats
    # every op exactly once
    assert st_.exec_edges == mat.nnz - mat.n
    assert st_.exec_finals == mat.n
    # cycle count bounded below by work/P and above by the serial bound
    assert st_.cycles >= mat.nnz / cfg.num_cus - 1
    assert st_.cycles <= 2 * mat.nnz + 64 * mat.n + 4096
    # stream memory consumed exactly once per op, in order
    assert len(prog.stream) == mat.nnz
    vi = prog.val_idx[prog.opcode > 0]
    assert sorted(vi.tolist()) == list(range(mat.nnz))


@settings(max_examples=25, deadline=None)
@given(random_triangular())
def test_causality(mat):
    """An edge may only read x[j] strictly after node j finalizes."""
    prog = api.compile(mat)
    solve_cycle = {}
    for t in range(prog.cycles):
        for c in range(prog.num_cus):
            if prog.opcode[t, c] == 2:
                solve_cycle[int(prog.out_idx[t, c])] = t
    for t in range(prog.cycles):
        for c in range(prog.num_cus):
            if prog.opcode[t, c] == 1:
                src = int(prog.src_idx[t, c])
                assert solve_cycle[src] < t, (src, t)
