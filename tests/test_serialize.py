"""Versioned checksummed Program serialization (`core/serialize.py`).

Round-trip fidelity (arrays, config, stats, exact solve parity), the
corruption contract — *any* byte-level damage to a saved blob raises
`ProgramCorruptionError`, exercised both with targeted defects (magic,
version, truncation, trailing bytes) and hypothesis-driven random k-byte
corruption — and the `api.save_program`/`load_program` surface including
the load-time structural verify (DESIGN.md §7).
"""

import dataclasses
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import api, serialize
from repro.core.csr import from_coo, random_rhs
from repro.core.errors import ProgramCorruptionError
from repro.core.matrices import generate
from repro.core.program import ScheduleStats
from repro.core.robust import FaultInjector


def tiny_matrix(n: int = 24, seed: int = 3):
    """A small random lower-tri system — keeps blobs byte-cheap."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for i in range(1, n):
        for j in rng.choice(i, size=min(i, int(rng.integers(1, 4))),
                            replace=False):
            rows.append(i), cols.append(int(j))
    vals = rng.standard_normal(len(rows)) * 0.3
    diag = rng.standard_normal(n) + 4.0
    return from_coo(n, rows, cols, vals, diag, name=f"tiny{n}")


@pytest.fixture(scope="module")
def prog():
    return api.compile(generate("band_cz"))


# ------------------------------------------------------------- round trip
def test_roundtrip_bit_exact(prog, tmp_path):
    path = tmp_path / "band_cz.prog"
    api.save_program(prog, path)
    p2 = api.load_program(path)
    for name in ("instr", "val_idx", "stream", "row_lo", "row_hi"):
        np.testing.assert_array_equal(getattr(prog, name), getattr(p2, name))
    assert p2.config == prog.config
    assert (p2.n, p2.num_slots) == (prog.n, prog.num_slots)
    assert p2.content_crc32() == prog.content_crc32()
    for f in dataclasses.fields(ScheduleStats):
        if f.name in ("per_cu_edges", "pass_stats"):
            continue
        assert getattr(p2.stats, f.name) == getattr(prog.stats, f.name), f.name
    np.testing.assert_array_equal(p2.stats.per_cu_edges,
                                  prog.stats.per_cu_edges)
    assert p2.stats.pass_stats is None  # compile-run telemetry, not artifact
    b = random_rhs(generate("band_cz"), seed=1)
    np.testing.assert_array_equal(api.solve_numpy(prog, b),
                                  api.solve_numpy(p2, b))


def test_roundtrip_without_row_metadata(prog):
    stripped = dataclasses.replace(prog, row_lo=None, row_hi=None)
    p2 = serialize.loads_program(serialize.dumps_program(stripped))
    assert p2.row_lo is None and p2.row_hi is None


# ------------------------------------------------------------- targeted defects
def test_bad_magic_version_truncation(prog):
    blob = serialize.dumps_program(prog)
    with pytest.raises(ProgramCorruptionError, match="magic"):
        serialize.loads_program(b"NOTPROG!" + blob[8:])
    bad_ver = blob[:8] + (99).to_bytes(4, "little") + blob[12:]
    with pytest.raises(ProgramCorruptionError, match="version"):
        serialize.loads_program(bad_ver)
    with pytest.raises(ProgramCorruptionError, match="truncated"):
        serialize.loads_program(blob[:10])
    with pytest.raises(ProgramCorruptionError, match="truncated|length"):
        serialize.loads_program(blob[:len(blob) // 2])
    with pytest.raises(ProgramCorruptionError, match="length"):
        serialize.loads_program(blob + b"\x00")


def test_corruption_is_a_valueerror(prog):
    """Taxonomy leaves keep the historical builtin for old callers."""
    blob = serialize.dumps_program(prog)
    with pytest.raises(ValueError):
        serialize.loads_program(blob[:10])


def test_load_verifies_structure(tmp_path):
    """CRC-clean but structurally corrupt content is stopped at load."""
    mat = tiny_matrix()
    prog = api.compile(mat)
    bad = FaultInjector(5).corrupt_stream(prog, k=1, mode="nan")
    path = tmp_path / "bad.prog"
    serialize.save_program(bad, path)  # checksums computed over bad bytes
    with pytest.raises(ProgramCorruptionError, match="non-finite"):
        api.load_program(path)
    p2 = api.load_program(path, verify=False)  # opt-out parses fine
    assert np.isnan(p2.stream).any()


# ------------------------------------------------------------- random corruption
_TINY = api.compile(tiny_matrix())
_BLOB = serialize.dumps_program(_TINY)


def _flip_k_bytes(blob: bytes, k: int, seed: int) -> bytes:
    rng = np.random.default_rng(seed)
    buf = bytearray(blob)
    for i in rng.integers(len(buf), size=k):
        buf[int(i)] ^= int(rng.integers(1, 256))
    return bytes(buf)


@pytest.mark.parametrize("k", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("seed", range(12))
def test_any_byte_corruption_detected(k, seed):
    """save -> flip k random bytes -> load raises ProgramCorruptionError.

    Every byte of the format is covered by the header CRC or the payload
    CRC (or is the magic/version/CRC itself), so no corruption parses.
    Deterministic 60-case sweep; widened by hypothesis when available.
    """
    with pytest.raises(ProgramCorruptionError):
        serialize.loads_program(_flip_k_bytes(_BLOB, k, seed))


try:  # hypothesis is optional in this container — gate, don't require
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass
else:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(1, 8), st.integers(0, 2**31 - 1))
    def test_any_byte_corruption_detected_hypothesis(k, seed):
        with pytest.raises(ProgramCorruptionError):
            serialize.loads_program(_flip_k_bytes(_BLOB, k, seed))
