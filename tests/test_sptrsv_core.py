"""System behaviour tests for the SpTRSV core (compiler + executors)."""

import dataclasses

import numpy as np
import pytest

from repro.core import api
from repro.core.csr import TriCSR, from_coo, random_rhs, serial_solve
from repro.core.dag import analyze, compute_levels
from repro.core.matrices import SUITE, generate
from repro.core.program import AccelConfig
from repro.core.schedule import compile_program

SMALL = ["chain_1k", "band_cz", "ckt_rajat04", "chem_bp", "wide_c36", "hub_small"]


def test_csr_validation_and_serial_solve():
    mat = from_coo(4, [1, 2, 3, 3], [0, 1, 0, 2], [-1, -1, -1, -1],
                   np.ones(4), "tiny")
    b = np.array([1.0, 2.0, 3.0, 4.0])
    x = serial_solve(mat, b)
    # forward substitution by hand
    assert np.allclose(x, [1.0, 3.0, 6.0, 11.0])


def test_levels_match_longest_path():
    mat = generate("chain_1k")
    lv = compute_levels(mat)
    assert lv[0] == 0
    assert lv[-1] == mat.n - 1  # bidiagonal chain: level == row index


def test_dag_stats_table3_fields():
    info = analyze(generate("band_cz"))
    row = info.row()
    assert row["binary_nodes"] == 2 * row["nnz"] - row["n"]
    assert 0 <= row["cdu_nodes_pct"] <= 100


@pytest.mark.parametrize("name", SMALL)
def test_medium_program_correct(name):
    mat = generate(name)
    prog = api.compile(mat)
    b = random_rhs(mat, 7)
    ref = serial_solve(mat, b)
    got = api.solve_numpy(prog, b)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("name", ["band_cz", "ckt_rajat04", "wide_c36"])
def test_jax_executor_matches_numpy(name):
    mat = generate(name)
    prog = api.compile(mat)
    b = random_rhs(mat, 8)
    np.testing.assert_allclose(
        api.solve(prog, b), api.solve_numpy(prog, b), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("name", ["band_cz", "chem_bp", "hub_small"])
def test_coarse_program_correct(name):
    mat = generate(name)
    prog = api.baseline_coarse(mat)
    b = random_rhs(mat, 9)
    np.testing.assert_allclose(
        api.solve_numpy(prog, b), serial_solve(mat, b), rtol=2e-4, atol=1e-4
    )


def test_serial_chain_cycle_count():
    """Bidiagonal chain is inherently serial: exactly 2n-1 cycles
    (edge+finalize per node, pipelined by one)."""
    mat = generate("chain_1k")
    prog = api.compile(mat)
    assert prog.stats.cycles == 2 * mat.n - 1


def test_cycles_lower_bound():
    for name in SMALL:
        mat = generate(name)
        prog = api.compile(mat)
        assert prog.stats.cycles >= mat.nnz / prog.config.num_cus


def test_every_op_scheduled_exactly_once():
    mat = generate("ckt_rajat04")
    prog = api.compile(mat)
    assert prog.stats.exec_edges == mat.nnz - mat.n
    assert prog.stats.exec_finals == mat.n
    # each x index finalized exactly once
    finals = prog.out_idx[prog.opcode == 2]
    assert len(np.unique(finals)) == mat.n


def test_medium_beats_coarse_on_cdu_heavy():
    """The paper's central claim (Fig. 9a)."""
    for name in ["band_dw2048", "ckt_add20", "grid_activsg"]:
        mat = generate(name)
        med = api.compile(mat).stats.cycles
        coa = api.baseline_coarse(mat).stats.cycles
        assert med < coa, (name, med, coa)


def test_psum_caching_reduces_cycles():
    """Fig. 9b/c: enabling the psum cache reduces total cycles."""
    mat = generate("ckt_rajat04")
    with_c = compile_program(mat, AccelConfig(psum_cache=True)).stats
    no_c = compile_program(mat, AccelConfig(psum_cache=False)).stats
    assert with_c.cycles <= no_c.cycles
    # still correct without the mechanism
    b = random_rhs(mat, 10)
    prog = compile_program(mat, AccelConfig(psum_cache=False))
    np.testing.assert_allclose(
        api.solve_numpy(prog, b), serial_solve(mat, b), rtol=2e-4, atol=1e-4
    )


def test_icr_improves_reuse():
    """Fig. 9f: ICR increases broadcast reuse events."""
    mat = generate("band_dw2048")
    icr = compile_program(mat, AccelConfig(icr=True)).stats
    no = compile_program(mat, AccelConfig(icr=False)).stats
    assert icr.reuse_events >= no.reuse_events
    assert icr.constraints <= no.constraints


def test_icr_preserves_correctness():
    mat = generate("band_cz")
    b = random_rhs(mat, 11)
    for icr in (True, False):
        prog = compile_program(mat, AccelConfig(icr=icr))
        np.testing.assert_allclose(
            api.solve_numpy(prog, b), serial_solve(mat, b), rtol=2e-4, atol=1e-4
        )


def test_roundrobin_alloc_correct():
    mat = generate("chem_bp")
    prog = compile_program(mat, AccelConfig(alloc="roundrobin"))
    b = random_rhs(mat, 12)
    np.testing.assert_allclose(
        api.solve_numpy(prog, b), serial_solve(mat, b), rtol=2e-4, atol=1e-4
    )


def test_dm_escape_program_still_correct():
    """Programs that needed emergency psum overflow must stay exact."""
    mat = generate("ckt_rajat04")
    prog = compile_program(mat, AccelConfig(psum_words=2))
    assert prog.stats.dm_escapes >= 0
    b = random_rhs(mat, 13)
    np.testing.assert_allclose(
        api.solve_numpy(prog, b), serial_solve(mat, b), rtol=2e-4, atol=1e-4
    )


def test_nop_breakdown_sums_to_one():
    mat = generate("chem_bp")
    st = api.compile(mat).stats
    total = sum(st.nop_breakdown().values())
    assert abs(total - 1.0) < 1e-9


def test_throughput_below_peak():
    for name in SMALL:
        st = api.compile(generate(name)).stats
        cfg = AccelConfig()
        assert st.throughput_gops(cfg) <= st.peak_throughput_gops(cfg) + 1e-9


def test_fine_baseline_runs():
    st = api.baseline_fine(generate("band_cz"))
    assert st.blocks >= st.n
    assert st.effective_cycles > 0


def test_suite_generators_all_valid():
    for name in SUITE:
        mat = generate(name)
        mat.validate()
