"""Static-analysis subsystem tests (DESIGN.md §8).

Three layers under test:

1. per-pass contract verifiers (`core/analysis/contracts.py`) wired into
   `compile_dag(verify_ir=True)` — a broken invariant must raise
   `IRValidationError` naming the guilty pass;
2. the schedule hazard/race detector (`core/analysis/hazards.py`) — every
   IR-level fault class (`core.robust.IR_FAULT_CLASSES`) must fire its
   expected diagnostic code, and every suite matrix must verify clean at
   the default configuration;
3. the performance linter (`core/analysis/perf.py`) — SPT2xx lints fire
   on the workloads known to exhibit the smells.

The benchmark smoke guard (`benchmarks/analysis_overhead.py --smoke`)
runs here too, so tier-1 keeps the fault-injection acceptance bar green.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import api, matrices
from repro.core.analysis import (
    CODES,
    SEV_ERROR,
    AnalysisReport,
    Diagnostic,
    analyze_program,
    lint_program,
    program_diagnostics,
    verify_assign,
    verify_emit,
    verify_frontend,
    verify_packed_program,
    verify_partition,
    verify_schedule,
)
from repro.core.compiler import assign, elide, emit, partition, sched
from repro.core.errors import IRValidationError, ProgramCorruptionError
from repro.core.frontends.sptrsv import lower_tri
from repro.core.program import AccelConfig
from repro.core.robust import (
    IR_FAULT_CLASSES,
    FaultInjector,
    run_ir_fault_injection,
    verify_program,
)

# small matrices spanning the structural spectrum (band / circuit / wide /
# hub); ckt_rajat04 is the one with live psum slot traffic, so every IR
# fault class is applicable there
FAST_SET = ["band_cz", "ckt_rajat04", "chem_bp", "wide_c36", "hub_small"]
FULL_MATRIX = "ckt_rajat04"


@pytest.fixture(scope="module")
def pipeline():
    """All staged IRs of FULL_MATRIX at the default config."""
    cfg = AccelConfig()
    dag = lower_tri(matrices.generate(FULL_MATRIX))
    pir = partition.run(dag)
    air = assign.run(pir, cfg)
    sir = sched.run(air, cfg)
    eir = elide.run(sir)
    prog = emit.run(eir, cfg, planes=None)
    return cfg, dag, pir, air, sir, eir, prog


# ------------------------------------------------------------ diagnostics
def test_code_registry_is_well_formed():
    for code, title in CODES.items():
        assert code.startswith("SPT") and len(code) == 6, code
        assert code[3] in "123", (
            f"{code}: 1xx correctness / 2xx perf / 3xx serving only")
        assert title


def test_diagnostic_rejects_unknown_code_and_severity():
    with pytest.raises(ValueError):
        Diagnostic(code="SPT999", severity=SEV_ERROR, message="x")
    with pytest.raises(ValueError):
        Diagnostic(code="SPT110", severity="fatal", message="x")


def test_report_render_and_json_roundtrip():
    d = Diagnostic(code="SPT110", severity=SEV_ERROR, message="row 3 never "
                   "finalized", pass_name="psum_schedule", node=3)
    rep = AnalysisReport(name="unit", meta={"n": 4}).extend([d])
    assert not rep.ok() and rep.codes() == {"SPT110"}
    text = rep.render()
    assert "SPT110" in text and "psum_schedule" in text
    blob = rep.to_json()
    import json

    back = json.loads(blob)
    assert back["name"] == "unit"
    assert back["diagnostics"][0]["code"] == "SPT110"
    assert back["diagnostics"][0]["node"] == 3


# ------------------------------------------------- clean-compile contract
@pytest.mark.parametrize("name", FAST_SET)
def test_clean_compile_verifies(name):
    prog = api.compile(matrices.generate(name), verify_ir=True)
    entries = [ps for ps in prog.stats.pass_stats if ps.name == "verify_ir"]
    assert len(entries) == 1
    assert entries[0].metrics["stages_verified"] == 6
    assert entries[0].seconds >= 0.0


def test_every_stage_verifies_clean(pipeline):
    cfg, dag, pir, air, sir, eir, prog = pipeline
    assert verify_frontend(dag) == []
    assert verify_partition(pir) == []
    assert verify_assign(air, cfg) == []
    assert verify_schedule(sir, air, cfg) == []
    assert verify_emit(eir, sir) == []
    assert verify_packed_program(prog, eir, cfg) == []


@pytest.mark.parametrize("cfg", [
    AccelConfig(num_cus=8, psum_words=4),
    AccelConfig(alloc="roundrobin"),
    AccelConfig(icr=False, psum_cache=False),
], ids=["small", "roundrobin", "no_icr_no_cache"])
def test_config_variants_verify_clean(cfg):
    for name in ["ckt_rajat04", "hub_small"]:
        api.compile(matrices.generate(name), cfg, verify_ir=True)


def test_suite_sweep_zero_diagnostics():
    """Every suite matrix (n <= 3000) compiles verified and lints with
    zero error diagnostics at the default configuration."""
    names = matrices.suite_names(max_n=3000)
    assert len(names) >= 17
    for name in names:
        prog = api.compile(matrices.generate(name), verify_ir=True)
        report = analyze_program(prog)
        assert report.errors == [], f"{name}: {report.render()}"


# ------------------------------------------------- IR fault injection
@pytest.mark.parametrize("fault", IR_FAULT_CLASSES)
def test_ir_fault_fires_expected_code(fault):
    mat = matrices.generate(FULL_MATRIX)
    (r,) = run_ir_fault_injection(mat, seed=3, classes=(fault,))
    assert r["applicable"], f"{fault} must be applicable on {FULL_MATRIX}"
    assert r["caught"], (f"{fault}: expected {r['expected_code']}, "
                         f"verifier fired {r['fired_codes']}")


def test_ir_fault_injection_seed_sweep():
    mat = matrices.generate(FULL_MATRIX)
    for seed in range(5):
        for r in run_ir_fault_injection(mat, seed=seed):
            assert r["applicable"] and r["caught"], r


def test_verify_ir_names_frontend_on_dag_fault():
    dag = lower_tri(matrices.generate(FULL_MATRIX))
    bad = FaultInjector(0).corrupt_dag(dag)
    with pytest.raises(IRValidationError) as exc:
        api.compile_dag(bad, verify_ir=True)
    assert "frontend" in str(exc.value)
    assert exc.value.detail["pass"] == "frontend"
    assert exc.value.detail["code"] == "SPT118"


def test_verify_ir_names_guilty_pass_on_schedule_fault(monkeypatch):
    """A scheduler bug (simulated by mutating its output) is blamed on
    psum_schedule — not discovered later as a generic corrupt program."""
    inj = FaultInjector(1)
    real_run = sched.run

    def bad_run(air, cfg):
        return inj.corrupt_schedule(real_run(air, cfg), "raw")

    monkeypatch.setattr(sched, "run", bad_run)
    with pytest.raises(IRValidationError) as exc:
        api.compile(matrices.generate(FULL_MATRIX), verify_ir=True)
    assert exc.value.detail["pass"] == "psum_schedule"
    assert exc.value.detail["code"] in ("SPT111", "SPT117")


def test_unverified_compile_ignores_ir_faults(monkeypatch):
    """Without verify_ir the pipeline stays permissive: the same mutation
    compiles (garbage in, packed garbage out) and only the packed-program
    checks can complain."""
    inj = FaultInjector(1)
    real_run = sched.run

    def bad_run(air, cfg):
        return inj.corrupt_schedule(real_run(air, cfg), "raw")

    monkeypatch.setattr(sched, "run", bad_run)
    prog = api.compile(matrices.generate(FULL_MATRIX))
    assert prog.cycles > 0


# ------------------------------------------------- verify_program dedup
def test_verify_program_raises_first_analyzer_error(pipeline):
    *_, prog = pipeline
    from repro.core.robust import _copy_program

    bad = _copy_program(prog)
    bad.val_idx[0, 0] = np.int32(bad.stream.size + 11)
    diags = program_diagnostics(bad)
    first = next(d for d in diags if d.severity == SEV_ERROR)
    with pytest.raises(ProgramCorruptionError) as exc:
        verify_program(bad)
    assert str(exc.value) == f"program integrity: {first.message}"
    assert exc.value.detail["code"] == first.code


def test_verify_program_clean(pipeline):
    *_, prog = pipeline
    verify_program(prog)  # must not raise
    assert program_diagnostics(prog) == []


# ------------------------------------------------------------ perf linter
def test_linter_flags_hub_imbalance():
    prog = api.compile(matrices.generate("hub_small"))
    codes = {d.code for d in lint_program(prog)}
    assert "SPT201" in codes  # load CV blowup on the hub row
    assert "SPT206" in codes  # utilization collapse


def test_linter_flags_psum_pressure():
    prog = api.compile(matrices.generate(FULL_MATRIX))
    codes = {d.code for d in lint_program(prog)}
    assert "SPT202" in codes  # emergency psum parks escape to overflow


def test_linter_silent_on_balanced_band():
    prog = api.compile(matrices.generate("band_cz"))
    assert lint_program(prog) == []


def test_analyze_program_report_shape(pipeline):
    *_, prog = pipeline
    report = analyze_program(prog)
    assert report.errors == []
    assert report.meta["artifact"] == "program"
    assert set(report.codes()) <= set(CODES)


# ---------------------------------------------------- benchmark smoke tier
def test_analysis_benchmark_smoke(capsys):
    from benchmarks.analysis_overhead import main

    main(["--smoke"])  # asserts internally: all faults caught, 0 errors
    out = capsys.readouterr().out
    assert "all caught" in out


# ------------------------------------------------- deterministic sweep
# (the hypothesis-driven version lives in test_analysis_property.py)
def test_random_lower_tri_verifies_clean():
    """Random well-formed lower-triangular systems compile with verify_ir
    and analyze with zero error diagnostics."""
    from repro.core.csr import from_coo

    for seed in range(6):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 48))
        rows, cols = [], []
        for i in range(1, n):
            m = rng.random(i) < 0.3
            for j in np.nonzero(m)[0]:
                rows.append(i)
                cols.append(int(j))
        vals = rng.uniform(-1, 1, len(rows))
        diag = rng.uniform(1.0, 2.0, n)
        mat = from_coo(n, rows, cols, vals, diag, name=f"rnd_an_{seed}")
        prog = api.compile(mat, verify_ir=True)
        assert analyze_program(prog, lint=False).ok()
