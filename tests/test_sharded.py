"""Multi-device batched execution (`repro.core.shard`).

Fast tests run in-process on whatever devices exist (a 1-device mesh still
exercises the full shard_map/placement/cache path).  The genuinely
multi-device checks force an 8-device CPU host via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in a subprocess —
the flag must be set before jax initializes.  At ~6 s the subprocess test
stays inside the fast ``-m "not slow"`` loop.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import api, executor, shard
from repro.core.matrices import generate


@pytest.fixture(scope="module")
def prog():
    return api.compile(generate("band_cz"))


@pytest.fixture(scope="module")
def mesh():
    return shard.batch_mesh()


# uneven (not divisible by any device count), even, and B=1 degenerate
@pytest.mark.parametrize("B", [1, 5, 8])
def test_sharded_matches_numpy_oracle(prog, mesh, B):
    bmat = np.random.default_rng(B).standard_normal((prog.n, B))
    got = api.solve_batch(prog, bmat, mesh=mesh)
    ref = api.solve_numpy(prog, bmat)
    assert got.shape == (prog.n, B)
    rel = np.abs(got - ref).max() / max(np.abs(ref).max(), 1e-12)
    assert rel <= 1e-5, (B, rel)


def test_sharded_cache_no_retrace(prog, mesh):
    rng = np.random.default_rng(2)
    # any B <= ndev pads to one column per device: same per-device width,
    # so all of these must share a single trace (valid for any mesh size)
    ndev = mesh.size
    sizes = sorted({1, max(1, ndev - 1), ndev})
    assert len({shard.sharded_widths(b, mesh) for b in sizes}) == 1
    api.solve_batch(prog, rng.standard_normal((prog.n, ndev)), mesh=mesh)  # prime
    before = executor.trace_count()
    for b in sizes:
        api.solve_batch(prog, rng.standard_normal((prog.n, b)), mesh=mesh)
    assert executor.trace_count() == before


def test_make_solver_mesh_shares_cache(prog, mesh):
    rng = np.random.default_rng(3)
    b = rng.standard_normal((prog.n, 4))
    x1 = np.asarray(api.make_solver(prog, batch=4, mesh=mesh)(b))
    before = executor.trace_count()
    x2 = np.asarray(api.make_solver(prog, batch=4, mesh=mesh)(b))
    assert executor.trace_count() == before
    np.testing.assert_allclose(x1, x2)
    with pytest.raises(ValueError):
        api.make_solver(prog, mesh=mesh)  # mesh requires explicit batch


def test_uneven_padding_roundtrip(prog, mesh):
    """B not divisible by the device count: pad columns must not leak."""
    ndev = mesh.size
    B = 7 if ndev != 7 else 9
    assert B % ndev != 0 or ndev == 1
    bmat = np.random.default_rng(4).standard_normal((prog.n, B))
    got = api.solve_batch(prog, bmat, mesh=mesh)
    assert got.shape == (prog.n, B)
    ref = api.solve_numpy(prog, bmat)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
    # solving a subset of the same columns agrees column-for-column
    sub = api.solve_batch(prog, bmat[:, :3], mesh=mesh)
    np.testing.assert_allclose(sub, got[:, :3], rtol=1e-5, atol=1e-6)


def test_sharded_widths():
    mesh = shard.batch_mesh(num_devices=1)
    assert shard.sharded_widths(1, mesh) == (1, 1)
    assert shard.sharded_widths(3, mesh) == (8, 8)


def test_split_composes_with_sharded_path(mesh):
    """Node splitting + sharded batch: the full composition of this PR."""
    mat = generate("hub_small")
    prog, split = api.compile_split(mat, max_indegree=48)
    bmat = np.random.default_rng(5).standard_normal((mat.n, 6))
    got = api.solve_split(prog, split, bmat, mesh=mesh)
    ref = np.stack(
        [api.reference_solve(mat, bmat[:, i]) for i in range(6)], axis=1
    )
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# forced 8-device host (subprocess: XLA_FLAGS must precede jax init)

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
from repro.core import api, executor, shard
from repro.core.csr import serial_solve
from repro.core.matrices import generate

out = {"devices": len(jax.devices()), "cases": []}
mat = generate("band_cz")
prog = api.compile(mat)
mesh = shard.batch_mesh()
rng = np.random.default_rng(1)
for B in [1, 7, 8, 32]:
    bmat = rng.standard_normal((mat.n, B))
    before = executor.trace_count()
    got = api.solve_batch(prog, bmat, mesh=mesh)
    ref = np.stack([serial_solve(mat, bmat[:, i]) for i in range(B)], axis=1)
    rel = float(np.abs(got - ref).max() / np.abs(ref).max())
    w_local, _ = shard.sharded_widths(B, mesh)
    out["cases"].append({"B": B, "w_local": w_local, "rel": rel,
                         "traces": executor.trace_count() - before})
print(json.dumps(out))
"""


def test_forced_8_device_mesh():
    # ~6 s (subprocess jax init + 2 traces): stays inside the fast loop
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    seen_widths = set()
    for case in out["cases"]:
        assert case["rel"] <= 1e-5, case
        # at most one trace per (program, per-device width, mesh): a repeat
        # of an already-seen width must not trace at all
        expected = 0 if case["w_local"] in seen_widths else 1
        assert case["traces"] <= expected, (case, seen_widths)
        seen_widths.add(case["w_local"])
