"""Batched multi-RHS execution: correctness across executors + cache hits."""

import numpy as np
import pytest

from repro.core import api, executor
from repro.core.matrices import generate


def _solve_batched(prog, bmat, impl):
    if impl == "numpy":
        return api.solve_numpy(prog, bmat)
    if impl == "jax":
        return api.solve_batch(prog, bmat)
    from repro.kernels.sptrsv import ops

    return ops.solve(prog, bmat, interpret=True)


def _solve_single(prog, b, impl):
    if impl == "numpy":
        return api.solve_numpy(prog, b)
    if impl == "jax":
        return api.solve(prog, b)
    from repro.kernels.sptrsv import ops

    return ops.solve(prog, b, interpret=True)


@pytest.fixture(scope="module")
def prog():
    return api.compile(generate("band_cz"))


# B=1 degenerate, non-multiples of the pad width (3, 13), and a padded width
@pytest.mark.parametrize("impl", ["numpy", "jax", "pallas"])
@pytest.mark.parametrize("B", [1, 3, 13, 16])
def test_batch_matches_single_rhs_solves(prog, impl, B):
    n = prog.n
    rng = np.random.default_rng(B)
    bmat = rng.standard_normal((n, B))
    got = _solve_batched(prog, bmat, impl)
    assert got.shape == (n, B)
    for i in range(B):
        ref = _solve_single(prog, bmat[:, i], impl)
        denom = max(np.abs(ref).max(), 1e-12)
        rel = np.abs(got[:, i] - np.asarray(ref)).max() / denom
        assert rel <= 1e-5, (impl, B, i, rel)


@pytest.mark.parametrize("impl", ["numpy", "jax", "pallas"])
def test_vector_rhs_keeps_vector_shape(prog, impl):
    b = np.random.default_rng(0).standard_normal(prog.n)
    x = _solve_single(prog, b, impl)
    assert np.asarray(x).shape == (prog.n,)


def test_solve_batch_accepts_vector(prog):
    b = np.random.default_rng(1).standard_normal(prog.n)
    x = api.solve_batch(prog, b)
    assert x.shape == (prog.n, 1)
    np.testing.assert_allclose(x[:, 0], api.solve(prog, b), rtol=1e-6, atol=1e-6)


def test_pad_batch_widths():
    assert executor.pad_batch(1) == 1
    assert executor.pad_batch(3) == executor.BATCH_PAD
    assert executor.pad_batch(8) == 8
    assert executor.pad_batch(9) == 16


def test_executor_cache_no_retrace(prog):
    """Repeated solves on the same program + padded width must not retrace."""
    rng = np.random.default_rng(5)
    b3 = rng.standard_normal((prog.n, 3))
    b5 = rng.standard_normal((prog.n, 5))
    api.solve_batch(prog, b3)  # primes the cache for padded width 8
    before = executor.trace_count()
    api.solve_batch(prog, b3)
    api.solve_batch(prog, rng.standard_normal((prog.n, 3)))
    api.solve_batch(prog, b5)  # pads to the same width -> same trace
    got = api.solve_batch(prog, b5)
    assert executor.trace_count() == before
    # and results stay correct through the cache
    np.testing.assert_allclose(
        got[:, 0], api.solve(prog, b5[:, 0]), rtol=1e-5, atol=1e-5
    )


# regression: SplitResult.expand_rhs used to allocate a 1-D buffer and
# crash on [n, B] input ("shape mismatch ... could not be broadcast")
@pytest.mark.parametrize("impl", ["numpy", "jax", "pallas"])
def test_batched_solve_split_matches_reference(impl):
    mat = generate("hub_small")
    sprog, split = api.compile_split(mat, max_indegree=48)
    B = 4
    bmat = np.random.default_rng(7).standard_normal((mat.n, B))
    got = split.extract(_solve_batched(sprog, split.expand_rhs(bmat), impl))
    assert got.shape == (mat.n, B)
    ref = np.stack(
        [api.reference_solve(mat, bmat[:, i]) for i in range(B)], axis=1
    )
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_solve_split_accepts_batched_rhs():
    """api.solve_split with b[n, B] — the exact ISSUE crash repro."""
    mat = generate("hub_small")
    sprog, split = api.compile_split(mat, max_indegree=48)
    bmat = np.random.default_rng(8).standard_normal((mat.n, 3))
    got = api.solve_split(sprog, split, bmat)  # crashed before the fix
    assert got.shape == (mat.n, 3)
    b1 = api.solve_split(sprog, split, bmat[:, 0])
    assert b1.shape == (mat.n,)
    np.testing.assert_allclose(got[:, 0], b1, rtol=1e-5, atol=1e-6)


def test_make_solver_shares_cache(prog):
    s = api.make_solver(prog, batch=4)
    rng = np.random.default_rng(6)
    b = rng.standard_normal((prog.n, 4))
    x1 = np.asarray(s(b))
    before = executor.trace_count()
    x2 = np.asarray(api.make_solver(prog, batch=4)(b))
    assert executor.trace_count() == before
    np.testing.assert_allclose(x1, x2)
