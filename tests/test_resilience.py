"""Resilient-serving tests (DESIGN.md §10): the breaker state machine on
an injectable clock, deterministic retry/backoff, the bounded incident
log, request deadlines (early flush + typed fail-fast), admission-control
load shedding, the resilient flush ladder (degradation, forced terminal
rung, typed exhaustion), the unified SPT3xx report, and the chaos
harness acceptance bar across seeds.  No wall clock anywhere.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.errors import (
    BackendExecutionError,
    DeadlineExceededError,
    LoadShedError,
    RobustnessError,
)
from repro.core.matrices import banded
from repro.core.resilience import (
    BREAKER_CLOSED,
    BREAKER_HALF_OPEN,
    BREAKER_OPEN,
    AdmissionConfig,
    BreakerBoard,
    BreakerConfig,
    CircuitBreaker,
    IncidentLog,
    ResilienceConfig,
    RetryPolicy,
    incident_to_diagnostic,
)
from repro.core.robust import (
    SERVICE_FAULT_CLASSES,
    Incident,
    run_service_fault_injection,
)
from repro.core.serve import (
    FLUSH_SHED,
    ManualClock,
    ProgramCache,
    ShedTicket,
    SolveService,
)

MAT_A = banded(64, 6, 0.5, 7, "res-a")
MAT_B = banded(48, 4, 0.6, 8, "res-b")


def make_svc(clock=None, resilience=None, **kw):
    clock = clock or ManualClock()
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_delay", 1.0)
    svc = SolveService(ProgramCache(), clock=clock, backend="numpy",
                       resilience=resilience, **kw)
    svc.register("a", MAT_A)
    svc.register("b", MAT_B)
    return svc, clock


# ------------------------------------------------------------- breaker
def test_breaker_opens_on_failure_rate_and_cools_down():
    cfg = BreakerConfig(window_s=10.0, min_samples=4, failure_threshold=0.5,
                        cooldown_s=5.0, half_open_probes=1)
    brk = CircuitBreaker(("a", "numpy"), cfg)
    t = 0.0
    for ok in (True, False, True, False):  # 2/4 failures: at threshold
        assert brk.allow(t)
        brk.record(t, ok)
        t += 1.0
    assert brk.state == BREAKER_OPEN       # opened at the failure, t=3.0
    assert not brk.allow(t)                # gated during cooldown
    assert not brk.allow(7.99)
    assert brk.allow(8.0)                  # cooldown elapsed: probe allowed
    assert brk.state == BREAKER_HALF_OPEN


def test_breaker_half_open_probe_success_closes():
    cfg = BreakerConfig(min_samples=2, failure_threshold=0.5,
                        cooldown_s=1.0, half_open_probes=2)
    brk = CircuitBreaker("k", cfg)
    brk.record(0.0, False)
    brk.record(0.1, False)
    assert brk.state == BREAKER_OPEN
    assert brk.allow(2.0) and brk.state == BREAKER_HALF_OPEN
    brk.record(2.0, True)
    assert brk.state == BREAKER_HALF_OPEN  # needs 2 consecutive successes
    brk.record(2.1, True)
    assert brk.state == BREAKER_CLOSED
    # the window was cleared on close: old failures don't linger
    brk.record(2.2, False)
    assert brk.state == BREAKER_CLOSED


def test_breaker_half_open_probe_failure_reopens_and_rearms():
    cfg = BreakerConfig(min_samples=2, failure_threshold=0.5, cooldown_s=2.0)
    brk = CircuitBreaker("k", cfg)
    brk.record(0.0, False)
    brk.record(0.0, False)
    assert brk.allow(2.0)                  # half-open probe
    brk.record(2.0, False)                 # probe fails
    assert brk.state == BREAKER_OPEN
    assert not brk.allow(3.9)              # cooldown re-armed from t=2
    assert brk.allow(4.0)


def test_breaker_window_expiry_forgets_old_failures():
    cfg = BreakerConfig(window_s=5.0, min_samples=4, failure_threshold=0.5)
    brk = CircuitBreaker("k", cfg)
    brk.record(0.0, False)
    brk.record(0.1, False)
    # 6s later the two failures fell out of the window; fresh successes
    # plus one failure stay under min_samples/threshold
    brk.record(6.0, True)
    brk.record(6.1, True)
    brk.record(6.2, True)
    brk.record(6.3, False)
    assert brk.state == BREAKER_CLOSED


def test_breaker_board_records_transitions_as_incidents():
    log = IncidentLog()
    board = BreakerBoard(BreakerConfig(min_samples=2, failure_threshold=0.5,
                                       cooldown_s=1.0), sink=log)
    key = ("a", "numpy")
    board.record(key, 0.0, False)
    board.record(key, 0.1, False)
    assert board.state(key) == BREAKER_OPEN
    board.allow(key, 2.0)
    board.record(key, 2.0, True)
    kinds = [i.kind for i in log]
    assert kinds == ["breaker-open", "breaker-half-open", "breaker-closed"]
    assert all(i.detail["matrix_id"] == "a" for i in log)
    assert board.states() == {"a/numpy": BREAKER_CLOSED}


# ------------------------------------------------------- retry / backoff
def test_retry_backoff_deterministic_and_bounded():
    pol = RetryPolicy(max_retries=3, base_delay_s=0.01, max_delay_s=0.05,
                      multiplier=2.0, jitter=0.5, seed=42)
    d = [pol.delay(a, key="a:numpy") for a in (1, 2, 3, 4)]
    assert d == [pol.delay(a, key="a:numpy") for a in (1, 2, 3, 4)]
    raw = [0.01, 0.02, 0.04, 0.05]
    for got, r in zip(d, raw):
        assert r * 0.5 <= got <= r  # jitter only shrinks, never grows
    # different keys desynchronize, different seeds reshuffle
    assert pol.delay(1, key="b:numpy") != pol.delay(1, key="a:numpy")
    assert RetryPolicy(seed=1).delay(1, "k") != RetryPolicy(seed=2).delay(1, "k")
    assert RetryPolicy(jitter=0.0).delay(2) == 0.02


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.5)
    with pytest.raises(ValueError):
        RetryPolicy(multiplier=0.5)
    with pytest.raises(ValueError):
        RetryPolicy().delay(0)


# --------------------------------------------------------- incident log
def test_incident_log_bounded_and_indexable():
    log = IncidentLog(cap=3)
    for i in range(5):
        log.append(Incident(stage="s", kind=f"k{i}", message=str(i)))
    assert len(log) == 3 and log.dropped == 2
    assert log[-1].kind == "k4" and log[0].kind == "k2"
    assert [i.kind for i in log] == ["k2", "k3", "k4"]
    assert log.by_kind() == {"k2": 1, "k3": 1, "k4": 1}
    log.set_cap(1)
    assert len(log) == 1 and log.dropped == 4
    with pytest.raises(ValueError):
        IncidentLog(cap=0)


def test_incident_to_diagnostic_codes():
    cases = {"exception": "SPT301", "nonfinite-output": "SPT302",
             "deadline-expired": "SPT303", "breaker-open": "SPT304",
             "shed": "SPT305", "disk-corrupt": "SPT306",
             "backoff": "SPT307", "hang": "SPT308",
             "something-new": "SPT301"}
    for kind, code in cases.items():
        d = incident_to_diagnostic(
            Incident(stage="numpy", kind=kind, message="m",
                     detail={"matrix_id": "a"}))
        assert d.code == code and d.pass_name == "serve"
        assert d.detail["kind"] == kind and d.detail["matrix_id"] == "a"


# ------------------------------------------------------------ deadlines
def test_expired_deadline_fails_fast_at_submit():
    svc, clock = make_svc()
    clock.advance(5.0)
    b = np.random.default_rng(0).standard_normal(MAT_A.n)
    t = svc.submit("a", b, deadline=4.0)
    assert t.done and t.failed and not t.shed
    with pytest.raises(DeadlineExceededError) as ei:
        t.result()
    assert ei.value.detail["deadline"] == 4.0
    assert svc.stats.deadline_failed_columns == 1
    assert svc.stats.solver_calls == 0  # consumed no solve
    assert svc.incidents[-1].kind == "deadline-expired"


def test_deadline_tightens_bucket_flush():
    svc, clock = make_svc()  # max_delay = 1.0
    b = np.random.default_rng(1).standard_normal(MAT_A.n)
    t = svc.submit("a", b, timeout=0.25)
    clock.advance(0.125)
    assert svc.pump() == 0 and not t.done
    clock.advance(0.125)  # now == deadline: flush early, deliver in time
    assert svc.pump() == 1 and t.done and not t.failed
    np.testing.assert_array_equal(
        t.result(),
        np.asarray(__import__("repro.core.executor", fromlist=["x"])
                   .execute_numpy(svc.cache.get(MAT_A), b)))


def test_deadline_missed_in_queue_fails_typed():
    svc, clock = make_svc()
    b = np.random.default_rng(2).standard_normal(MAT_A.n)
    t = svc.submit("a", b, timeout=0.3)
    clock.advance(2.0)  # overslept the pump: deadline long gone
    svc.pump()
    assert t.done and t.failed
    with pytest.raises(DeadlineExceededError):
        t.result()
    # the flush consumed no solver call for the expired column
    assert svc.stats.solver_calls == 0


def test_mixed_bucket_expired_column_does_not_poison_live_ones():
    svc, clock = make_svc()
    rng = np.random.default_rng(3)
    t_short = svc.submit("a", rng.standard_normal(MAT_A.n), timeout=0.2)
    t_long = svc.submit("a", rng.standard_normal(MAT_A.n))
    clock.advance(2.0)
    svc.pump()
    assert t_short.failed and t_long.done and not t_long.failed
    assert t_long.result().shape == (MAT_A.n,)


def test_submit_rejects_deadline_and_timeout_together():
    svc, _ = make_svc()
    with pytest.raises(ValueError, match="not both"):
        svc.submit("a", np.zeros(MAT_A.n), deadline=1.0, timeout=1.0)


# ------------------------------------------------------------- shedding
def test_admission_sheds_over_budget_request_whole():
    res = ResilienceConfig(
        admission=AdmissionConfig(max_pending_per_matrix=3))
    svc, clock = make_svc(resilience=res, max_batch=8)
    rng = np.random.default_rng(4)
    ok = svc.submit("a", rng.standard_normal((MAT_A.n, 2)))
    assert not ok.shed and svc.pending_columns("a") == 2
    t = svc.submit("a", rng.standard_normal((MAT_A.n, 2)))  # 2+2 > 3
    assert isinstance(t, ShedTicket) and t.shed and t.done
    with pytest.raises(LoadShedError) as ei:
        t.result()
    assert ei.value.detail["budget"] == "max_pending_per_matrix"
    assert svc.pending_columns("a") == 2  # nothing was enqueued
    st = svc.stats
    assert st.requests_shed == 1 and st.columns_shed == 2
    shed_recs = [f for f in st.flushes if f.reason == FLUSH_SHED]
    assert len(shed_recs) == 1 and shed_recs[0].index == -1
    assert svc.incidents[-1].kind == "shed"
    # other matrix unaffected by the per-matrix budget
    assert not svc.submit("b", rng.standard_normal(MAT_B.n)).shed


def test_global_budget_sheds_across_matrices():
    res = ResilienceConfig(admission=AdmissionConfig(max_pending_total=3))
    svc, _ = make_svc(resilience=res, max_batch=8)
    rng = np.random.default_rng(5)
    svc.submit("a", rng.standard_normal((MAT_A.n, 2)))
    t = svc.submit("b", rng.standard_normal((MAT_B.n, 2)))
    assert t.shed and t.error.detail["budget"] == "max_pending_total"


def test_due_flush_frees_budget_before_admission():
    res = ResilienceConfig(
        admission=AdmissionConfig(max_pending_per_matrix=2))
    svc, clock = make_svc(resilience=res, max_batch=8)
    rng = np.random.default_rng(6)
    svc.submit("a", rng.standard_normal((MAT_A.n, 2)))
    clock.advance(1.5)  # the bucket is due: submit pumps it first
    t = svc.submit("a", rng.standard_normal((MAT_A.n, 2)))
    assert not t.shed


# ------------------------------------------------- resilient flush path
def fail_n_times(svc, stage_name, n, exc=RuntimeError("boom")):
    """Wrap the service's stage-solver: first ``n`` calls of a rung raise."""
    orig = svc._stage_solver
    count = {"left": n}

    def wrapped(stage, prog, k, mat):
        fn = orig(stage, prog, k, mat)
        if stage != stage_name:
            return fn

        def chaotic(bmat):
            if count["left"] > 0:
                count["left"] -= 1
                raise exc
            return fn(bmat)
        return chaotic
    svc._stage_solver = wrapped
    return count


def test_retry_recovers_transient_fault_same_rung():
    res = ResilienceConfig(retry=RetryPolicy(max_retries=1, jitter=0.0))
    svc, clock = make_svc(resilience=res)
    fail_n_times(svc, "numpy", 1)
    b = np.random.default_rng(7).standard_normal(MAT_A.n)
    t = svc.submit("a", b)
    clock.advance(1.0)
    svc.pump()
    assert t.done and not t.failed
    rec = [f for f in svc.stats.flushes if f.index >= 0][-1]
    assert rec.stage == "numpy"  # recovered on the entry rung
    assert svc.stats.retries == 1 and svc.stats.degraded_flushes == 0
    kinds = [i.kind for i in svc.incidents]
    assert "exception" in kinds and "backoff" in kinds


def test_persistent_fault_degrades_to_reference_rung():
    res = ResilienceConfig(retry=RetryPolicy(max_retries=1, jitter=0.0))
    svc, clock = make_svc(resilience=res)
    fail_n_times(svc, "numpy", 99)
    b = np.random.default_rng(8).standard_normal(MAT_A.n)
    t = svc.submit("a", b)
    clock.advance(1.0)
    svc.pump()
    assert t.done and not t.failed
    rec = [f for f in svc.stats.flushes if f.index >= 0][-1]
    assert rec.stage == "reference"
    assert svc.stats.degraded_flushes == 1
    from repro.core.csr import serial_solve

    np.testing.assert_array_equal(t.result(), serial_solve(MAT_A, b))


def test_repeated_failures_open_breaker_then_skip_rung():
    res = ResilienceConfig(
        retry=RetryPolicy(max_retries=0),
        breaker=BreakerConfig(min_samples=2, failure_threshold=0.5,
                              cooldown_s=100.0))
    svc, clock = make_svc(resilience=res)
    fail_n_times(svc, "numpy", 99)
    rng = np.random.default_rng(9)
    for _ in range(2):
        svc.submit("a", rng.standard_normal(MAT_A.n))
        clock.advance(1.1)
        svc.pump()
    assert svc._breakers.state(("a", "numpy")) == BREAKER_OPEN
    # next flush skips the open rung entirely: no new numpy exception
    exc_before = sum(1 for i in svc.incidents if i.kind == "exception")
    t = svc.submit("a", rng.standard_normal(MAT_A.n))
    clock.advance(1.1)
    svc.pump()
    assert t.done and not t.failed
    assert sum(1 for i in svc.incidents
               if i.kind == "exception") == exc_before
    # matrix b's breaker is independent and still closed
    assert svc._breakers.state(("b", "numpy")) == BREAKER_CLOSED


def test_all_rungs_gated_forces_terminal_rung_service_still_answers():
    res = ResilienceConfig(
        retry=RetryPolicy(max_retries=0),
        breaker=BreakerConfig(min_samples=1, failure_threshold=0.1,
                              cooldown_s=1e9))
    svc, clock = make_svc(resilience=res)
    # fail BOTH rungs until their breakers open
    fail_n_times(svc, "numpy", 99)
    counts_ref = fail_n_times(svc, "reference", 1)
    rng = np.random.default_rng(10)
    t1 = svc.submit("a", rng.standard_normal(MAT_A.n))
    clock.advance(1.1)
    svc.pump()
    assert t1.failed  # both rungs failed; typed, carries the trail
    assert isinstance(t1.error, BackendExecutionError)
    assert t1.error.detail["incidents"]
    assert svc.stats.failed_flushes == 1
    # breakers now open on both rungs; the terminal rung is forced anyway
    t2 = svc.submit("a", rng.standard_normal(MAT_A.n))
    clock.advance(1.1)
    svc.pump()
    assert t2.done and not t2.failed and counts_ref["left"] == 0
    rec = [f for f in svc.stats.flushes if f.index >= 0][-1]
    assert rec.stage == "reference"


def test_nonfinite_output_degrades_without_retry():
    res = ResilienceConfig(retry=RetryPolicy(max_retries=3, jitter=0.0))
    svc, clock = make_svc(resilience=res)
    orig = svc._stage_solver

    def wrapped(stage, prog, k, mat):
        fn = orig(stage, prog, k, mat)
        if stage != "numpy":
            return fn
        return lambda bmat: np.full_like(np.asarray(fn(bmat)), np.nan)
    svc._stage_solver = wrapped
    t = svc.submit("a", np.random.default_rng(11).standard_normal(MAT_A.n))
    clock.advance(1.1)
    svc.pump()
    assert t.done and not t.failed
    # health failures are deterministic: exactly one nonfinite incident,
    # zero retries of the sick rung
    assert sum(1 for i in svc.incidents
               if i.kind == "nonfinite-output") == 1
    assert svc.stats.retries == 0


def test_hang_classified_and_rung_abandoned():
    res = ResilienceConfig(retry=RetryPolicy(max_retries=3, jitter=0.0),
                           flush_timeout_s=0.5)
    svc, clock = make_svc(resilience=res)
    orig = svc._stage_solver

    def wrapped(stage, prog, k, mat):
        fn = orig(stage, prog, k, mat)
        if stage != "numpy":
            return fn

        def hanging(bmat):
            clock.advance(1.0)  # simulated stall past flush_timeout_s
            return fn(bmat)
        return hanging
    svc._stage_solver = wrapped
    t = svc.submit("a", np.random.default_rng(12).standard_normal(MAT_A.n))
    clock.advance(1.1)
    svc.pump()
    assert t.done and not t.failed
    hangs = [i for i in svc.incidents if i.kind == "hang"]
    assert len(hangs) == 1 and hangs[0].elapsed_s > 0.5
    rec = [f for f in svc.stats.flushes if f.index >= 0][-1]
    assert rec.stage == "reference"


def test_backoff_sleeper_is_injectable():
    slept = []
    res = ResilienceConfig(retry=RetryPolicy(max_retries=2, jitter=0.0,
                                             base_delay_s=0.25),
                           sleep=slept.append)
    svc, clock = make_svc(resilience=res)
    fail_n_times(svc, "numpy", 2)
    svc.submit("a", np.random.default_rng(13).standard_normal(MAT_A.n))
    clock.advance(1.1)
    svc.pump()
    assert slept == [0.25, 0.5]


# ------------------------------------------------------- report surface
def test_report_unifies_incidents_as_spt3xx_json():
    import json

    res = ResilienceConfig(
        retry=RetryPolicy(max_retries=0),
        admission=AdmissionConfig(max_pending_per_matrix=1))
    svc, clock = make_svc(resilience=res, max_batch=8)
    fail_n_times(svc, "numpy", 1)
    rng = np.random.default_rng(14)
    svc.submit("a", rng.standard_normal(MAT_A.n))
    svc.submit("a", rng.standard_normal(MAT_A.n))        # shed
    svc.submit("b", rng.standard_normal(MAT_B.n), timeout=-1.0)  # expired
    clock.advance(1.1)
    svc.pump()
    rep = svc.report()
    codes = rep.codes()
    assert {"SPT301", "SPT303", "SPT305"} <= codes
    d = json.loads(rep.to_json())
    assert d["name"].startswith("serve[")
    assert d["meta"]["requests_shed"] == 1
    assert d["meta"]["breakers"]  # breaker states ride in meta
    assert all(dd["code"] in
               {"SPT301", "SPT302", "SPT303", "SPT304", "SPT305",
                "SPT306", "SPT307", "SPT308", "SPT309"}
               for dd in d["diagnostics"])
    assert "SPT30" in rep.render()


def test_report_surfaces_incident_log_saturation():
    res = ResilienceConfig(retry=RetryPolicy(max_retries=0), incident_cap=2)
    svc, clock = make_svc(resilience=res)
    fail_n_times(svc, "numpy", 99)
    rng = np.random.default_rng(15)
    for _ in range(4):
        svc.submit("a", rng.standard_normal(MAT_A.n))
        clock.advance(1.1)
        svc.pump()
    assert len(svc.incidents) == 2 and svc.incidents.dropped > 0
    rep = svc.report()
    assert "SPT309" in rep.codes()


def test_one_shared_incident_log_cache_and_service(tmp_path):
    """Disk-tier corruption and flush-path incidents land in ONE log."""
    res = ResilienceConfig(retry=RetryPolicy(max_retries=0))
    cache = ProgramCache(capacity=1, disk_dir=tmp_path)
    clock = ManualClock()
    svc = SolveService(cache, max_batch=4, max_delay=1.0, clock=clock,
                       backend="numpy", resilience=res)
    svc.register("a", MAT_A)
    svc.register("b", MAT_B)
    assert svc.incidents is cache.incidents
    rng = np.random.default_rng(16)
    svc.submit("a", rng.standard_normal(MAT_A.n))
    clock.advance(1.1)
    svc.pump()
    svc.submit("b", rng.standard_normal(MAT_B.n))  # evicts a (capacity 1)
    clock.advance(1.1)
    svc.pump()
    # corrupt a's blob; its next flush rehydrates -> corrupt -> recompile
    blob = next(tmp_path.glob("*.prog"))
    raw = bytearray(blob.read_bytes())
    raw[50] ^= 0xFF
    blob.write_bytes(bytes(raw))
    fail_n_times(svc, "numpy", 1)
    t = svc.submit("a", rng.standard_normal(MAT_A.n))
    clock.advance(1.1)
    svc.pump()
    assert t.done and not t.failed
    kinds = {i.kind for i in svc.incidents}
    assert "disk-corrupt" in kinds and "exception" in kinds
    assert {"SPT306", "SPT301"} <= svc.report().codes()


# ------------------------------------------------------- chaos harness
@pytest.mark.parametrize("seed", range(5))
def test_service_chaos_no_silent_wrong_no_deadlock(seed):
    results = run_service_fault_injection(seed=seed, requests=14)
    assert {r["fault"] for r in results} == set(SERVICE_FAULT_CLASSES)
    assert not any(r["silent_wrong"] for r in results), results
    assert not any(r["deadlocked"] for r in results), results
    # each class saw real traffic and the harness is seeded-reproducible
    assert all(r["tickets"] == 14 for r in results)
    again = run_service_fault_injection(seed=seed, requests=14)
    assert results == again


def test_chaos_sheds_and_typed_failures_actually_happen():
    """Across the default seeds the interesting outcomes all occur."""
    total = {"shed": 0, "failed_typed": 0, "completed": 0}
    for seed in range(3):
        for r in run_service_fault_injection(seed=seed, requests=14):
            for k in total:
                total[k] += r[k]
    assert total["shed"] > 0
    assert total["failed_typed"] > 0
    assert total["completed"] > 0


# ------------------------------------------------- bench smoke + schema
def test_serve_chaos_smoke(capsys):
    from benchmarks.serve_chaos import main

    main(["--smoke"])
    out = capsys.readouterr().out
    assert "0 silent wrong, 0 deadlocks" in out


def test_bench_serve_chaos_json_schema():
    from scripts.check_bench import check_chaos

    problems = check_chaos()
    assert problems == [], "\n".join(problems)


# ---------------------------------------------- legacy path unaffected
def test_without_resilience_config_legacy_behavior_intact():
    svc, clock = make_svc()
    assert svc.resilience is None and svc._breakers is None
    b = np.random.default_rng(17).standard_normal(MAT_A.n)
    t = svc.submit("a", b)
    clock.advance(1.0)
    svc.pump()
    assert t.done and not t.failed and not t.shed
    st = svc.stats.to_dict()
    assert st["requests_shed"] == 0 and st["retries"] == 0
    assert st["failed_flushes"] == 0


def test_resilience_overhead_fault_free_accounting_is_clean():
    """A fault-free resilient service: zero incidents, zero retries, all
    flushes on the entry rung — resilience must be pure bookkeeping."""
    res = ResilienceConfig()
    svc, clock = make_svc(resilience=res)
    rng = np.random.default_rng(18)
    for _ in range(6):
        svc.submit("a", rng.standard_normal(MAT_A.n))
        clock.advance(1.1)
        svc.pump()
    assert len(svc.incidents) == 0
    st = svc.stats
    assert st.retries == 0 and st.degraded_flushes == 0
    assert st.failed_flushes == 0
    assert all(f.stage == "numpy" for f in st.flushes)
    states = svc._breakers.states()  # gating touches every rung lazily
    assert states and all(s == BREAKER_CLOSED for s in states.values())
