"""Property-based serving tests (DESIGN.md §9).

Hypothesis drives seeded request interleavings — mixed matrices (plus a
duplicate tenant id sharing one pattern), request widths 1..33, clock
advances between submits — through a `SolveService` and asserts two
contracts against the per-request oracle:

  * **bit-identity**: every routed result equals the per-request solve
    of the same column through the same backend, `np.array_equal`-exact
    (micro-batching may never change arithmetic — no executor mixes
    columns);
  * **trace discipline**: the executor cache is hit at most once per
    (program, padded width) on the jax backend — flush widths bucket
    with the same `executor.pad_batch` the cache keys on — and never on
    the numpy backend.

Runs 200 derandomized examples per backend (numpy / jax): seeded
hypothesis + the injectable clock only, no wall time anywhere.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import api, executor  # noqa: E402
from repro.core.matrices import banded  # noqa: E402
from repro.core.serve import (  # noqa: E402
    ManualClock,
    ProgramCache,
    SolveService,
)

# tiny matrices keep 2 x 200 examples fast; the full-size service behavior
# is covered by tests/test_serve.py
_MATS = [
    banded(40, 6, 0.6, 101, "tiny_a"),
    banded(56, 8, 0.5, 102, "tiny_b"),
    banded(64, 5, 0.5, 103, "tiny_c"),
]
# one shared cache: programs compile once for the whole suite, and tenant
# "m0dup" below shares m0's entry (same pattern fingerprint)
_CACHE = ProgramCache(capacity=8)
# (backend, id(program), padded width) pairs that have already traced
_SEEN: set = set()

_steps = st.lists(
    st.tuples(
        st.integers(min_value=0, max_value=3),        # tenant index
        st.integers(min_value=1, max_value=33),       # request width
        st.sampled_from([0.0, 0.4, 1.2]),             # clock advance
        st.integers(min_value=0, max_value=2**31 - 1),  # rhs seed
    ),
    min_size=1, max_size=6,
)

_IDS = ["m0", "m1", "m2", "m0dup"]
_BY_ID = {"m0": _MATS[0], "m1": _MATS[1], "m2": _MATS[2],
          "m0dup": _MATS[0]}


def _oracle(prog, bmat, backend):
    """Per-request solve of the whole request, bypassing the batcher."""
    if backend == "numpy":
        return api.solve_numpy(prog, bmat)
    return np.asarray(api.solve_batch(prog, np.asarray(bmat, np.float32)))


@pytest.mark.parametrize("backend", ["numpy", "jax"])
@settings(max_examples=200, deadline=None, derandomize=True)
@given(steps=_steps)
def test_interleavings_match_per_request_oracle(backend, steps):
    clock = ManualClock()
    svc = SolveService(_CACHE, max_batch=8, max_delay=1.0, clock=clock,
                       backend=backend)
    for mid in _IDS:
        svc.register(mid, _BY_ID[mid])
    traces_before = executor.trace_count()

    submitted = []  # (ticket, matrix_id, bmat)
    for tenant, width, advance, seed in steps:
        clock.advance(advance)
        mid = _IDS[tenant]
        n = _BY_ID[mid].n
        bmat = np.random.default_rng(seed).standard_normal((n, width))
        submitted.append((svc.submit(mid, bmat), mid, bmat))
    svc.drain()

    # every ticket completed and routed results bit-identical to the
    # per-request oracle (columns regrouped by the batcher notwithstanding)
    total_cols = 0
    for ticket, mid, bmat in submitted:
        assert ticket.done
        prog = svc.cache.get(_BY_ID[mid])
        got = ticket.result()
        assert got.shape == bmat.shape
        assert np.array_equal(got, _oracle(prog, bmat, backend)), mid
        total_cols += bmat.shape[1]
    assert svc.stats.completed_columns == total_cols == svc.stats.columns
    assert sum(f.columns for f in svc.stats.flushes) == total_cols

    # trace discipline: at most one trace per (program, padded width);
    # the oracle's width-1 solves share the same keyed cache
    pairs = set()
    for f in svc.stats.flushes:
        prog = svc.cache.get(_BY_ID[f.matrix_id])
        assert f.padded == executor.pad_batch(f.columns)
        pairs.add((backend, id(prog), f.padded))
    for _, mid, bmat in submitted:
        pairs.add((backend, id(svc.cache.get(_BY_ID[mid])),
                   executor.pad_batch(bmat.shape[1])))
    delta = executor.trace_count() - traces_before
    if backend == "numpy":
        assert delta == 0
    else:
        assert delta <= len(pairs - _SEEN), (delta, pairs - _SEEN)
    _SEEN.update(pairs)


def test_duplicate_tenant_ids_share_one_compile():
    """m0 and m0dup fingerprint identically -> one cache entry, one
    compile, however many tenants registered it."""
    from repro.core.serve import pattern_fingerprint

    fp = pattern_fingerprint(_MATS[0])
    ent = _CACHE.entries.get(fp)
    if ent is None:  # property test didn't touch m0 (possible, tiny odds)
        _CACHE.get(_MATS[0])
        ent = _CACHE.entries[fp]
    assert ent.compiles == 1
