"""Substrate tests: optimizer, data pipeline, checkpointing, fault tolerance,
elastic re-meshing, sharding rules."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.data import SyntheticLMDataset
from repro.distributed import HeartbeatMonitor, StragglerPolicy, plan_remesh
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_warmup


# ------------------------------------------------------------------ optim
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state = adamw_update(params, grads, state, 0.05,
                                     weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)
    assert int(state["step"]) == 300


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    got = float(jnp.linalg.norm(clipped["a"]))
    assert abs(got - 1.0) < 1e-4


def test_cosine_warmup_schedule():
    lrs = [float(cosine_warmup(jnp.asarray(s), 1.0, 10, 100)) for s in range(100)]
    assert lrs[0] < 0.2
    assert abs(max(lrs) - 1.0) < 0.1
    assert lrs[-1] < 0.2


# ------------------------------------------------------------------ data
def test_data_deterministic_and_step_indexed():
    ds = SyntheticLMDataset(vocab=100, seq_len=16, global_batch=8, seed=1)
    b1 = ds.batch(7)
    b2 = ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch(8)["tokens"], b1["tokens"])
    # next-token structure
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_host_sharding_partitions():
    ds = SyntheticLMDataset(vocab=50, seq_len=8, global_batch=8, seed=2)
    full = [ds.batch(3, host_id=h, num_hosts=4)["tokens"] for h in range(4)]
    assert all(f.shape == (2, 8) for f in full)
    # learnability: the markov structure bounds the successor set
    b = ds.batch(0)
    succ = {}
    for row in b["tokens"]:
        for a, c in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(c))
    avg = np.mean([len(v) for v in succ.values()])
    assert avg <= 8 * len(ds.tables)  # branch * tables upper bound


# ------------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "opt": {"m": np.ones(3)}}
    save_checkpoint(str(tmp_path), 5, tree)
    zero = jax.tree.map(np.zeros_like, tree)
    restored, step = restore_checkpoint(str(tmp_path), zero)
    assert step == 5
    np.testing.assert_array_equal(restored["params"]["w"], tree["params"]["w"])


def test_checkpoint_ignores_uncommitted(tmp_path):
    tree = {"w": np.ones(2)}
    path = save_checkpoint(str(tmp_path), 1, tree)
    os.makedirs(str(tmp_path / "step_00000009"))  # crashed partial write
    restored, step = restore_checkpoint(str(tmp_path), {"w": np.zeros(2)})
    assert step == 1


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save_async(s, {"w": np.full(3, s, np.float32)})
    mgr.wait()
    assert mgr.latest_step() == 30
    restored, step = mgr.restore({"w": np.zeros(3, np.float32)})
    assert step == 30 and restored["w"][0] == 30
    kept = [n for n in os.listdir(tmp_path) if n.startswith("step_")]
    assert len(kept) == 2  # retention


def test_train_restart_resumes(tmp_path):
    """End-to-end restart: train, 'crash', restart, verify continuation."""
    from repro.launch.train import main

    args = ["--arch", "smollm-360m", "--reduced", "--steps", "6",
            "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "3", "--log-every", "100"]
    main(args)
    r2 = main(args[:4] + ["12"] + args[5:])  # resumes from step 6
    assert r2["steps"] <= 12 - 3  # restored, so fewer than 12 fresh steps


# ------------------------------------------------------------------ fault tolerance
def test_heartbeat_monitor_detects_failures():
    t = [0.0]
    mon = HeartbeatMonitor([0, 1, 2], timeout_s=10.0, clock=lambda: t[0])
    t[0] = 5.0
    mon.beat(0); mon.beat(1)
    t[0] = 12.0
    assert mon.check() == [2]
    assert mon.healthy == [0, 1]
    mon.rejoin(2)
    assert mon.healthy == [0, 1, 2]


def test_straggler_policy_flags_and_evicts():
    pol = StragglerPolicy(factor=1.5, patience=3)
    verdicts = []
    for _ in range(10):
        verdicts.append(pol.record_step({0: 1.0, 1: 1.0, 2: 1.0, 3: 2.5}))
    assert any(3 in v.rebalance for v in verdicts)
    share = pol.host_share([0, 1, 2, 3], [3])
    assert share[3] < share[0]
    for _ in range(10):
        v = pol.record_step({0: 1.0, 1: 1.0, 2: 1.0, 3: 10.0})
    assert 3 in v.evict


def test_elastic_remesh_plans():
    full = plan_remesh(512, model_axis=16, chips_per_pod=256)
    assert full.mesh_shape == (2, 16, 16)
    degraded = plan_remesh(500, model_axis=16, chips_per_pod=256)
    assert degraded.chips_used <= 500
    assert degraded.mesh_shape[-1] == 16  # model axis preserved
    single = plan_remesh(200, model_axis=16, chips_per_pod=256)
    assert single.mesh_shape == (12, 16)
    with pytest.raises(RuntimeError):
        plan_remesh(8, model_axis=16)


# ------------------------------------------------------------------ sharding rules
def test_param_sharding_rules_cover_big_leaves():
    """Every weight matrix leaf must have a non-replicated spec — catching
    rule-regression that would silently replicate a 100GB tensor."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config, list_archs
    from repro.distributed.sharding import _path_str, _spec_for
    from repro.launch.steps import abstract_params

    for arch in list_archs():
        cfg = get_config(arch)
        shapes = abstract_params(cfg)
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            if np.prod(leaf.shape) < 10_000_000:
                continue
            spec = _spec_for(_path_str(path), leaf.ndim)
            assert spec != P(), (arch, _path_str(path), leaf.shape)
