"""DAG-workload frontends: upper / transpose-pair / circuit round-trips.

Each new frontend (core/frontends/) is round-tripped against scipy/numpy
oracles across the executors — the vectorized numpy oracle, the `lax.scan`
JAX executor, and both Pallas placements — plus the batched and sharded
paths, all running the unchanged `Program` format.  Seeded sweeps always
run; hypothesis widens them where it is installed.
"""

import numpy as np
import pytest

from repro.core import api, shard
from repro.core.csr import (
    from_coo,
    serial_solve,
    serial_solve_upper,
    transpose_upper,
)
from repro.core.dag import analyze
from repro.core.frontends.dagcirc import random_circuit
from repro.core.matrices import generate
from repro.core.program import AccelConfig

TOL = dict(rtol=1e-5, atol=1e-5)


def random_lower(n, density, seed, name=None):
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for i in range(1, n):
        m = rng.random(i) < density
        for j in np.nonzero(m)[0]:
            rows.append(i)
            cols.append(int(j))
    vals = rng.uniform(-0.5, 0.5, len(rows))
    diag = rng.uniform(1.0, 2.0, n) * rng.choice([-1.0, 1.0], n)
    return from_coo(n, rows, cols, vals, diag, name=name or f"rnd_{seed}")


# ------------------------------------------------------------------ upper
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_upper_solve_matches_scipy(seed):
    scipy_sparse = pytest.importorskip("scipy.sparse")
    n = 80 + 17 * seed
    u = transpose_upper(random_lower(n, 0.25, seed))
    rng = np.random.default_rng(100 + seed)
    b = rng.standard_normal(n)
    mat = scipy_sparse.csr_matrix(
        (u.values, u.colidx, u.rowptr), shape=(n, n))
    ref = scipy_sparse.linalg.spsolve_triangular(mat, b, lower=False)
    cw = api.compile_upper(u)
    for backend in ("numpy", "jax"):
        np.testing.assert_allclose(cw.solve(b, backend=backend), ref, **TOL)
    np.testing.assert_allclose(serial_solve_upper(u, b), ref, rtol=1e-10)


def test_upper_solve_suite_matrix_all_executors():
    mat = generate("band_cz")
    u = transpose_upper(mat)
    b = np.random.default_rng(7).standard_normal(mat.n)
    ref = serial_solve_upper(u, b)
    cw = api.compile_upper(u)
    np.testing.assert_allclose(cw.solve(b, backend="numpy"), ref, **TOL)
    np.testing.assert_allclose(cw.solve(b, backend="jax"), ref, **TOL)
    np.testing.assert_allclose(
        cw.solve(b, backend="pallas", placement="resident",
                 cycles_per_block=64), ref, **TOL)
    np.testing.assert_allclose(
        cw.solve(b, backend="pallas", placement="blocked",
                 cycles_per_block=64), ref, **TOL)


def test_upper_batched_and_sharded():
    u = transpose_upper(generate("band_cz"))
    n = u.n
    rng = np.random.default_rng(11)
    bmat = rng.standard_normal((n, 8))
    ref = np.stack([serial_solve_upper(u, bmat[:, k]) for k in range(8)],
                   axis=1)
    cw = api.compile_upper(u)
    np.testing.assert_allclose(cw.solve(bmat), ref, **TOL)
    mesh = shard.batch_mesh()
    np.testing.assert_allclose(cw.solve(bmat, mesh=mesh), ref, **TOL)


def test_solve_upper_accepts_raw_matrix():
    u = transpose_upper(random_lower(40, 0.3, 5))
    b = np.random.default_rng(5).standard_normal(40)
    np.testing.assert_allclose(
        api.solve_upper(u, b), serial_solve_upper(u, b), **TOL)


# --------------------------------------------------------- transpose pair
@pytest.mark.parametrize("seed", [3, 4])
def test_compile_pair_ic_sweep(seed):
    """One compiled pair runs the full forward+backward IC application:
    x = Lᵀ \\ (L \\ b) == (L Lᵀ)⁻¹ b."""
    mat = random_lower(70 + 11 * seed, 0.3, seed)
    dense = mat.to_dense()
    rng = np.random.default_rng(200 + seed)
    b = rng.standard_normal(mat.n)
    ref = np.linalg.solve(dense @ dense.T, b)
    pair = api.compile_pair(mat)
    for backend in ("numpy", "jax"):
        got = pair.solve(b, backend=backend)
        np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-5)
    # the backward sweep alone must match the serial upper oracle
    y = serial_solve(mat, b)
    np.testing.assert_allclose(
        pair.backward.solve(y), serial_solve_upper(transpose_upper(mat), y),
        **TOL)


def test_pair_pallas_blocked_placement():
    mat = generate("band_cz")
    pair = api.compile_pair(mat)
    b = np.random.default_rng(13).standard_normal(mat.n)
    dense = mat.to_dense()
    ref = np.linalg.solve(dense @ dense.T, b)
    got = pair.solve(b, backend="pallas", placement="blocked",
                     cycles_per_block=64)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------- circuits
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_circuit_matches_oracle(seed):
    circ = random_circuit(120 + 40 * seed, max_fan_in=5, seed=seed,
                          locality=60 if seed % 2 else None)
    cw = api.compile_circuit(circ)
    rng = np.random.default_rng(300 + seed)
    u = rng.standard_normal(circ.n)
    ref = circ.eval(u)
    for backend in ("numpy", "jax"):
        np.testing.assert_allclose(cw.solve(u, backend=backend), ref, **TOL)


def test_circuit_pallas_and_batched():
    circ = random_circuit(256, max_fan_in=4, seed=9, locality=48)
    cw = api.compile_circuit(circ)
    rng = np.random.default_rng(42)
    umat = rng.standard_normal((circ.n, 4))
    ref = circ.eval(umat)
    np.testing.assert_allclose(cw.solve(umat), ref, **TOL)
    np.testing.assert_allclose(
        cw.solve(umat, backend="pallas", placement="resident",
                 cycles_per_block=32), ref, **TOL)


def test_circuit_pallas_blocked_placement():
    """Strongly-local circuits admit the row-blocked window placement."""
    circ = random_circuit(1024, max_fan_in=4, seed=21, locality=48)
    cw = api.compile_circuit(circ)
    from repro.kernels.sptrsv import ops

    plan = ops.plan_window(cw.program, 32)
    assert plan.feasible and plan.num_blocks > 1
    u = np.random.default_rng(1).standard_normal((circ.n, 4))
    got = cw.solve(u, backend="pallas", placement="blocked",
                   cycles_per_block=32)
    np.testing.assert_allclose(got, circ.eval(u), **TOL)


def test_circuit_stats_and_analysis():
    """Generic DAG workloads get the paper's Table III treatment too."""
    circ = random_circuit(300, seed=4)
    info = analyze(circ)
    assert info.n == 300 and info.nnz == circ.n_edges + circ.n
    prog = api.compile_circuit(circ, AccelConfig()).program
    assert prog.stats.exec_edges == circ.n_edges
    assert prog.stats.exec_finals == circ.n
    rep = api.report(prog)
    assert rep["emitted_cycles"] == prog.cycles          # satellite: report
    assert rep["planes"] == prog.planes                  # exposes PR-4
    assert rep["instr_bytes"] == prog.instr_bytes()      # encoding fields


# -------------------------------------------------- hypothesis wide sweeps
def test_upper_property_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 70), st.floats(0.0, 0.5),
           st.integers(0, 2**31 - 1))
    def run(n, density, seed):
        u = transpose_upper(random_lower(n, density, seed))
        b = np.random.default_rng(seed ^ 0xABC).standard_normal(n)
        cw = api.compile_upper(u)
        ref = serial_solve_upper(u, b)
        np.testing.assert_allclose(cw.solve(b, backend="numpy"), ref, **TOL)

    run()


def test_circuit_property_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 120), st.integers(1, 8),
           st.floats(0.05, 0.9), st.integers(0, 2**31 - 1))
    def run(n, fan_in, leaf_frac, seed):
        circ = random_circuit(n, max_fan_in=fan_in, leaf_frac=leaf_frac,
                              seed=seed)
        u = np.random.default_rng(seed ^ 0x5A5).standard_normal(n)
        cw = api.compile_circuit(circ)
        np.testing.assert_allclose(cw.solve(u, backend="numpy"),
                                   circ.eval(u), **TOL)

    run()


# ------------------------------------------------------- benchmark wiring
def test_dag_workloads_smoke():
    """Tier-1 guard on the DAG-workload benchmark (satellite: CI wiring)."""
    from benchmarks.dag_workloads import run

    rows = run(smoke=True)
    assert rows, "smoke set is empty"
    workloads = {r["workload"] for r in rows}
    assert {"lower", "upper", "transpose_pair", "circuit"} <= workloads
    for r in rows:
        assert r["max_err"] <= 1e-5, r
        assert r["cycles"] >= 1 and r["emitted_cycles"] <= r["cycles"], r
