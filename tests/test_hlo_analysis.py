"""Tests for the trip-count-aware HLO analyzer (roofline data source)."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo


def test_scan_trip_count_exact():
    def f(x, ws):
        def step(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(step, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    s = analyze_hlo(txt)
    assert s["dot_flops"] == 10 * 2 * 128 ** 3
    assert s["dynamic_trip_warnings"] == 0


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(c, wg):
            def inner(ci, w):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, wg)
            return c, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 4, 64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    s = analyze_hlo(txt)
    assert s["dot_flops"] == 12 * 2 * 64 ** 3


def test_collective_bytes_counted():
    import os
    # this test relies on >1 device from the session-wide default; if the
    # runner has a single CPU device the module has no collectives — skip.
    if len(jax.devices()) < 2:
        import pytest
        pytest.skip("needs >1 device")
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((len(jax.devices()),), ("d",))
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)

    def f(a):
        return a.sum(axis=0)

    sh = NamedSharding(mesh, P("d", None))
    txt = (
        jax.jit(f, in_shardings=(sh,), out_shardings=NamedSharding(mesh, P()))
        .lower(x).compile().as_text()
    )
    s = analyze_hlo(txt)
    assert s.collective_bytes > 0


def test_dot_flops_simple():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    txt = jax.jit(f).lower(a, b).compile().as_text()
    s = analyze_hlo(txt)
    assert s["dot_flops"] == 2 * 32 * 64 * 16
