"""Property-based resilience tests (hypothesis; skipped if unavailable).

A random interleaving of solve requests, injected backend faults, clock
advances, and overload bursts is replayed against a resilient
SolveService.  The invariants, for EVERY interleaving:

  * every ticket terminates (no deadlock): completed, typed-failed, or
    shed — never left pending after a drain;
  * every completed non-shed ticket is bit-identical to the
    stage-matched oracle (execute_numpy for entry-rung flushes,
    serial_solve for degraded reference flushes) — zero silent wrong
    answers;
  * every failed ticket carries a typed RobustnessError;
  * accounting closes: requests == completed + failed + shed.
"""

import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.csr import serial_solve
from repro.core.errors import RobustnessError
from repro.core.executor import execute_numpy
from repro.core.matrices import banded
from repro.core.resilience import (
    AdmissionConfig,
    BreakerConfig,
    ResilienceConfig,
    RetryPolicy,
)
from repro.core.schedule import compile_program
from repro.core.serve import ManualClock, ProgramCache, SolveService

_MATS = {
    "a": banded(40, 6, 0.6, 101, "prop_res_a"),
    "b": banded(32, 4, 0.5, 102, "prop_res_b"),
}
_PROGS = {mid: compile_program(m) for mid, m in _MATS.items()}

# (tenant, n_cols, fault, advance_s, rhs_seed) — fault applies to the
# entry ("numpy") rung of the flush that next consumes the solver.
_STEP = st.tuples(
    st.sampled_from(sorted(_MATS)),
    st.integers(min_value=1, max_value=5),
    st.sampled_from(["none", "none", "exc", "exc-exc", "nan"]),
    st.sampled_from([0.0, 0.05, 0.2, 0.6, 1.5]),
    st.integers(min_value=0, max_value=2**16),
)


def _build_service(fault_feed):
    clock = ManualClock()
    res = ResilienceConfig(
        retry=RetryPolicy(max_retries=1, base_delay_s=0.001, jitter=0.0),
        breaker=BreakerConfig(window_s=30.0, min_samples=3,
                              failure_threshold=0.6, cooldown_s=2.0),
        admission=AdmissionConfig(max_pending_per_matrix=8,
                                  max_pending_total=12),
    )
    svc = SolveService(ProgramCache(), max_batch=3, max_delay=0.5,
                       clock=clock, backend="numpy", resilience=res)
    for mid, m in _MATS.items():
        svc.register(mid, m)

    orig = svc._stage_solver

    def wrapped(stage, prog, k, mat):
        fn = orig(stage, prog, k, mat)
        if stage != "numpy":
            return fn

        def chaotic(bmat):
            action = fault_feed.pop(0) if fault_feed else "none"
            if action.startswith("exc"):
                if action == "exc-exc":  # survives one retry too
                    fault_feed.insert(0, "exc")
                raise RuntimeError("injected backend fault")
            x = np.asarray(fn(bmat))
            if action == "nan":
                return np.full_like(x, np.nan)
            return x
        return chaotic

    svc._stage_solver = wrapped
    return svc, clock


def _check_ticket(svc, ticket, rhs):
    if ticket.shed:
        with pytest.raises(RobustnessError):
            ticket.result()
        return "shed"
    assert ticket.done, "ticket left pending after drain (deadlock)"
    if ticket.failed:
        assert isinstance(ticket.error, RobustnessError)
        return "failed"
    flush_by_index = {r.index: r for r in svc.stats.flushes if r.index >= 0}
    stages = {flush_by_index[i].stage for i in ticket.flush_indices}
    got = np.asarray(ticket.result())
    mid = ticket.matrix_id
    if stages == {"numpy"}:
        want = np.asarray(execute_numpy(_PROGS[mid], rhs))
    elif stages == {"reference"}:
        bm = np.asarray(rhs, dtype=np.float64)
        cols = bm[:, None] if bm.ndim == 1 else bm
        want = np.stack([serial_solve(_MATS[mid], cols[:, j])
                         for j in range(cols.shape[1])], axis=1)
        if rhs.ndim == 1:
            want = want[:, 0]
    else:  # mixed-stage wide ticket: weaker residual bound
        dense = _MATS[mid].to_dense()
        cols = got.reshape(dense.shape[0], -1).astype(np.float64)
        rcols = rhs.reshape(dense.shape[0], -1).astype(np.float64)
        for j in range(cols.shape[1]):
            r = rcols[:, j] - dense @ cols[:, j]
            denom = max(float(np.linalg.norm(rcols[:, j])), 1e-30)
            assert float(np.linalg.norm(r)) / denom <= 1e-3
        return "completed"
    np.testing.assert_array_equal(got, want)
    return "completed"


@settings(max_examples=60, deadline=None, derandomize=True)
@given(steps=st.lists(_STEP, min_size=1, max_size=12))
def test_random_fault_interleavings_never_silently_wrong(steps):
    fault_feed = [f for (_, _, f, _, _) in steps]
    svc, clock = _build_service(list(fault_feed))
    tickets = []
    for (mid, k, _fault, adv, rhs_seed) in steps:
        rng = np.random.default_rng(rhs_seed)
        n = _MATS[mid].n
        rhs = (rng.standard_normal(n) if k == 1
               else rng.standard_normal((n, k))).astype(np.float32)
        tickets.append((svc.submit(mid, rhs), rhs))
        clock.advance(adv)
        svc.pump()
    clock.advance(10.0)
    svc.pump()
    svc.drain()

    outcomes = {"completed": 0, "failed": 0, "shed": 0}
    for ticket, rhs in tickets:
        outcomes[_check_ticket(svc, ticket, rhs)] += 1
    assert sum(outcomes.values()) == len(steps)
    st_ = svc.stats
    assert st_.requests == len(steps)  # shed requests are still requests
    assert outcomes["shed"] == st_.requests_shed
    assert st_.failed_flushes == 0 or outcomes["failed"] > 0


@settings(max_examples=25, deadline=None, derandomize=True)
@given(steps=st.lists(_STEP, min_size=1, max_size=10),
       seed=st.integers(min_value=0, max_value=7))
def test_interleaving_is_deterministic(steps, seed):
    """The same interleaving replayed twice gives identical outcomes,
    stats, and bit-identical answers — resilience adds no hidden
    nondeterminism (no wall-clock reads, seeded jitter only)."""
    runs = []
    for _ in range(2):
        fault_feed = [f for (_, _, f, _, _) in steps]
        svc, clock = _build_service(list(fault_feed))
        tickets = []
        for (mid, k, _fault, adv, rhs_seed) in steps:
            rng = np.random.default_rng(rhs_seed + seed)
            n = _MATS[mid].n
            rhs = (rng.standard_normal(n) if k == 1
                   else rng.standard_normal((n, k))).astype(np.float32)
            tickets.append(svc.submit(mid, rhs))
            clock.advance(adv)
            svc.pump()
        clock.advance(10.0)
        svc.pump()
        svc.drain()
        outs = []
        for t in tickets:
            if t.shed:
                outs.append(("shed", None))
            elif t.failed:
                outs.append(("failed", type(t.error).__name__))
            else:
                outs.append(("ok", np.asarray(t.result()).tobytes()))
        stats = svc.stats.to_dict()
        stats.pop("flushes", None)
        stats.pop("cache", None)  # compile_seconds is real wall time
        runs.append((outs, stats, [i.kind for i in svc.incidents]))
    assert runs[0] == runs[1]
