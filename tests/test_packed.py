"""Packed single-word VLIW instruction encoding (DESIGN.md §Perf).

Covers the encoding from four angles so it cannot drift silently:
  * a golden-format regression (hand-computed word constants);
  * pack/decode roundtrip property tests (hypothesis) in both plane
    regimes, including the shared-field validation errors;
  * all-three-executor parity on suite matrices in the 1-plane regime and
    the forced 2-plane large-n fallback;
  * all-NOP stall-row elision: hardware vs emitted cycle accounting and
    executor parity on a psum-starved DAG that provokes global stalls.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import api
from repro.core.csr import random_rhs, serial_solve
from repro.core.matrices import generate
from repro.core.program import (
    CTL_BITS,
    OP_BITS,
    OP_FINAL,
    SLOT_BITS,
    SRC_BITS,
    AccelConfig,
    decode_instructions,
    pack_instructions,
    packed_planes,
    validate_fields,
)
from repro.core.schedule import compile_program


def _fields(op, src, ctl, slot):
    """Wrap scalars into the [T=1, P=1] arrays pack_instructions expects."""
    return (np.array([[op]]), np.array([[src]]),
            np.array([[ctl]]), np.array([[slot]]))


# ------------------------------------------------------------- golden format
def test_golden_single_plane_word():
    """The exact bit layout is load-bearing (kernels decode it bitwise) —
    pin it with hand-computed constants."""
    assert (SRC_BITS, OP_BITS, CTL_BITS, SLOT_BITS) == (18, 2, 3, 8)
    word = pack_instructions(*_fields(2, 5, 3, 7), planes=1)
    assert word.shape == (1, 1, 1) and word.dtype == np.int32
    #        src 5   | op 2 << 18 | ctl 3 << 20 | slot 7 << 23
    assert int(word[0, 0, 0]) == 5 + (2 << 18) + (3 << 20) + (7 << 23)
    assert int(word[0, 0, 0]) == 62390277
    # the all-NOP lane is the zero word
    assert int(pack_instructions(*_fields(0, 0, 0, 0), planes=1)[0, 0, 0]) == 0
    # max-value fields still fit the non-negative int32 range
    wmax = pack_instructions(
        *_fields(3, (1 << SRC_BITS) - 1, 7, 255), planes=1)
    assert int(wmax[0, 0, 0]) == (1 << 31) - 1


def test_golden_two_plane_words():
    words = pack_instructions(*_fields(2, 300000, 3, 7), planes=2)
    assert words.shape == (1, 2, 1) and words.dtype == np.int32
    assert int(words[0, 0, 0]) == 300000            # plane 0: full-width src
    assert int(words[0, 1, 0]) == 2 + (3 << 2) + (7 << 5) == 238


def test_packed_planes_threshold():
    assert packed_planes(1 << SRC_BITS) == 1        # n = 2^18 still fits
    assert packed_planes((1 << SRC_BITS) + 1) == 2  # one row more -> fallback
    assert packed_planes(64) == 1


def test_program_golden_format():
    """A compiled Program's packed tensor is self-consistent: decode ->
    re-pack reproduces it bit-exactly, and out_idx is derived from (op, src)."""
    prog = api.compile(generate("band_cz"))
    assert prog.instr.dtype == np.int32
    assert prog.instr.shape == (prog.cycles, 1, prog.num_cus)
    op, src, ctl, slot = decode_instructions(prog.instr, prog.planes)
    repacked = pack_instructions(op, src, ctl, slot, planes=prog.planes)
    np.testing.assert_array_equal(repacked, prog.instr)
    np.testing.assert_array_equal(
        prog.out_idx, np.where(op == OP_FINAL, src, prog.n))
    # every emitted row has at least one active lane (stall rows elided)
    assert (op != 0).any(axis=1).all()


# ---------------------------------------------------- roundtrip (seeded sweep)
# (the hypothesis property variant lives in test_packed_property.py,
# importorskip-guarded; this seeded sweep always runs in tier-1)
@pytest.mark.parametrize("planes", [1, 2])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_pack_decode_roundtrip_seeded(planes, seed):
    rng = np.random.default_rng(seed)
    shape = (int(rng.integers(1, 7)), int(rng.integers(1, 9)))
    src_hi = (1 << SRC_BITS) - 1 if planes == 1 else (1 << 30)
    op = rng.integers(0, 4, shape)
    src = rng.integers(0, src_hi + 1, shape)
    ctl = rng.integers(0, 8, shape)
    slot = rng.integers(0, 256, shape)
    words = pack_instructions(op, src, ctl, slot, planes=planes)
    assert words.dtype == np.int32 and words.shape[1] == planes
    op2, src2, ctl2, slot2 = decode_instructions(words, planes)
    np.testing.assert_array_equal(op2, op)
    np.testing.assert_array_equal(src2, src)
    np.testing.assert_array_equal(ctl2, ctl)
    np.testing.assert_array_equal(slot2, slot)


def test_decode_matches_on_jax_arrays():
    """The shared decode helper is backend-agnostic: jnp arrays decode to
    the same fields the numpy path produces."""
    import jax.numpy as jnp

    prog = api.compile(generate("wide_c36"))
    ref = decode_instructions(prog.instr, prog.planes)
    jx = decode_instructions(jnp.asarray(prog.instr), prog.planes)
    for a, b in zip(ref, jx):
        np.testing.assert_array_equal(np.asarray(b), a)


# ----------------------------------------------------------------- validation
@pytest.mark.parametrize("bad,match", [
    (dict(op=4), "op"),
    (dict(ctl=8), "ctl"),
    (dict(slot=256), "slot"),
    (dict(src=1 << SRC_BITS), "src"),
    (dict(src=-1), "src"),
])
def test_field_validation_rejects_overflow(bad, match):
    """The single shared validation point (satellite: the slot field could
    silently overflow 8 bits via schedule's overflow-slot growth)."""
    base = dict(op=1, src=3, ctl=2, slot=5)
    base.update(bad)
    with pytest.raises(ValueError, match=match):
        pack_instructions(
            *_fields(base["op"], base["src"], base["ctl"], base["slot"]),
            planes=1)


def test_validate_fields_two_plane_src_unbounded():
    # plane-2 src is full int32; only the control fields are width-checked
    validate_fields(*_fields(1, 1 << 25, 2, 5), planes=2)
    with pytest.raises(ValueError, match="slot"):
        validate_fields(*_fields(1, 1 << 25, 2, 300), planes=2)


# ------------------------------------------------------------ executor parity
def _parity(prog, mat, seed, impls=("numpy", "jax", "pallas")):
    b = random_rhs(mat, seed)
    ref = serial_solve(mat, b)
    if "numpy" in impls:
        np.testing.assert_allclose(api.solve_numpy(prog, b), ref,
                                   rtol=1e-5, atol=1e-5 * np.abs(ref).max())
    if "jax" in impls:
        np.testing.assert_allclose(api.solve(prog, b), ref,
                                   rtol=1e-5, atol=1e-5 * np.abs(ref).max())
    if "pallas" in impls:
        from repro.kernels.sptrsv import ops

        np.testing.assert_allclose(ops.solve(prog, b, interpret=True), ref,
                                   rtol=1e-5, atol=1e-5 * np.abs(ref).max())


@pytest.mark.parametrize("name", ["band_cz", "ckt_rajat04", "hub_small"])
@pytest.mark.parametrize("planes", [1, 2])
def test_all_executors_parity_both_regimes(name, planes):
    """Suite parity in the packed 1-plane regime AND the forced 2-plane
    large-n fallback (n >= 2^18 triggers it for real; forcing keeps the
    test matrix compile-time small)."""
    mat = generate(name)
    prog = compile_program(mat, planes=planes)
    assert prog.planes == planes
    assert prog.instr_bytes_per_lane_cycle() == 4 * planes + 4
    _parity(prog, mat, seed=17 + planes)


def test_two_plane_blocked_placement_parity():
    mat = generate("band_cz")
    prog = compile_program(mat, planes=2)
    from repro.kernels.sptrsv import ops

    b = random_rhs(mat, 23)
    x = ops.solve(prog, b, cycles_per_block=64, interpret=True,
                  placement="blocked")
    ref = serial_solve(mat, b)
    np.testing.assert_allclose(x, ref, rtol=1e-5,
                               atol=1e-5 * np.abs(ref).max())


# ------------------------------------------------------------- stall elision
def test_stall_rows_elided_with_parity():
    """A psum-starved config provokes global stalls (all lanes blocked);
    those all-NOP rows must be counted as hardware cycles but elided from
    the emitted stream — and every executor must still match the oracle."""
    mat = generate("ckt_rajat04")
    prog = compile_program(mat, AccelConfig(psum_words=2))
    st_ = prog.stats
    assert st_.emitted_cycles < st_.cycles, "config did not provoke stalls"
    assert prog.cycles == st_.emitted_cycles
    assert prog.row_lo.shape == (prog.cycles,)
    # elided rows carried no work: per-op totals are unchanged
    assert (prog.opcode == 1).sum() == st_.exec_edges
    assert (prog.opcode == 2).sum() == st_.exec_finals
    _parity(prog, mat, seed=31)


def test_hardware_cycle_count_unchanged_by_elision():
    """stats.cycles is the paper's hardware metric: a serial chain still
    costs exactly 2n-1 cycles regardless of emission policy."""
    mat = generate("chain_1k")
    prog = api.compile(mat)
    assert prog.stats.cycles == 2 * mat.n - 1
    assert prog.stats.emitted_cycles <= prog.stats.cycles


# ------------------------------------------------- traffic accounting + smoke
def test_instr_bytes_accounting():
    prog = api.compile(generate("band_cz"))
    assert prog.instr_bytes_per_lane_cycle() == 8   # was 24 unpacked
    assert prog.instr_bytes() == prog.cycles * prog.num_cus * 8


def test_vmem_instruction_buffers_halved():
    """Acceptance: the Pallas double-buffer footprint must be at least
    halved by the packed encoding (it is 3x smaller: 8 vs 24 B)."""
    from repro.kernels.sptrsv import ops

    prog = api.compile(generate("band_cz"))
    now = ops.instr_buffer_bytes(prog, 128)
    five_plane = 2 * 128 * prog.num_cus * 24
    assert now * 2 <= five_plane
    acct = ops.state_bytes(prog, 8, placement="resident")
    assert acct["instr"] == now and acct["total"] == acct["xb"] + now
    plan = ops.plan_window(prog, 64)
    acct_b = ops.state_bytes(prog, 8, placement="blocked", plan=plan,
                             cycles_per_block=64)
    assert acct_b["xb"] == plan.state_bytes(8)


def test_instruction_breakdown_smoke():
    """Tier-1 guard on the traffic accounting (satellite: regressions must
    fail the fast suite, not just benchmark runs)."""
    from benchmarks.instruction_breakdown import run

    rows = run(smoke=True)
    assert rows, "smoke set is empty"
    for r in rows:
        assert r["bytes_per_lane_cycle"] <= 8, r
        assert r["traffic_ratio"] >= 3.0, r
        assert r["emitted_cycles"] <= r["cycles"], r
