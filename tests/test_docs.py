"""Tier-1 wrapper around the docs cross-reference check.

Every DESIGN.md section citation in source must resolve to a real
heading, and every cited markdown file must exist — see
`scripts/check_docs.py`.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))

import check_docs  # noqa: E402


def test_design_sections_resolve():
    problems = check_docs.check()
    assert not problems, "\n".join(problems)


def test_expected_docs_exist():
    for path in check_docs.DOC_FILES.values():
        assert path.exists(), f"missing doc: {path}"


def test_cited_sections_present():
    # the anchors the codebase is known to cite today
    heads = check_docs.design_headings()
    assert {"1", "3", "5", "Perf"} <= heads, heads
