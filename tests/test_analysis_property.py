"""Property-based tests (hypothesis) on the static-analysis subsystem.

Complements `tests/test_analysis.py` (which always runs): for ANY
well-formed random lower-triangular system the verified compile must be
diagnostic-free, and for ANY seed every IR-level fault class must be
caught by its per-pass contract verifier.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import api, matrices  # noqa: E402
from repro.core.analysis import analyze_program  # noqa: E402
from repro.core.csr import from_coo  # noqa: E402
from repro.core.robust import (  # noqa: E402
    IR_FAULT_CLASSES,
    run_ir_fault_injection,
)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 48), st.integers(0, 2**31 - 1))
def test_random_lower_tri_verifies_clean(n, seed):
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for i in range(1, n):
        m = rng.random(i) < 0.3
        for j in np.nonzero(m)[0]:
            rows.append(i)
            cols.append(int(j))
    vals = rng.uniform(-1, 1, len(rows))
    diag = rng.uniform(1.0, 2.0, n)
    mat = from_coo(n, rows, cols, vals, diag, name=f"hyp_an_{seed}")
    prog = api.compile(mat, verify_ir=True)
    assert analyze_program(prog, lint=False).ok()


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(IR_FAULT_CLASSES), st.integers(0, 2**31 - 1))
def test_random_seeded_faults_always_caught(fault, seed):
    mat = matrices.generate("ckt_rajat04")
    (r,) = run_ir_fault_injection(mat, seed=seed, classes=(fault,))
    assert r["applicable"] and r["caught"], r
