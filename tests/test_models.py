"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions, prefill/decode consistency, remat invariance."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import (
    RuntimeFlags,
    decode_step,
    init_params,
    prefill,
    train_forward,
)

pytestmark = pytest.mark.slow

FLAGS = RuntimeFlags(use_pallas=False, interpret=False, remat=False)
KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _extra(cfg):
    if cfg.family == "vlm":
        return {"vision": jax.random.normal(
            KEY, (B, cfg.vision_tokens, cfg.vision_dim), jnp.float32)}
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(
            KEY, (B, cfg.enc_frames, cfg.d_model), jnp.float32)}
    return {}


def test_all_ten_archs_registered():
    expected = {
        "starcoder2-7b", "phi3-medium-14b", "smollm-360m", "granite-8b",
        "llama-3.2-vision-11b", "zamba2-2.7b", "rwkv6-1.6b", "whisper-base",
        "granite-moe-1b-a400m", "arctic-480b",
    }
    assert expected <= set(list_archs())


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    loss, metrics = jax.jit(
        lambda p, t, l: train_forward(p, t, l, cfg, FLAGS, _extra(cfg))
    )(params, tokens, labels)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    # gradient step sanity: grads exist and are finite for every leaf
    grads = jax.grad(
        lambda p: train_forward(p, tokens, labels, cfg, FLAGS, _extra(cfg))[0]
    )(params)
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_arch_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    extra = _extra(cfg)
    full_logits, _ = prefill(params, tokens, cfg, FLAGS, extra)
    _, cache = prefill(params, tokens[:, :S], cfg, FLAGS, extra, pad_to=2 * S)
    logits_d, cache2 = decode_step(params, tokens[:, S:S + 1], cache, cfg, FLAGS)
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(full_logits), rtol=2e-3, atol=2e-3
    )
    assert int(cache2["pos"]) == S + 1


@pytest.mark.parametrize("arch", ["granite-8b", "zamba2-2.7b", "rwkv6-1.6b",
                                  "granite-moe-1b-a400m"])
def test_remat_invariance(arch):
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    losses = []
    for remat in (False, True):
        fl = RuntimeFlags(use_pallas=False, interpret=False, remat=remat)
        loss = train_forward(params, tokens, labels, cfg, fl, _extra(cfg))[0]
        losses.append(float(loss))
    assert abs(losses[0] - losses[1]) < 1e-5


def test_param_count_analytic_close_to_actual():
    """The roofline MODEL_FLOPS uses the analytic count — keep it honest."""
    for arch in ["granite-8b", "smollm-360m", "granite-moe-1b-a400m"]:
        cfg = get_config(arch).reduced()
        params = init_params(KEY, cfg)
        actual = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
        est = cfg.param_count()
        assert 0.5 < est / actual < 1.6, (arch, est, actual)


def test_multi_token_decode_matches_prefill():
    """Decode 4 tokens one at a time == prefill of the longer sequence."""
    cfg = get_config("smollm-360m").reduced()
    params = init_params(KEY, cfg)
    T = 8
    tokens = jax.random.randint(KEY, (B, S + T), 0, cfg.vocab)
    full_logits, _ = prefill(params, tokens, cfg, FLAGS)
    _, cache = prefill(params, tokens[:, :S], cfg, FLAGS, pad_to=S + T)
    for i in range(T):
        logits_d, cache = decode_step(
            params, tokens[:, S + i:S + i + 1], cache, cfg, FLAGS
        )
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(full_logits), rtol=2e-3, atol=2e-3
    )
