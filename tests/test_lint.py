"""Tier-1 shim over the source lint guard (`scripts/check_lint.py`).

The guard enforces the ruff rule subset pinned in ``pyproject.toml``
(F401/E501/W291/W293/E722) over ``src/repro/core`` and ``scripts`` —
with a real ruff when available, its built-in AST checker otherwise —
so lint rot fails the test suite, not just CI environments that happen
to ship ruff.
"""

from __future__ import annotations

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))

import check_lint  # noqa: E402


def test_core_sources_lint_clean(capsys):
    rc = check_lint.main([])
    out = capsys.readouterr().out
    assert rc == 0, f"lint problems:\n{out}"


def test_noqa_suppression_works(tmp_path, monkeypatch):
    f = tmp_path / "mod.py"
    f.write_text("import os  # noqa: F401\nimport sys  # noqa\n")
    monkeypatch.setattr(check_lint, "REPO", tmp_path)
    assert check_lint._lint_file(f) == []


def test_fallback_catches_unused_import(tmp_path, monkeypatch):
    f = tmp_path / "mod.py"
    f.write_text("import os\nimport sys  # noqa: F401\n\n"
                 "x = 1  \ntry:\n    pass\nexcept:\n    pass\n")
    monkeypatch.setattr(check_lint, "REPO", tmp_path)
    problems = check_lint._lint_file(f)
    codes = {p.split(": ")[1].split()[0] for p in problems}
    assert codes == {"F401", "W291", "E722"}


def test_fallback_counts_all_exports_as_used(tmp_path, monkeypatch):
    f = tmp_path / "mod.py"
    f.write_text('from os import path\n\n__all__ = ["path"]\n')
    monkeypatch.setattr(check_lint, "REPO", tmp_path)
    assert check_lint._lint_file(f) == []
