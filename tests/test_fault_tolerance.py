"""Deterministic fault-tolerance control-plane tests (DESIGN.md §7).

`HeartbeatMonitor` / `StragglerPolicy` are clock-injectable — no wall
clock in the decision logic — so the timeout, rejoin, and straggler
rebalance/eviction paths are driven here entirely by a fake clock and
fixed duration streams (referenced from
`distributed/fault_tolerance.py`'s module docstring).
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.distributed import HeartbeatMonitor, StragglerPolicy


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


# ------------------------------------------------------------ heartbeats
def test_timeout_boundary_is_strict():
    clk = FakeClock()
    mon = HeartbeatMonitor([0, 1], timeout_s=10.0, clock=clk)
    clk.t = 10.0
    assert mon.check() == []          # exactly at timeout: still alive
    clk.t = 10.0 + 1e-9
    assert mon.check() == [0, 1]      # strictly beyond: failed
    assert mon.healthy == [] and mon.failed == [0, 1]


def test_beat_with_explicit_timestamp():
    clk = FakeClock()
    mon = HeartbeatMonitor([0, 1], timeout_s=5.0, clock=clk)
    mon.beat(0, at=8.0)               # timestamp from a remote report
    clk.t = 12.0
    assert mon.check() == [1]
    assert mon.last_seen(0) == 8.0
    assert mon.check(at=14.0) == [0]  # explicit-now path


def test_failed_host_beats_ignored_until_rejoin():
    clk = FakeClock()
    mon = HeartbeatMonitor([0], timeout_s=1.0, clock=clk)
    clk.t = 5.0
    assert mon.check() == [0]
    mon.beat(0)                       # zombie heartbeat: must not revive
    assert mon.failed == [0]
    mon.rejoin(0, at=5.5)
    assert mon.healthy == [0] and mon.last_seen(0) == 5.5
    assert mon.check(at=6.0) == []    # fresh lease after rejoin


def test_repeated_check_reports_each_failure_once():
    clk = FakeClock()
    mon = HeartbeatMonitor([0, 1], timeout_s=1.0, clock=clk)
    clk.t = 2.0
    assert mon.check() == [0, 1]
    clk.t = 3.0
    assert mon.check() == []          # newly-failed only, no re-reports


# ------------------------------------------------------------ stragglers
def test_eviction_path_is_deterministic():
    pol = StragglerPolicy(factor=1.5, patience=2, evict_factor=3.0,
                          clock=FakeClock(42.0))
    healthy = {0: 1.0, 1: 1.0, 2: 1.0}
    v1 = pol.record_step({**healthy, 3: 10.0})   # > evict_factor: +2 strikes
    assert v1.rebalance == [3] and v1.evict == [] and v1.at == 42.0
    v2 = pol.record_step({**healthy, 3: 10.0})   # 4 strikes == 2*patience
    assert v2.evict == [3]


def test_rebalance_before_eviction_and_recovery():
    pol = StragglerPolicy(factor=1.5, patience=2, clock=FakeClock())
    healthy = {0: 1.0, 1: 1.0, 2: 1.0}
    for _ in range(2):                           # mild slowness: +1/step
        v = pol.record_step({**healthy, 3: 2.0})
    assert v.rebalance == [3] and v.evict == []
    for _ in range(3):                           # back to speed: decay
        v = pol.record_step({**healthy, 3: 1.0})
    assert v.rebalance == [] and v.evict == []


def test_verdict_timestamps_use_injected_clock():
    clk = FakeClock(7.0)
    pol = StragglerPolicy(clock=clk)
    assert pol.record_step({0: 1.0, 1: 1.0}).at == 7.0
    assert pol.record_step({0: 1.0, 1: 1.0}, at=9.5).at == 9.5


def test_empty_step_rejected():
    with pytest.raises(ValueError, match="at least one host"):
        StragglerPolicy().record_step({})


def test_host_share_discounts_flagged():
    pol = StragglerPolicy()
    share = pol.host_share([0, 1, 2, 3], flagged=[3], discount=0.5)
    assert share[3] == pytest.approx(share[0] / 2)
    assert sum(share.values()) == pytest.approx(1.0)
