"""FROZEN pre-pipeline compiler (PR 5 equivalence reference — do not edit).

Verbatim copy of the monolithic `core/schedule.compile_program` as it stood
before the staged `core/compiler/` pipeline replaced it.  Used only by
`tests/test_compiler_pipeline.py` to assert the pipeline reproduces the
legacy instruction stream and stats bit-for-bit on the bundled matrix
suite.  Original docstring follows.

Cycle-accurate compiler for the medium-granularity dataflow (paper §IV).

This is the paper's custom compiler: it allocates coarse nodes to CUs in
topological order, then simulates the synchronized VLIW machine cycle by
cycle, applying

  * the medium-granularity dataflow (§IV-A): node = minimal *allocation*
    unit, edge = minimal *scheduling* unit;
  * the partial-sum caching mechanism (§IV-B) with the deadlock-avoiding
    capacity rules of Fig. 7;
  * the ICR reordering of intra-node edge computation (§IV-C, Algo. 2),
    implemented exactly (max-count category, tie -> min initial R-value)
    with a lazy max-heap;
  * an online banked-register-file model with value broadcast (same-source
    reads are free — the crossbar broadcasts one read to many CUs) and
    x_i-register-file spill modelling (§III-B live-range/spill discussion).

The output is a `Program`: a dense, branch-free VLIW instruction stream that
the numpy / JAX / Pallas executors run verbatim; the schedule length is the
hardware cycle count (the paper's compiler "can fully predict the behavior
of the hardware", §III-B — we lean on exactly that property for timing).

Deviations from the paper (DESIGN.md §5 "Deviations from the paper"):
  * bank assignment is online least-used-first-fit instead of offline greedy
    graph coloring — same mechanism, conservative (never fewer conflicts);
  * ICR examines a per-CU window of ready edges (default 16);
  * the Fig. 7 capacity rule does not provably exclude a global psum
    deadlock (all slots holding blocked parents while the only startable
    node needs a park); on a detected global stall we park one partial sum
    into emergency overflow slots (modelling a data-memory psum spill, as
    the paper's register-file spill path would) and count `dm_escapes`.
"""

from __future__ import annotations

import heapq
import time
from collections import Counter

import numpy as np

from repro.core.csr import TriCSR
from repro.core.program import (
    OP_EDGE,
    OP_FINAL,
    PS_KEEP,
    PS_LOAD,
    PS_RESET,
    PS_STORE_RESET,
    PS_SWAP,
    AccelConfig,
    Program,
    ScheduleStats,
    pack_instructions,
    packed_planes,
)

__all__ = ["compile_program", "allocate_nodes", "PSUM_OVERFLOW_SLOTS"]

PSUM_OVERFLOW_SLOTS = 4  # emergency data-memory-modelled psum spill slots


# ---------------------------------------------------------------------------
# Node -> CU allocation (topological order == row order for triangular L)
# ---------------------------------------------------------------------------
def allocate_nodes(mat: TriCSR, cfg: AccelConfig) -> list[list[int]]:
    p = cfg.num_cus
    tasks: list[list[int]] = [[] for _ in range(p)]
    if cfg.alloc == "roundrobin":
        for i in range(mat.n):
            tasks[i % p].append(i)
        return tasks
    if cfg.alloc != "least_edges":
        raise ValueError(f"unknown alloc policy {cfg.alloc!r}")
    indeg = mat.in_degree()
    heap = [(0, c) for c in range(p)]  # (load, cu) — least accumulated work
    heapq.heapify(heap)
    for i in range(mat.n):
        w, c = heapq.heappop(heap)
        tasks[c].append(i)
        heapq.heappush(heap, (w + int(indeg[i]) + 1, c))
    return tasks


class _Node:
    __slots__ = (
        "nid", "owner", "srcs", "val_of", "ready", "pending",
        "remaining", "started", "solved", "slot",
    )

    def __init__(self, nid: int, owner: int, srcs, val_idx):
        self.nid = nid
        self.owner = owner
        self.srcs = srcs
        self.val_of = dict(zip(srcs.tolist(), val_idx.tolist()))
        self.ready: list[int] = []
        self.pending = len(srcs)
        self.remaining = len(srcs)
        self.started = False
        self.solved = False
        self.slot = -1

    def has_work(self) -> bool:
        return bool(self.ready) or (self.remaining == 0 and not self.solved)


class _CU:
    __slots__ = (
        "cid", "tasks", "pos_of", "head", "started_mask", "current",
        "cached", "free_slots", "free_over", "next_over", "resident",
        "spilled", "done_count", "edge_count",
    )

    def __init__(self, cid: int, tasks: list[int], psum_words: int):
        self.cid = cid
        self.tasks = tasks
        self.pos_of = {nd: k for k, nd in enumerate(tasks)}
        self.head = 0
        self.started_mask = np.zeros(len(tasks), dtype=bool)
        self.current: _Node | None = None
        self.cached: list[_Node] = []
        self.free_slots = list(range(psum_words))
        self.free_over = list(range(psum_words, psum_words + PSUM_OVERFLOW_SLOTS))
        self.next_over = psum_words + PSUM_OVERFLOW_SLOTS  # grows on demand
        self.resident: dict[int, int] = {}
        self.spilled: set[int] = set()
        self.done_count = 0
        self.edge_count = 0

    def peek_over_slot(self) -> int:
        """Next overflow slot (modelled data-memory psum spill; unbounded)."""
        if self.free_over:
            return self.free_over[0]
        if self.next_over > 250:
            raise RuntimeError("psum overflow slots exhausted (>250)")
        return self.next_over

    def advance_head(self) -> None:
        while self.head < len(self.tasks) and self.started_mask[self.head]:
            self.head += 1

    def release_slot(self, slot: int, psum_words: int) -> None:
        if slot < psum_words:
            self.free_slots.append(slot)
        else:
            self.free_over.append(slot)

    def all_done(self) -> bool:
        return self.done_count == len(self.tasks)


def _icr_assign(edge_cus, cands):
    """Algorithm 2 of the paper, exact, via a lazy max-heap.

    Returns {cu: src}.  Categories = distinct source nodes; repeatedly pick
    the category with the most remaining edges (tie -> smallest initial
    R-value, then smallest id), assign it to every CU that has it, remove
    those CUs, and recount.
    """
    cnt: Counter = Counter()
    cu_of_src: dict[int, list[int]] = {}
    for c in edge_cus:
        for s in cands[c]:
            cnt[s] += 1
            cu_of_src.setdefault(s, []).append(c)
    r_value = dict(cnt)
    heap = [(-v, r_value[s], s) for s, v in cnt.items()]
    heapq.heapify(heap)
    assigned: dict[int, int] = {}
    unassigned = set(edge_cus)
    while unassigned and heap:
        negv, _, s = heapq.heappop(heap)
        if cnt.get(s, 0) != -negv:
            continue  # stale entry
        for c in cu_of_src[s]:
            if c in unassigned:
                assigned[c] = s
                unassigned.discard(c)
                for s2 in cands[c]:
                    v = cnt.get(s2, 0)
                    if v > 0:
                        cnt[s2] = v - 1
                        if v > 1:
                            heapq.heappush(heap, (-(v - 1), r_value[s2], s2))
                        else:
                            del cnt[s2]
    return assigned


def compile_program(mat: TriCSR, cfg: AccelConfig | None = None, *,
                    planes: int | None = None) -> Program:
    """Compile ``mat`` into a packed VLIW `Program`.

    ``planes`` forces the packed-word layout (1 = single-word, 2 = the
    large-n fallback); ``None`` auto-selects via `program.packed_planes`.
    Cycles in which no lane executes (bank-conflict replay / global stalls)
    are counted in ``stats.cycles`` (the hardware cycle count) but *elided*
    from the emitted instruction stream — an all-NOP row carries no
    information, so streaming it would be pure HBM traffic
    (``stats.emitted_cycles`` counts the rows actually emitted).
    """
    cfg = cfg or AccelConfig()
    if cfg.dataflow not in ("medium", "coarse"):
        raise ValueError(f"unknown dataflow {cfg.dataflow!r}")
    t0 = time.perf_counter()
    n, p = mat.n, cfg.num_cus
    inv_diag = 1.0 / mat.diag()

    task_lists = allocate_nodes(mat, cfg)
    owner = np.empty(n, dtype=np.int64)
    for c, ts in enumerate(task_lists):
        for nid in ts:
            owner[nid] = c

    nodes: list[_Node] = []
    consumers: list[list[int]] = [[] for _ in range(n)]
    for i in range(n):
        lo, hi = int(mat.rowptr[i]), int(mat.rowptr[i + 1])
        srcs = mat.colidx[lo : hi - 1]
        nodes.append(_Node(i, int(owner[i]), srcs, np.arange(lo, hi - 1)))
        for j in srcs:
            consumers[j].append(i)

    cus = [_CU(c, task_lists[c], cfg.psum_words) for c in range(p)]
    startable: list[dict[int, int]] = [dict() for _ in range(p)]  # pos -> nid
    for nd in nodes:
        if nd.pending == 0:
            c = nd.owner
            startable[c][cus[c].pos_of[nd.nid]] = nd.nid

    ops_t, val_t, src_t, pct_t, psl_t = [], [], [], [], []
    rlo_t: list[int] = []  # per-cycle min/max solution row touched
    rhi_t: list[int] = []  # (row-blocked executor metadata, DESIGN.md §1)
    stream: list[float] = []
    stats = ScheduleStats(name=mat.name, n=n, nnz=mat.nnz, cycles=0,
                          exec_edges=0, exec_finals=0)

    bank_of: dict[int, int] = {}
    bank_load = np.zeros(cfg.num_banks, dtype=np.int64)
    bank_free_order = list(range(cfg.num_banks))

    solved_total = 0
    cycle = 0
    stall_streak = 0
    values = mat.values
    max_cycles = 8 * mat.nnz + 64 * n + 4096

    while solved_total < n:
        if cycle > max_cycles:
            raise RuntimeError(f"scheduler did not converge on {mat.name}")
        op_row = np.zeros(p, dtype=np.uint8)
        val_row = np.zeros(p, dtype=np.int32)
        src_row = np.zeros(p, dtype=np.int32)
        pct_row = np.zeros(p, dtype=np.uint8)
        psl_row = np.zeros(p, dtype=np.uint8)

        # ---------------------------------------------- phase 1: node choice
        chosen: list[tuple[str, _Node, int, int] | None] = [None] * p
        nop_kind: list[str | None] = [None] * p

        for cu in cus:
            c = cu.cid
            if cu.all_done():
                nop_kind[c] = "l"
                continue
            cur = cu.current
            cur_live = cur is not None and not cur.solved

            if cfg.dataflow == "coarse":
                cu.advance_head()
                if cur_live and cur.has_work():
                    kind = "edge" if cur.ready else "final"
                    chosen[c] = (kind, cur, PS_KEEP, 0)
                elif not cur_live and cu.head < len(cu.tasks):
                    nd = nodes[cu.tasks[cu.head]]
                    if nd.pending == 0:
                        kind = "edge" if nd.ready else "final"
                        chosen[c] = (kind, nd, PS_RESET, 0)
                    else:
                        nop_kind[c] = "d"
                else:
                    nop_kind[c] = "d"
                continue

            picked: tuple[str, _Node] | None = None
            for nd in cu.cached:  # cached nodes have absolute priority
                if nd.has_work():
                    picked = ("resume", nd)
                    break
            if picked is None and cur_live and cur.has_work():
                picked = ("continue", cur)
            if picked is None and startable[c] and (cfg.psum_cache or not cur_live):
                pos = min(startable[c])
                picked = ("start", nodes[startable[c][pos]])
            if picked is None:
                # deadlock escape (also required with psum_cache=False: a
                # blocked current node can circularly wait on unstarted
                # nodes — see docstring)
                if stall_streak >= 2 and cur_live and startable[c]:
                    pos = min(startable[c])
                    nd = nodes[startable[c][pos]]
                    stats.dm_escapes += 1
                    kind = "edge" if nd.ready else "final"
                    chosen[c] = (kind, nd, PS_STORE_RESET, cu.peek_over_slot())
                    continue
                nop_kind[c] = "d"
                continue

            mode, nd = picked
            if mode == "resume":
                if cur_live:
                    ctrl, slot = PS_SWAP, nd.slot  # read-before-write swap
                else:
                    ctrl, slot = PS_LOAD, nd.slot
            elif mode == "continue":
                ctrl, slot = PS_KEEP, 0
            else:  # start
                if cur_live:
                    cu.advance_head()
                    first_new = (cu.head < len(cu.tasks)
                                 and cu.tasks[cu.head] == nd.nid)
                    need = 1 if first_new else 2
                    if len(cu.free_slots) < need:
                        if stall_streak >= 2:
                            # emergency psum overflow park (DESIGN.md §5)
                            ctrl, slot = PS_STORE_RESET, cu.peek_over_slot()
                            stats.dm_escapes += 1
                            kind = "edge" if nd.ready else "final"
                            chosen[c] = (kind, nd, ctrl, slot)
                            continue
                        nop_kind[c] = "p"
                        continue
                    ctrl, slot = PS_STORE_RESET, cu.free_slots[0]
                else:
                    ctrl, slot = PS_RESET, 0
            kind = "edge" if nd.ready else "final"
            chosen[c] = (kind, nd, ctrl, slot)

        # ---------------------------------------------- phase 2: ICR + banks
        edge_cus = [c for c in range(p) if chosen[c] and chosen[c][0] == "edge"]
        assigned_src: dict[int, int] = {}
        if edge_cus:
            w = cfg.icr_window
            cands = {c: chosen[c][1].ready[:w] for c in edge_cus}
            if cfg.icr:
                assigned_src = _icr_assign(edge_cus, cands)
            else:
                for c in edge_cus:  # traditional ascending-source-id pick
                    assigned_src[c] = min(chosen[c][1].ready)

            group = Counter(assigned_src.values())
            stats.distinct_reads += len(group)
            stats.reuse_events += sum(v - 1 for v in group.values())
            k = len(group)
            stats.constraints += k * (k - 1) // 2

            # banked-read model: one distinct address per bank per cycle;
            # identical addresses broadcast for free via the crossbar.
            used_banks: dict[int, int] = {}
            for s in sorted(group, key=lambda s_: (-group[s_], s_)):
                if s not in bank_of:
                    free = [b for b in bank_free_order if b not in used_banks]
                    pool = free if free else bank_free_order
                    b = min(pool, key=lambda b_: (bank_load[b_], b_))
                    bank_of[s] = b
                    bank_load[b] += 1
                b = bank_of[s]
                if b in used_banks and used_banks[b] != s:
                    for c in [c_ for c_, ss in assigned_src.items() if ss == s]:
                        del assigned_src[c]
                        chosen[c] = None
                        nop_kind[c] = "b"
                        stats.conflicts += 1
                else:
                    used_banks[b] = s

            # x_i register-file spill-reload model
            for c in list(assigned_src):
                s = assigned_src[c]
                cu = cus[c]
                if s in cu.spilled:
                    cu.spilled.discard(s)
                    if len(cu.resident) >= cfg.xi_words:
                        evict = min(cu.resident, key=cu.resident.get)
                        cu.spilled.add(evict)
                        del cu.resident[evict]
                    cu.resident[s] = 1
                    del assigned_src[c]
                    chosen[c] = None
                    nop_kind[c] = "s"

        # ---------------------------------------------- phase 3: execute
        newly_solved: list[_Node] = []
        executed = 0
        for c in range(p):
            if chosen[c] is None:
                k = nop_kind[c]
                if k == "b":
                    stats.bnop += 1
                elif k == "p":
                    stats.pnop += 1
                elif k == "s":
                    stats.snop += 1
                elif k == "l":
                    stats.lnop += 1
                else:
                    stats.dnop += 1
                continue
            executed += 1
            kind, nd, ctrl, slot = chosen[c]
            cu = cus[c]
            cur = cu.current

            if ctrl == PS_SWAP:
                cur.slot = nd.slot
                cu.cached[cu.cached.index(nd)] = cur
                nd.slot = -1
            elif ctrl == PS_LOAD:
                cu.release_slot(nd.slot, cfg.psum_words)
                cu.cached.remove(nd)
                nd.slot = -1
            elif ctrl == PS_STORE_RESET:
                if slot < cfg.psum_words:
                    cu.free_slots.remove(slot)
                elif slot in cu.free_over:
                    cu.free_over.remove(slot)
                else:
                    assert slot == cu.next_over
                    cu.next_over += 1
                cur.slot = slot
                cu.cached.append(cur)

            if not nd.started:
                nd.started = True
                pos = cu.pos_of[nd.nid]
                cu.started_mask[pos] = True
                startable[c].pop(pos, None)
                cu.advance_head()
            cu.current = nd

            pct_row[c] = ctrl
            psl_row[c] = slot

            if kind == "edge":
                s = assigned_src[c]
                nd.ready.remove(s)
                nd.remaining -= 1
                cu.edge_count += 1
                if s in cu.resident:
                    cu.resident[s] -= 1
                    if cu.resident[s] <= 0:
                        del cu.resident[s]  # release after last use (R_vs)
                op_row[c] = OP_EDGE
                val_row[c] = len(stream)
                stream.append(float(values[nd.val_of[s]]))
                src_row[c] = s
                stats.exec_edges += 1
            else:
                op_row[c] = OP_FINAL
                val_row[c] = len(stream)
                stream.append(float(inv_diag[nd.nid]))
                src_row[c] = nd.nid  # FINAL writes x[src]: out_idx is derived
                nd.solved = True
                cu.done_count += 1
                newly_solved.append(nd)
                stats.exec_finals += 1

        stall_streak = 0 if executed else stall_streak + 1

        # deliver newly solved values — consumable from the NEXT cycle
        for nd in newly_solved:
            solved_total += 1
            j = nd.nid
            per_cu_uses: dict[int, int] = {}
            for i in consumers[j]:
                cons = nodes[i]
                cons.ready.append(j)
                cons.pending -= 1
                cu_i = cons.owner
                per_cu_uses[cu_i] = per_cu_uses.get(cu_i, 0) + 1
                if not cons.started:
                    startable[cu_i][cus[cu_i].pos_of[i]] = i
            for cu_i, uses in per_cu_uses.items():
                cu = cus[cu_i]
                if len(cu.resident) < cfg.xi_words:
                    cu.resident[j] = cu.resident.get(j, 0) + uses
                else:
                    cu.spilled.add(j)
                    stats.spilled_values += 1

        if executed:
            ops_t.append(op_row)
            val_t.append(val_row)
            src_t.append(src_row)
            pct_t.append(pct_row)
            psl_t.append(psl_row)
            # Solution rows touched this cycle: EDGE lanes read x[src],
            # FINAL lanes read b[src] and write x[src].  The per-cycle
            # [lo, hi] envelope is what the row-blocked Pallas path needs
            # to place its VMEM window.
            touched = src_row[op_row != 0]
            rlo_t.append(int(touched.min()))
            rhi_t.append(int(touched.max()))
        # else: all-NOP stall cycle — counts as hardware time but is elided
        # from the emitted stream (no state changes, no traffic needed)
        cycle += 1

    stats.cycles = cycle
    stats.emitted_cycles = len(ops_t)
    stats.per_cu_edges = np.array([cu.edge_count for cu in cus])
    num_slots = max(cu.next_over for cu in cus)

    instr = pack_instructions(
        np.stack(ops_t), np.stack(src_t), np.stack(pct_t), np.stack(psl_t),
        planes=planes if planes is not None else packed_planes(n),
    )
    stats.compile_seconds = time.perf_counter() - t0

    return Program(
        num_slots=num_slots,
        config=cfg,
        n=n,
        instr=instr,
        val_idx=np.stack(val_t),
        stream=np.array(stream, dtype=np.float32),
        stats=stats,
        row_lo=np.array(rlo_t, dtype=np.int32),
        row_hi=np.array(rhi_t, dtype=np.int32),
    )
