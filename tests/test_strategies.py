"""Scheduling-strategy frontier (DESIGN.md §11): correctness + selection.

Every registered strategy must produce a `Program` that passes the full
static verifier and bit-matches the numpy oracle on every executor; the
analytic cost model must be exact (predicted cycles == measured
``stats.cycles``); ``schedule="auto"`` must never be worse than the
paper baseline and must win where the frontier says it does; and the
strategy must be part of the `ProgramCache` identity.  The
``BENCH_schedule.json`` trajectory schema is guarded here too.
"""

import numpy as np
import pytest

from repro.core import api, robust
from repro.core.compiler import strategies
from repro.core.csr import random_rhs, serial_solve
from repro.core.matrices import generate
from repro.core.program import AccelConfig
from repro.core.serve import ProgramCache, pattern_fingerprint
from repro.kernels.sptrsv import ops

ALT_STRATEGIES = [s for s in strategies.STRATEGIES if s != "paper"]
PARITY_SET = ["band_cz", "hub_small"]


# ------------------------------------------------ registry + validation
def test_registry_shape_and_unknown_name():
    assert list(strategies.STRATEGIES) == ["paper", "level", "locality",
                                           "cpath", "eager"]
    with pytest.raises(ValueError, match="unknown schedule strategy"):
        strategies.get("nope")
    with pytest.raises(ValueError, match="unknown schedule strategy"):
        api.compile(generate("hub_small"), schedule="nope")


def test_coarse_dataflow_keeps_single_candidate():
    cfg = AccelConfig(dataflow="coarse", icr=False, psum_cache=False)
    assert strategies.candidate_names(cfg) == ["paper"]
    # auto degrades to the paper schedule rather than erroring
    prog = api.compile(generate("hub_small"), cfg, schedule="auto")
    assert prog.stats.schedule == "paper"


# ------------------------------------------------ per-strategy parity
@pytest.mark.parametrize("name", PARITY_SET)
@pytest.mark.parametrize("strategy", ALT_STRATEGIES)
def test_strategy_verifies_and_matches_oracle(name, strategy):
    mat = generate(name)
    prog = api.compile(mat, schedule=strategy, verify_ir=True)
    robust.verify_program(prog)  # raises on any structural/hazard diag
    assert prog.stats.schedule == strategy
    b = random_rhs(mat, 11)
    np.testing.assert_allclose(api.solve_numpy(prog, b),
                               serial_solve(mat, b), rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("strategy", ALT_STRATEGIES)
def test_strategy_jax_and_pallas_executors_agree(strategy):
    mat = generate("band_cz")
    prog = api.compile(mat, schedule=strategy)
    b = random_rhs(mat, 12)
    ref = api.solve_numpy(prog, b)
    np.testing.assert_allclose(api.solve(prog, b), ref,
                               rtol=1e-5, atol=1e-5)
    xr = ops.solve(prog, b, interpret=True, placement="resident")
    np.testing.assert_allclose(xr, ref, rtol=1e-5, atol=1e-5)
    plan = ops.plan_window(prog, 64)
    if plan.feasible:
        xb = ops.solve(prog, b, cycles_per_block=64, interpret=True,
                       placement="blocked")
        np.testing.assert_allclose(xb, ref, rtol=1e-5, atol=1e-5)
    else:
        # level-set packing interleaves distant rows, so its envelope
        # can legitimately admit no window; the SPT205 lint covers it
        assert strategy == "level", plan.reason


# ------------------------------------------------ cost model + auto
def test_auto_cost_model_is_exact_and_never_worse_than_paper():
    for name in ("ckt_fpga", "hub_small", "band_cz"):
        prog = api.compile(generate(name), schedule="auto")
        st = prog.stats
        costs = st.schedule_costs
        assert set(costs) == set(strategies.STRATEGIES)
        assert st.schedule in costs
        assert st.cycles == costs[st.schedule]["cycles"], name
        assert st.cycles <= costs["paper"]["cycles"], name


def test_auto_strictly_wins_on_psum_bound_circuit():
    # list schedulers beat the paper's resume-first order on ckt_fpga
    prog = api.compile(generate("ckt_fpga"), schedule="auto")
    st = prog.stats
    assert st.schedule != "paper"
    assert st.cycles < st.schedule_costs["paper"]["cycles"]
    b = random_rhs(generate("ckt_fpga"), 13)
    np.testing.assert_allclose(api.solve_numpy(prog, b),
                               serial_solve(generate("ckt_fpga"), b),
                               rtol=2e-4, atol=1e-4)


def test_auto_records_selection_pass_and_report():
    prog = api.compile(generate("hub_small"), schedule="auto")
    names = [ps.name for ps in prog.stats.pass_stats]
    assert "strategy_select" in names
    sel = next(ps for ps in prog.stats.pass_stats
               if ps.name == "strategy_select")
    assert sel.metrics["chosen"] == prog.stats.schedule
    assert set(sel.metrics["predicted_cycles"]) == \
        set(strategies.STRATEGIES)
    rep = api.report(prog)
    assert rep["schedule"] == prog.stats.schedule
    assert set(rep["schedule_costs"]) == set(strategies.STRATEGIES)


def test_explicit_strategy_round_trips_serialization(tmp_path):
    prog = api.compile(generate("hub_small"), schedule="locality")
    path = tmp_path / "locality.prog"
    api.save_program(prog, path)
    loaded = api.load_program(path)
    assert loaded.stats.schedule == "locality"
    np.testing.assert_array_equal(loaded.instr, prog.instr)


# ------------------------------------------------ cache-key separation
def test_program_cache_keys_separate_strategies():
    mat = generate("hub_small")
    fp_paper = pattern_fingerprint(mat)
    assert pattern_fingerprint(mat, "paper") == fp_paper  # back-compat
    assert pattern_fingerprint(mat, "locality") != fp_paper
    assert pattern_fingerprint(mat, "locality") != \
        pattern_fingerprint(mat, "eager")

    base = ProgramCache(capacity=2)
    alt = ProgramCache(capacity=2, schedule="locality")
    assert base.get(mat).stats.schedule == "paper"
    assert alt.get(mat).stats.schedule == "locality"


# ------------------------------------------------ SPT208 frontier lint
def _fake_costs(paper: int, level: int) -> dict:
    return {s: {"strategy": s, "cycles": c, "stall_rows": 0,
                "psum_spills": 0, "planes": 1}
            for s, c in (("paper", paper), ("level", level))}


def test_spt208_fires_past_threshold_only():
    from repro.core.analysis import analyze_program

    prog = api.compile(generate("hub_small"))
    prog.stats.schedule = "level"
    prog.stats.schedule_costs = _fake_costs(paper=100, level=150)
    assert "SPT208" in analyze_program(prog).codes()
    prog.stats.schedule_costs = _fake_costs(paper=100, level=105)
    assert "SPT208" not in analyze_program(prog).codes()  # within 10%


def test_lint_cli_frontier_flags_paper_on_circuit(capsys):
    from scripts.lint_program import main

    rc = main(["--matrix", "ckt_rajat04", "--schedule", "paper",
               "--frontier"])
    out = capsys.readouterr().out
    assert rc == 0  # warn-severity only
    assert "SPT208" in out


# ------------------------------------------------ bench smoke + schema
def test_schedule_frontier_smoke(capsys):
    from benchmarks.schedule_frontier import main

    main(["--smoke"])
    out = capsys.readouterr().out
    assert "smoke" in out and "never worse" in out


def test_bench_schedule_trajectory_schema():
    from scripts.check_bench import check_schedule

    problems = check_schedule()
    assert not problems, "\n".join(problems)
