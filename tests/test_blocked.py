"""Row-blocked HBM-resident Pallas placement: parity, planning, composition.

The blocked kernel is forced (``placement="blocked"``) with a small
``cycles_per_block`` in interpret mode, so the window machinery — boundary
flush/shift/refill DMAs across many cycle blocks — is exercised on matrices
whose ``x[n_pad, B]`` footprint exceeds a (deliberately tiny) configured
VMEM threshold, as on a real TPU it would at paper-scale n.
"""

import numpy as np
import pytest

from repro.core import api
from repro.core.csr import random_rhs, serial_solve
from repro.core.matrices import generate
from repro.kernels.sptrsv import ops


def _refs(mat, bmat):
    return np.stack(
        [serial_solve(mat, bmat[:, i]) for i in range(bmat.shape[1])], axis=1
    ).astype(np.float32)


# --------------------------------------------------------------- parity
@pytest.mark.parametrize("name,cpb", [
    ("band_cz", 64), ("band_cz", 32), ("chain_1k", 128), ("band_dw2048", 64),
])
def test_blocked_matches_oracle(name, cpb):
    mat = generate(name)
    prog = api.compile(mat)
    plan = ops.plan_window(prog, cpb)
    assert plan.feasible and plan.num_blocks > 1  # window machinery exercised
    assert plan.window < mat.n                    # genuinely sub-vector VMEM
    b = random_rhs(mat, 3)
    x = ops.solve(prog, b, cycles_per_block=cpb, interpret=True,
                  placement="blocked")
    np.testing.assert_allclose(
        x, serial_solve(mat, b).astype(np.float32), rtol=1e-5, atol=1e-5
    )


def test_blocked_matches_resident_batched():
    mat = generate("band_cz")
    prog = api.compile(mat)
    rng = np.random.default_rng(0)
    bmat = rng.standard_normal((mat.n, 5))
    xb = ops.solve(prog, bmat, cycles_per_block=64, interpret=True,
                   placement="blocked")
    xr = ops.solve(prog, bmat, cycles_per_block=64, interpret=True,
                   placement="resident")
    np.testing.assert_allclose(xb, xr, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(xb, _refs(mat, bmat), rtol=1e-5, atol=1e-5)


def test_blocked_past_vmem_threshold():
    """Acceptance: x[n_pad, B] footprint above the configured threshold ->
    auto placement goes blocked, and the solve still matches the oracle."""
    mat = generate("band_cz")
    prog = api.compile(mat)
    nb = 8
    limit = 2 * (mat.n + 1) * nb * 4 - 1  # just below the x+b footprint
    mode, plan = ops.resolve_placement(prog, nb, vmem_limit_bytes=limit,
                                       cycles_per_block=64)
    assert mode == "blocked" and plan.feasible
    rng = np.random.default_rng(1)
    bmat = rng.standard_normal((mat.n, nb))
    x = ops.solve(prog, bmat, cycles_per_block=64, interpret=True,
                  vmem_limit_bytes=limit)
    np.testing.assert_allclose(x, _refs(mat, bmat), rtol=1e-5, atol=1e-5)


def test_single_block_sweep():
    """cycles_per_block > program cycles -> one window, flush-only path."""
    mat = generate("band_cz")
    prog = api.compile(mat)
    plan = ops.plan_window(prog, 1024)
    assert plan.feasible and plan.num_blocks == 1
    b = random_rhs(mat, 5)
    x = ops.solve(prog, b, cycles_per_block=1024, interpret=True,
                  placement="blocked")
    np.testing.assert_allclose(
        x, serial_solve(mat, b).astype(np.float32), rtol=1e-5, atol=1e-5
    )


# --------------------------------------------------------------- planning
def test_plan_window_bounds_envelope():
    """Every cycle block's touched rows must sit inside its planned window."""
    prog = api.compile(generate("band_cz"))
    cpb = 64
    plan = ops.plan_window(prog, cpb)
    assert plan.feasible
    t = prog.cycles
    g = -(-t // cpb)
    for gi in range(g):
        sl = slice(gi * cpb, min((gi + 1) * cpb, t))
        hi = prog.row_hi[sl].max()
        if hi < 0:
            continue
        lo = prog.row_lo[sl][prog.row_hi[sl] >= 0].min()
        assert gi * plan.stride <= lo
        assert hi < gi * plan.stride + plan.window
    assert plan.window >= 2 * plan.stride
    assert plan.n_hbm == (plan.num_blocks - 1) * plan.stride + plan.window


def test_row_metadata_emitted():
    prog = api.compile(generate("chain_1k"))
    assert prog.row_lo is not None and prog.row_hi is not None
    assert prog.row_lo.shape == (prog.cycles,)
    active = prog.row_hi >= 0
    assert (prog.row_lo[active] <= prog.row_hi[active]).all()
    assert prog.row_hi.max() == prog.n - 1  # last row is touched somewhere


def test_threshold_auto_select():
    """Auto placement: resident under the limit, blocked above it, resident
    again when no feasible window exists (hub-heavy circuit DAG)."""
    prog = api.compile(generate("band_cz"))
    mode, plan = ops.resolve_placement(prog, 8, vmem_limit_bytes=1 << 30)
    assert (mode, plan) == ("resident", None)
    mode, plan = ops.resolve_placement(prog, 8, vmem_limit_bytes=1024,
                                       cycles_per_block=64)
    assert mode == "blocked" and plan.feasible and plan.window < prog.n

    ckt = api.compile(generate("ckt_rajat04"))
    assert not ops.plan_window(ckt, 128).feasible
    mode, plan = ops.resolve_placement(ckt, 8, vmem_limit_bytes=1024)
    assert mode == "resident"  # infeasible window -> graceful fallback
    with pytest.raises(ValueError, match="infeasible"):
        ops.resolve_placement(ckt, 8, placement="blocked")


def test_x_block_rows_floor():
    prog = api.compile(generate("band_cz"))
    small = ops.plan_window(prog, 64)
    floored = ops.plan_window(prog, 64, min_window=small.window + 64)
    assert floored.window >= small.window + 64
    assert floored.window % 8 == 0


# --------------------------------------------------------------- caching
def test_pallas_executor_cached_per_knobs():
    from repro.core.executor import _EXEC_CACHE, make_pallas_executor

    prog = api.compile(generate("band_cz"))
    make_pallas_executor(prog, batch=5, cycles_per_block=64,
                         placement="blocked", interpret=True)
    n_entries = len(_EXEC_CACHE[prog])
    # same padded width + knobs -> cache hit, no new entry
    make_pallas_executor(prog, batch=7, cycles_per_block=64,
                         placement="blocked", interpret=True)
    assert len(_EXEC_CACHE[prog]) == n_entries
    # different placement -> its own entry
    make_pallas_executor(prog, batch=5, cycles_per_block=64,
                         placement="resident", interpret=True)
    assert len(_EXEC_CACHE[prog]) == n_entries + 1


# ----------------------------------------------------------- composition
def test_api_solve_batch_pallas_blocked():
    mat = generate("band_cz")
    prog = api.compile(mat)
    rng = np.random.default_rng(2)
    bmat = rng.standard_normal((mat.n, 6))
    x = api.solve_batch(prog, bmat, backend="pallas", placement="blocked",
                        cycles_per_block=64, interpret=True)
    np.testing.assert_allclose(x, _refs(mat, bmat), rtol=1e-5, atol=1e-5)
    solver = api.make_solver(prog, batch=6, backend="pallas",
                             placement="blocked", cycles_per_block=64,
                             interpret=True)
    assert solver.placement == "blocked"
    np.testing.assert_allclose(np.asarray(solver(bmat)), x,
                               rtol=1e-6, atol=1e-6)


def test_solve_split_composes_with_blocked():
    mat = generate("band_dw2048")
    prog, split = api.compile_split(mat, max_indegree=16)
    rng = np.random.default_rng(3)
    bmat = rng.standard_normal((mat.n, 4))
    x = api.solve_split(prog, split, bmat, backend="pallas",
                        placement="blocked", cycles_per_block=64,
                        interpret=True)
    np.testing.assert_allclose(x, _refs(mat, bmat), rtol=1e-5, atol=1e-5)


def test_mesh_shards_blocked_pallas():
    """Row-blocked pallas under shard_map: columns over devices, window
    machinery per device.  Single-device mesh on a plain CPU host; the
    forced-8-device variant lives in the slow sharded suite."""
    from repro.core import shard

    mat = generate("band_cz")
    prog = api.compile(mat)
    mesh = shard.batch_mesh()
    rng = np.random.default_rng(4)
    bmat = rng.standard_normal((mat.n, 2 * mesh.size))
    x = api.solve_batch(prog, bmat, mesh=mesh, backend="pallas",
                        placement="blocked", cycles_per_block=64,
                        interpret=True)
    np.testing.assert_allclose(x, _refs(mat, bmat), rtol=1e-5, atol=1e-5)
