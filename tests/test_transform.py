"""Tests for the beyond-paper medium-node splitting (core.transform)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import api
from repro.core.csr import from_coo, random_rhs, serial_solve
from repro.core.matrices import generate
from repro.core.transform import split_heavy_nodes


def test_split_equivalence_on_suite():
    for name in ["hub_wall", "hub_small", "ckt_rajat04", "band_cz"]:
        mat = generate(name)
        b = random_rhs(mat, 3)
        ref = serial_solve(mat, b)
        prog, split = api.compile_split(mat, max_indegree=48)
        got = api.solve_split(prog, split, b)
        np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_split_bounds_indegree():
    mat = generate("hub_wall")
    split = split_heavy_nodes(mat, max_indegree=32)
    assert split.mat.in_degree().max() <= 32 + split.n_aux  # parent gets aux edges
    # aux rows created for every heavy chunk
    assert split.n_aux > 0
    # identity mapping for untouched systems
    sp2 = split_heavy_nodes(generate("chain_1k"), max_indegree=32)
    assert sp2.n_aux == 0
    assert sp2.mat.n == generate("chain_1k").n


def test_split_speedup_on_load_imbalance():
    """The paper's §V-E open problem: splitting must beat the plain medium
    dataflow AND the fine baseline on pure hub-wall load imbalance."""
    mat = generate("hub_wall")
    base = api.compile(mat)
    prog, split = api.compile_split(mat, max_indegree=64)
    assert prog.stats.cycles < base.stats.cycles / 3
    fine = api.baseline_fine(mat)
    flops = 2 * mat.nnz - mat.n
    gops_split = flops / (prog.stats.cycles * prog.config.clock_period_s) / 1e9
    assert gops_split > fine.throughput_gops()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 9))
def test_split_equivalence_property(seed, max_indeg):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 60))
    rows, cols = [], []
    for i in range(1, n):
        m = rng.random(i) < 0.4
        for j in np.nonzero(m)[0]:
            rows.append(i)
            cols.append(int(j))
    mat = from_coo(n, rows, cols, rng.uniform(-1, 1, len(rows)),
                   rng.uniform(1, 2, n), name=f"h{seed}")
    b = rng.standard_normal(n)
    ref = serial_solve(mat, b)
    split = split_heavy_nodes(mat, max_indegree=max_indeg)
    prog = api.compile(split.mat)
    got = split.extract(api.solve_numpy(prog, split.expand_rhs(b)))
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)
