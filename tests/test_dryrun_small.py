"""Distributed dry-run smoke: compile every family on an 8-device mesh.

The full 512-device 40-cell dry-run is run by `repro.launch.dryrun --all`
(results in results/dryrun/); this test keeps the same code path honest in
CI-sized time by compiling REDUCED configs on 8 fake devices in a
subprocess (XLA device count must be set before jax initializes, hence the
subprocess).
"""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import RuntimeFlags, init_cache
from repro.launch.steps import (abstract_params, abstract_opt_state,
                                make_train_step, make_decode_step)
from repro.distributed.sharding import (param_shardings, cache_shardings,
                                        batch_sharding, dp_axes)

out = {}
mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch in %ARCHS%:
    cfg = get_config(arch).reduced()
    flags = RuntimeFlags(use_pallas=False, interpret=False, remat=True,
                         mesh=mesh, dp=dp_axes(mesh))
    p_shape = abstract_params(cfg)
    p_shard = param_shardings(mesh, p_shape)
    o_shape = abstract_opt_state(p_shape)
    o_shard = param_shardings(mesh, o_shape)
    o_shard["step"] = NamedSharding(mesh, P())
    B, S = 4, 64
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.vision_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), jnp.float32)
    b_shard = {k: (batch_sharding(mesh, B) if v.ndim == 2 else
                   NamedSharding(mesh, P(("data",), None, None)))
               for k, v in batch.items()}
    with mesh:
        c = jax.jit(make_train_step(cfg, flags),
                    in_shardings=(p_shard, o_shard, b_shard)
                    ).lower(p_shape, o_shape, batch).compile()
    # decode path too
    cache = jax.eval_shape(lambda: init_cache(cfg, B, 2 * S))
    c_shard = cache_shardings(mesh, cfg, cache, B)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    flags_d = dataclasses.replace(flags, remat=False)
    with mesh:
        c2 = jax.jit(make_decode_step(cfg, flags_d),
                     in_shardings=(p_shard, batch_sharding(mesh, B), c_shard)
                     ).lower(p_shape, tok, cache).compile()
    out[arch] = "ok"
print(json.dumps(out))
"""


@pytest.mark.parametrize("archs", [
    ["smollm-360m", "granite-moe-1b-a400m"],
    ["rwkv6-1.6b", "zamba2-2.7b"],
    ["whisper-base", "llama-3.2-vision-11b"],
])
def test_small_mesh_compile(archs):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    code = SCRIPT.replace("%ARCHS%", json.dumps(archs))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert all(out[a] == "ok" for a in archs)
