"""Deterministic serving tests (DESIGN.md §9): every scheduling branch of
the micro-batcher driven by an injectable clock — no sleeps, no wall
time — plus the `ProgramCache` tier behavior (LRU order, capacity-1
thrash, disk rehydrate, fingerprints, corruption degradation) and the
`BENCH_serve.json` schema / smoke guards for tier-1.
"""

import json

import numpy as np
import pytest

from repro.core import api, executor
from repro.core.errors import ProgramCorruptionError
from repro.core.matrices import generate
from repro.core.serve import (
    FLUSH_DEADLINE,
    FLUSH_DRAIN,
    FLUSH_FULL,
    ManualClock,
    ProgramCache,
    SolveService,
    pattern_fingerprint,
)


@pytest.fixture(scope="module")
def mats():
    return {"a": generate("band_cz"), "b": generate("chem_bp")}


def make_svc(mats, clock, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_delay", 1.0)
    svc = SolveService(ProgramCache(), clock=clock, **kw)
    for mid, m in mats.items():
        svc.register(mid, m)
    return svc


def oracle(svc, mid, b):
    prog = svc.cache.get(svc._mats[mid])
    return np.asarray(api.solve(prog, np.asarray(b, np.float32)))


# ---------------------------------------------------------------- batcher
def test_deadline_flush_not_before_deadline(mats):
    clock = ManualClock()
    svc = make_svc(mats, clock)
    b = np.random.default_rng(0).standard_normal(mats["a"].n)
    t = svc.submit("a", b)
    assert not t.done
    clock.advance(0.999)
    assert svc.pump() == 0 and not t.done
    clock.advance(0.001)  # deadline is inclusive: arrival + max_delay <= now
    assert svc.pump() == 1 and t.done
    assert svc.stats.flushes_deadline == 1 and svc.stats.flushes_full == 0
    assert svc.stats.flushes[0].reason == FLUSH_DEADLINE
    np.testing.assert_array_equal(t.result(), oracle(svc, "a", b))


def test_bucket_full_flush_is_immediate_no_clock_motion(mats):
    clock = ManualClock()
    svc = make_svc(mats, clock)
    rng = np.random.default_rng(1)
    bs = [rng.standard_normal(mats["a"].n) for _ in range(4)]
    tickets = [svc.submit("a", b) for b in bs]
    assert all(t.done for t in tickets)  # 4th submit filled the bucket
    assert svc.stats.flushes_full == 1 and svc.stats.flushes_deadline == 0
    rec = svc.stats.flushes[0]
    assert (rec.reason, rec.columns, rec.padded) == (FLUSH_FULL, 4, 8)
    for t, b in zip(tickets, bs):
        np.testing.assert_array_equal(t.result(), oracle(svc, "a", b))


def test_out_of_order_completion_across_matrices(mats):
    clock = ManualClock()
    svc = make_svc(mats, clock)
    rng = np.random.default_rng(2)
    slow = svc.submit("a", rng.standard_normal(mats["a"].n))
    fast = [svc.submit("b", rng.standard_normal(mats["b"].n))
            for _ in range(4)]
    # matrix b's bucket filled and flushed although submitted later
    assert all(t.done for t in fast) and not slow.done
    clock.advance(1.0)
    svc.pump()
    assert slow.done
    assert slow.completed_at == 1.0 and fast[0].completed_at == 0.0


def test_deadline_order_is_deterministic_oldest_first(mats):
    clock = ManualClock()
    svc = make_svc(mats, clock)
    rng = np.random.default_rng(3)
    ta = svc.submit("a", rng.standard_normal(mats["a"].n))
    clock.advance(0.5)
    tb = svc.submit("b", rng.standard_normal(mats["b"].n))
    clock.advance(1.0)  # both due; a (older) must flush first
    assert svc.pump() == 2
    assert ta.done and tb.done
    assert [f.matrix_id for f in svc.stats.flushes] == ["a", "b"]


def test_submit_pumps_due_buckets_before_enqueueing(mats):
    clock = ManualClock()
    svc = make_svc(mats, clock)
    rng = np.random.default_rng(4)
    old = svc.submit("a", rng.standard_normal(mats["a"].n))
    clock.advance(5.0)
    new = svc.submit("a", rng.standard_normal(mats["a"].n))
    # the overdue bucket flushed (deadline) before the new arrival joined
    assert old.done and not new.done
    assert svc.stats.flushes[0].columns == 1


def test_wide_request_spans_flushes_and_routes_all_columns(mats):
    clock = ManualClock()
    svc = make_svc(mats, clock)
    n = mats["a"].n
    bmat = np.random.default_rng(5).standard_normal((n, 10))
    t = svc.submit("a", bmat)
    # two immediate full flushes of 4, two columns left pending
    assert not t.done and svc.pending_columns("a") == 2
    assert svc.stats.flushes_full == 2
    assert svc.drain() == 1
    assert t.done and t.flush_indices == [0, 1, 2]
    assert svc.stats.flushes[2].reason == FLUSH_DRAIN
    got = t.result()
    assert got.shape == (n, 10)
    for j in range(10):
        np.testing.assert_array_equal(got[:, j], oracle(svc, "a", bmat[:, j]))


def test_per_request_result_routing_distinct_rhs(mats):
    clock = ManualClock()
    svc = make_svc(mats, clock, max_batch=8)
    rng = np.random.default_rng(6)
    bs = [rng.standard_normal(mats["b"].n) for _ in range(8)]
    tickets = [svc.submit("b", b) for b in bs]
    for t, b in zip(tickets, bs):
        np.testing.assert_array_equal(t.result(), oracle(svc, "b", b))


def test_zero_column_request_completes_immediately(mats):
    svc = make_svc(mats, ManualClock())
    t = svc.submit("a", np.zeros((mats["a"].n, 0)))
    assert t.done and t.result().shape == (mats["a"].n, 0)
    assert svc.pending_columns() == 0


def test_submit_errors(mats):
    svc = make_svc(mats, ManualClock())
    with pytest.raises(KeyError, match="unknown matrix_id"):
        svc.submit("nope", np.zeros(4))
    with pytest.raises(ValueError, match="expected b of shape"):
        svc.submit("a", np.zeros(mats["a"].n + 1))
    with pytest.raises(ValueError, match="already registered"):
        svc.register("a", mats["a"])
    t = svc.submit("a", np.zeros(mats["a"].n))
    with pytest.raises(RuntimeError, match="pump\\(\\) or drain\\(\\)"):
        t.result()


def test_core_never_reads_wall_clock(mats):
    calls = []

    def clock():
        calls.append(1)
        return 0.0

    svc = make_svc(mats, clock)
    svc.submit("a", np.zeros(mats["a"].n), now=0.0)
    svc.pump(now=2.0)
    svc.drain(now=3.0)
    # explicit `now=` short-circuits the clock entirely; the default
    # clock is only consulted when no time is passed
    assert calls == []
    svc.submit("a", np.zeros(mats["a"].n))
    assert len(calls) == 1


def test_numpy_backend_and_servestats(mats):
    svc = make_svc(mats, ManualClock(), backend="numpy")
    rng = np.random.default_rng(7)
    before = executor.trace_count()
    bs = [rng.standard_normal(mats["a"].n) for _ in range(4)]
    tickets = [svc.submit("a", b) for b in bs]
    assert executor.trace_count() == before  # numpy path never traces
    prog = svc.cache.get(svc._mats["a"])
    for t, b in zip(tickets, bs):
        np.testing.assert_array_equal(t.result(), api.solve_numpy(prog, b))
    st = svc.stats
    assert (st.requests, st.columns, st.completed_columns) == (4, 4, 4)
    assert st.batched_columns == 4 and st.solver_calls == 1
    assert st.cache["entries"]  # per-entry counters surfaced
    d = st.to_dict()
    assert d["flushes"][0]["reason"] == FLUSH_FULL
    assert json.dumps(d)  # machine-readable end to end


def test_service_arg_validation(mats):
    with pytest.raises(ValueError, match="max_batch"):
        SolveService(max_batch=0)
    with pytest.raises(ValueError, match="max_delay"):
        SolveService(max_delay=-1.0)
    with pytest.raises(ValueError, match="numpy"):
        SolveService(backend="numpy", mesh=object())
    with pytest.raises(ValueError):
        SolveService(backend="bogus")


# ------------------------------------------------------ executor contract
def test_executor_cache_key_contract_asserted(mats):
    prog = ProgramCache().get(mats["a"])
    with pytest.raises(AssertionError, match="padded width"):
        executor._cached_executor(prog, 3)  # 3 is not a padded width
    executor.make_jax_executor(prog, batch=3)  # pads to 8 internally
    entries = executor.cached_entries(prog)
    assert entries and all(
        w == executor.pad_batch(w) for w in entries if isinstance(w, int))


def test_service_buckets_only_create_padded_cache_keys(mats):
    svc = make_svc(mats, ManualClock(), max_batch=5)
    rng = np.random.default_rng(8)
    for _ in range(7):
        svc.submit("a", rng.standard_normal(mats["a"].n))
    svc.drain()
    prog = svc.cache.get(svc._mats["a"])
    widths = [w for w in executor.cached_entries(prog) if isinstance(w, int)]
    assert widths and all(w == executor.pad_batch(w) for w in widths)


# ---------------------------------------------------------- program cache
def _pattern_variant(mat, seed):
    """Same shape/nnz as ``mat``, different pattern (one edge moved)."""
    from repro.core.csr import from_coo

    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    for i in range(mat.n):
        lo, hi = mat.rowptr[i], mat.rowptr[i + 1]
        for j in range(lo, hi - 1):
            rows.append(i)
            cols.append(int(mat.colidx[j]))
            vals.append(float(mat.values[j]))
    # move one off-diagonal edge to a different column
    for k in range(len(cols)):
        i, c = rows[k], cols[k]
        options = [c2 for c2 in range(i) if c2 != c and
                   c2 not in [cols[q] for q in range(len(cols))
                              if rows[q] == i]]
        if options:
            cols[k] = int(rng.choice(options))
            break
    diag = np.asarray([float(mat.values[mat.rowptr[i + 1] - 1])
                       for i in range(mat.n)])
    return from_coo(mat.n, np.asarray(rows), np.asarray(cols),
                    np.asarray(vals), diag, name=mat.name + "_variant")


def test_fingerprint_structure_only_and_distinguishes_patterns(mats):
    m = mats["a"]
    fp = pattern_fingerprint(m)
    # same pattern, different values -> same fingerprint
    import dataclasses

    m2 = dataclasses.replace(m, values=m.values * 2.0)
    assert pattern_fingerprint(m2) == fp
    # same shape, different pattern -> different fingerprint
    m3 = _pattern_variant(m, 0)
    assert m3.n == m.n and m3.nnz == m.nnz
    assert pattern_fingerprint(m3) != fp


def test_lru_eviction_order_and_hits():
    a, b, c = generate("band_cz"), generate("chem_bp"), generate("ckt_fpga")
    cache = ProgramCache(capacity=2)
    pa, pb = cache.get(a), cache.get(b)
    assert cache.fingerprints() == [pattern_fingerprint(a),
                                    pattern_fingerprint(b)]
    assert cache.get(a) is pa  # hit refreshes recency: order now [b, a]
    cache.get(c)               # evicts b (least recently used)
    assert cache.fingerprints() == [pattern_fingerprint(a),
                                    pattern_fingerprint(c)]
    assert cache.evictions == 1
    assert cache.get(b) is not pb  # b was evicted -> recompiled object
    ent = cache.entries[pattern_fingerprint(b)]
    assert ent.compiles == 2 and ent.hits == 0
    ea = cache.entries[pattern_fingerprint(a)]
    assert ea.hits == 1 and ea.compiles == 1
    assert ea.compile_seconds > 0.0


def test_capacity_one_thrash_memory_only():
    a, b = generate("band_cz"), generate("chem_bp")
    cache = ProgramCache(capacity=1)
    for _ in range(2):
        cache.get(a)
        cache.get(b)
    assert len(cache) == 1 and cache.evictions == 3
    assert cache.entries[pattern_fingerprint(a)].compiles == 2
    assert cache.entries[pattern_fingerprint(b)].compiles == 2
    assert cache.hits == 0 and cache.misses == 4


def test_capacity_one_thrash_disk_tier_rehydrates(tmp_path):
    a, b = generate("band_cz"), generate("chem_bp")
    cache = ProgramCache(capacity=1, disk_dir=tmp_path)
    for _ in range(3):
        cache.get(a)
        cache.get(b)
    # one compile each; every revisit rehydrated from disk, no recompile
    ea = cache.entries[pattern_fingerprint(a)]
    eb = cache.entries[pattern_fingerprint(b)]
    assert (ea.compiles, eb.compiles) == (1, 1)
    assert (ea.disk_hits, eb.disk_hits) == (2, 2)


def test_disk_rehydrate_equals_in_memory_program(tmp_path):
    a = generate("band_cz")
    cache = ProgramCache(capacity=1, disk_dir=tmp_path)
    pa = cache.get(a)
    cache.get(generate("chem_bp"))  # evict a
    ra = cache.get(a)               # rehydrated from disk
    assert ra is not pa
    assert ra.n == pa.n and ra.num_slots == pa.num_slots
    assert ra.config == pa.config
    np.testing.assert_array_equal(ra.instr, pa.instr)
    np.testing.assert_array_equal(ra.val_idx, pa.val_idx)
    np.testing.assert_array_equal(ra.stream, pa.stream)
    rng = np.random.default_rng(9)
    bb = rng.standard_normal(a.n)
    np.testing.assert_array_equal(np.asarray(api.solve(ra, bb)),
                                  np.asarray(api.solve(pa, bb)))


def test_corrupt_disk_entry_degrades_to_recompile_with_incident(tmp_path):
    a = generate("band_cz")
    cache = ProgramCache(capacity=1, disk_dir=tmp_path)
    cache.get(a)
    blobs = list(tmp_path.glob("*.prog"))
    assert len(blobs) == 1
    raw = bytearray(blobs[0].read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    blobs[0].write_bytes(bytes(raw))
    cache.get(generate("chem_bp"))  # evict a from memory
    prog = cache.get(a)             # corrupt blob -> incident + recompile
    ent = cache.entries[pattern_fingerprint(a)]
    assert ent.disk_corrupt == 1 and ent.compiles == 2
    inc = cache.incidents[-1]
    assert inc.stage == "program-cache" and inc.kind == "disk-corrupt"
    assert inc.error == "ProgramCorruptionError"
    b = np.random.default_rng(10).standard_normal(a.n)
    np.testing.assert_allclose(np.asarray(api.solve(prog, b)),
                               api.reference_solve(a, b),
                               rtol=1e-4, atol=1e-4)
    # the rewritten blob is healthy again
    assert cache.get(generate("chem_bp")) is not None
    assert cache.get(a) is not prog
    assert ent.disk_corrupt == 1  # no further corruption events


def test_same_pattern_new_values_is_a_values_refresh(tmp_path):
    import dataclasses

    a = generate("band_cz")
    a2 = dataclasses.replace(a, values=a.values * 1.5)
    cache = ProgramCache(capacity=2, disk_dir=tmp_path)
    p1 = cache.get(a)
    p2 = cache.get(a2)  # same fingerprint, different values CRC
    assert p1 is not p2  # new identity: executors cache on identity
    fp = pattern_fingerprint(a)
    # guarded miss served by the values-only fast path: one compiler run,
    # the second program regathered through the provenance plane
    assert cache.entries[fp].compiles == 1
    assert cache.entries[fp].value_refreshes == 1
    assert cache.misses == 2 and cache.value_refreshes == 1
    # schedule tensors shared, value stream fresh
    assert p2.instr is p1.instr and p2.stream is not p1.stream
    assert len(list(tmp_path.glob(f"{fp}.*.prog"))) == 2  # distinct blobs
    b = np.random.default_rng(11).standard_normal(a.n)
    np.testing.assert_allclose(np.asarray(api.solve(p2, b)),
                               api.reference_solve(a2, b),
                               rtol=1e-4, atol=1e-4)
    # the refreshed stream is bit-identical to a full recompile's
    from repro.core.schedule import compile_program

    np.testing.assert_array_equal(p2.stream, compile_program(a2).stream)


def test_values_refresh_disk_blob_rehydrates(tmp_path):
    import dataclasses

    a = generate("band_cz")
    a2 = dataclasses.replace(a, values=a.values * 2.0)
    cache = ProgramCache(capacity=2, disk_dir=tmp_path)
    cache.get(a)
    cache.get(a2)
    # a fresh cache finds both blobs on disk: zero compiles, zero refreshes
    cold = ProgramCache(capacity=2, disk_dir=tmp_path)
    cold.get(a2)
    fp = pattern_fingerprint(a)
    assert cold.entries[fp].compiles == 0
    assert cold.entries[fp].disk_hits == 1


def test_cache_rejects_zero_capacity():
    with pytest.raises(ValueError, match="capacity"):
        ProgramCache(capacity=0)


def test_load_program_corruption_error_type(tmp_path):
    path = tmp_path / "junk.prog"
    path.write_bytes(b"not a program")
    with pytest.raises(ProgramCorruptionError):
        api.load_program(path)


# ------------------------------------------------------- api.make_service
def test_make_service_defaults_and_disk_tier(tmp_path, mats):
    clock = ManualClock()
    svc = api.make_service(mats, capacity=1, disk_dir=tmp_path,
                           max_batch=2, max_delay=0.5, clock=clock)
    rng = np.random.default_rng(12)
    ta = svc.submit("a", rng.standard_normal((mats["a"].n, 2)))
    tb = svc.submit("b", rng.standard_normal((mats["b"].n, 2)))
    assert ta.done and tb.done
    # capacity-1 cache spilled "a" to disk; next "a" flush rehydrates
    tc = svc.submit("a", rng.standard_normal(mats["a"].n))
    clock.advance(0.5)
    svc.pump()
    assert tc.done
    fp = pattern_fingerprint(mats["a"])
    assert svc.cache.entries[fp].disk_hits == 1
    assert svc.cache.entries[fp].compiles == 1


# ------------------------------------------------- bench smoke + schema
def test_serve_load_smoke(capsys):
    from benchmarks.serve_load import main

    main(["--smoke"])
    out = capsys.readouterr().out
    assert "smoke" in out


def test_bench_serve_json_schema():
    from scripts.check_bench import check

    problems = check()
    assert problems == [], "\n".join(problems)
