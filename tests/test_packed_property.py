"""Property-based tests (hypothesis) on the packed instruction encoding.

Complements `tests/test_packed.py` (which always runs): for ANY in-range
field arrays, pack -> decode must be the identity in both plane regimes,
and compiled programs must roundtrip bit-exactly.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.program import (  # noqa: E402
    SRC_BITS,
    decode_instructions,
    pack_instructions,
)


@st.composite
def packed_fields(draw):
    planes = draw(st.sampled_from([1, 2]))
    t = draw(st.integers(min_value=1, max_value=8))
    p = draw(st.integers(min_value=1, max_value=16))
    shape = (t, p)
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    src_hi = (1 << SRC_BITS) - 1 if planes == 1 else np.iinfo(np.int32).max
    return planes, (
        rng.integers(0, 4, shape),
        rng.integers(0, int(src_hi) + 1, shape),
        rng.integers(0, 8, shape),
        rng.integers(0, 256, shape),
    )


@settings(max_examples=80, deadline=None)
@given(packed_fields())
def test_pack_decode_roundtrip(case):
    planes, (op, src, ctl, slot) = case
    words = pack_instructions(op, src, ctl, slot, planes=planes)
    assert words.dtype == np.int32 and words.shape[1] == planes
    op2, src2, ctl2, slot2 = decode_instructions(words, planes)
    np.testing.assert_array_equal(op2, op)
    np.testing.assert_array_equal(src2, src)
    np.testing.assert_array_equal(ctl2, ctl)
    np.testing.assert_array_equal(slot2, slot)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 60), st.integers(0, 2**31 - 1))
def test_random_program_repacks_bit_exactly(n, seed):
    """decode -> re-pack over a real compiled program is the identity."""
    from repro.core.csr import from_coo
    from repro.core.schedule import compile_program

    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for i in range(1, n):
        m = rng.random(i) < 0.3
        for j in np.nonzero(m)[0]:
            rows.append(i)
            cols.append(int(j))
    vals = rng.uniform(-1, 1, len(rows))
    diag = rng.uniform(1.0, 2.0, n)
    mat = from_coo(n, rows, cols, vals, diag, name=f"hyp_pack_{seed}")
    prog = compile_program(mat)
    fields = decode_instructions(prog.instr, prog.planes)
    np.testing.assert_array_equal(
        pack_instructions(*fields, planes=prog.planes), prog.instr)
