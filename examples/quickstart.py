"""Quickstart: solve a sparse triangular system on the modeled accelerator.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end to end: generate a benchmark matrix,
compile it with the medium-granularity dataflow (paper §IV), execute the
VLIW program with the JAX executor AND the Pallas kernel, and print the
paper's metrics.
"""

import numpy as np

from repro.core import api
from repro.core.csr import random_rhs, serial_solve
from repro.kernels.sptrsv import ops as sptrsv_kernel


def main() -> None:
    # 1. a circuit-simulation-style benchmark matrix (add20 archetype)
    mat = api.matrix("ckt_add20")
    print(f"matrix {mat.name}: n={mat.n} nnz={mat.nnz} "
          f"flops={mat.binary_nodes}")

    # 2. compile: medium granularity dataflow + psum caching + ICR
    prog = api.compile(mat)
    print("compiled:", {k: v for k, v in api.report(prog).items()
                        if k in ("cycles", "throughput_gops", "peak_gops",
                                 "pe_utilization", "compile_s")})

    # 3. solve Lx = b three ways and check against the serial oracle
    b = random_rhs(mat, seed=42)
    x_ref = serial_solve(mat, b)
    x_jax = api.solve(prog, b)                      # lax.scan executor
    x_pal = sptrsv_kernel.solve(prog, b)            # Pallas kernel
    print("jax executor   max err:", float(np.abs(x_jax - x_ref).max()))
    print("pallas kernel  max err:", float(np.abs(x_pal - x_ref).max()))

    # 4. batched multi-RHS: one instruction-stream pass solves all columns
    B = 8
    rng = np.random.default_rng(0)
    bmat = rng.standard_normal((mat.n, B))
    x_bat = api.solve_batch(prog, bmat)             # [n, B] in one pass
    refs = np.stack([serial_solve(mat, bmat[:, i]) for i in range(B)], axis=1)
    print(f"batched (B={B})  max err:", float(np.abs(x_bat - refs).max()))
    solver = api.make_solver(prog, batch=B)         # cached: later calls
    x_bat2 = np.asarray(solver(bmat))               # reuse the same trace
    assert np.allclose(x_bat, x_bat2)

    # 5. multi-device: shard the RHS columns over every local device
    #    (run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to
    #    see it spread over 8 fake CPU devices; on TPU it just works)
    from repro.core import shard
    mesh = shard.batch_mesh()                       # 1-D mesh, all devices
    x_sh = api.solve_batch(prog, bmat, mesh=mesh)   # columns over devices
    print(f"sharded over {mesh.size} device(s) max err:",
          float(np.abs(x_sh - refs).max()))

    # 6. large n: past a VMEM footprint threshold the Pallas kernel keeps
    #    x and b in HBM and slides a row-blocked VMEM window over them
    #    (flush/refill at cycle-block boundaries, DESIGN.md §1).  Forced
    #    here on a small band so it runs quickly; on `band_big16k` and up
    #    placement="auto" picks it by itself.
    band = api.matrix("band_cz")
    bprog = api.compile(band)
    solver_big = api.make_solver(bprog, batch=B, backend="pallas",
                                 placement="blocked")
    print(f"row-blocked solve: window={solver_big.plan.window} rows "
          f"(of n={band.n}), stride={solver_big.plan.stride}, "
          f"{solver_big.plan.num_blocks} cycle blocks")
    bb = rng.standard_normal((band.n, B))
    x_blk = np.asarray(solver_big(bb))
    refs_b = np.stack([serial_solve(band, bb[:, i]) for i in range(B)], axis=1)
    print("row-blocked      max err:", float(np.abs(x_blk - refs_b).max()))

    # 7. compare the three dataflows of the paper (Fig. 6 / Fig. 9a)
    coarse = api.baseline_coarse(mat).stats
    fine = api.baseline_fine(mat)
    print(f"cycles: coarse={coarse.cycles} fine={fine.effective_cycles:.0f} "
          f"medium={prog.stats.cycles}  (lower is better)")


if __name__ == "__main__":
    main()
