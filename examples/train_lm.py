"""End-to-end training driver: a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py                # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --tiny         # CI-sized

Uses the full production stack: config registry, synthetic data pipeline,
AdamW + cosine schedule + clipping, async checkpointing, local mesh.
Training loss on the synthetic Markov corpus should drop from ~ln(vocab)
toward ~ln(branch)=1.39.
"""

import argparse
import dataclasses
import sys

from repro.configs import get_config
from repro.launch.train import main as train_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.tiny:
        steps = args.steps or 30
        argv = ["--arch", "smollm-360m", "--reduced", "--steps", str(steps),
                "--batch", "4", "--seq", "128", "--lr", "3e-3",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "10"]
    else:
        # ~110M params (see repro/configs/lm_100m.py)
        steps = args.steps or 300
        argv = ["--arch", "lm-100m", "--steps", str(steps),
                "--batch", "4", "--seq", "256", "--lr", "1e-3",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
                "--log-every", "10"]
    result = train_main(argv)
    assert result["last_loss"] < result["first_loss"], "loss must decrease"
    print("OK: loss decreased", result)


if __name__ == "__main__":
    main()
