"""The paper's dataflow taxonomy on a sequence model (DESIGN.md §1).

    PYTHONPATH=src python examples/ssm_as_sptrsv.py

A linear SSM recurrence h_t = a_t h_{t-1} + u_t IS a bidiagonal SpTRSV.
This example shows the equivalence numerically (SpTRSV solver == SSM scan
on the same system), then runs the three execution granularities of the
recurrence and times them on this host:

    coarse = sequential lax.scan         (one step at a time)
    fine   = parallel prefix (assoc.) scan (2x ops, log depth)
    medium = chunked kernel (repro.kernels.ssd_scan) — the paper's
             coarse-allocation / fine-computation idea
"""

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.csr import from_coo, serial_solve
from repro.kernels.ssd_scan import ops as ssd


def main() -> None:
    n = 512
    rng = np.random.default_rng(0)
    a = rng.uniform(0.5, 0.99, n - 1)          # decay
    u = rng.standard_normal(n)                 # input

    # --- equivalence: (I - sub-diag(a)) h = u  <=>  h_t = a_t h_{t-1} + u_t
    mat = from_coo(n, range(1, n), range(0, n - 1), -a, np.ones(n), "ssm")
    h_sptrsv = serial_solve(mat, u)
    h_scan = np.zeros(n)
    h_scan[0] = u[0]
    for t in range(1, n):
        h_scan[t] = a[t - 1] * h_scan[t - 1] + u[t]
    print("SpTRSV == SSM scan:", np.allclose(h_sptrsv, h_scan))

    # --- batched multi-RHS: many input sequences through the same L in one
    # pass of the compiled VLIW stream (api.solve_batch), exactly how a
    # batch of SSM channels shares the recurrence weights
    from repro.core import api

    n_rhs = 8
    prog = api.compile(mat)
    U = rng.standard_normal((n, n_rhs))
    H_bat = api.solve_batch(prog, U)               # [n, n_rhs], one stream pass
    H_ref = np.stack([serial_solve(mat, U[:, i]) for i in range(n_rhs)], axis=1)
    print(f"batched SpTRSV (B={n_rhs}) == per-column scans:",
          np.allclose(H_bat, H_ref, rtol=1e-4, atol=1e-4))

    # --- the three granularities on a batched multi-head recurrence
    B, L, H, K, V = 4, 4096, 8, 32, 32
    q = jnp.asarray(rng.standard_normal((B, L, H, K)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, L, H, K)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((B, L, H, V)), jnp.float32)
    w = jnp.asarray(-rng.uniform(0.0, 0.2, (B, L, H, K)), jnp.float32)

    def unmerge(y):
        return y.reshape(B, H, L, V).transpose(0, 2, 1, 3)

    def coarse():
        from repro.kernels.ssd_scan.ref import scan_ref
        merge = lambda x, d: x.transpose(0, 2, 1, 3).reshape(B * H, L, d)
        y, _ = scan_ref(merge(q, K), merge(k, K), merge(v, V), merge(w, K),
                        jnp.zeros((B * H, K, V)))
        return unmerge(y)

    def medium():
        y, _ = ssd.linear_recurrence(q, k, v, w, chunk=64)
        return y

    def fine():
        # associative scan over (decay-matrix, state) pairs — 2x work
        merge = lambda x, d: x.transpose(0, 2, 1, 3).reshape(B * H, L, d)
        km, vm, wm = merge(k, K), merge(v, V), merge(w, K)
        kv = jnp.einsum("blk,blv->blkv", km, vm)
        d = jnp.exp(wm)[..., None]  # [BH, L, K, 1]

        def combine(x, y):
            dx, sx = x
            dy, sy = y
            return dx * dy, sy + dy * sx

        _, s = jax.lax.associative_scan(combine, (d, kv), axis=1)
        return unmerge(jnp.einsum("blk,blkv->blv", merge(q, K), s))

    ys = {}
    for name, fn in [("coarse", coarse), ("medium", medium), ("fine", fine)]:
        fn_j = jax.jit(fn)
        y = fn_j(); jax.block_until_ready(y)       # compile + warm
        t0 = time.perf_counter()
        for _ in range(3):
            y = fn_j()
        jax.block_until_ready(y)
        dt = (time.perf_counter() - t0) / 3
        ys[name] = np.asarray(y)
        print(f"{name:7s} {dt*1e3:8.1f} ms/call")
    print("medium == coarse:",
          np.allclose(ys["medium"], ys["coarse"], rtol=2e-3, atol=2e-3))
    print("fine   == coarse:",
          np.allclose(ys["fine"], ys["coarse"], rtol=2e-3, atol=2e-3))


if __name__ == "__main__":
    main()
