"""Batched serving example: prefill + decode with KV cache slot reuse.

    PYTHONPATH=src python examples/serve_batch.py [--arch granite-moe-1b-a400m]

Drives `repro.launch.serve` for a reduced-config model: 8 concurrent
requests, batched prefill, 32 decode steps, throughput report.
"""

import argparse

from repro.launch.serve import main as serve_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    args = ap.parse_args()
    result = serve_main([
        "--arch", args.arch, "--reduced", "--requests", "8",
        "--prefill-len", "64", "--decode-steps", "32",
    ])
    assert result["decode_tokens_per_s"] > 0
    print("OK")


if __name__ == "__main__":
    main()
