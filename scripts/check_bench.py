#!/usr/bin/env python
"""Bench-trajectory schema check: the perf history stays machine-readable.

The repo keeps three perf *trajectory* files — ``BENCH_serve.json``
(appended by ``benchmarks/serve_load.py --record``),
``BENCH_serve_chaos.json`` (appended by ``benchmarks/serve_chaos.py
--record``) and ``BENCH_schedule.json`` (appended by
``benchmarks/schedule_frontier.py --record``) — so re-anchors can read a
curve instead of a single CSV snapshot.  A trajectory is only useful if
every entry still parses years later, so this check pins the schemas:
top-level envelope, per-entry metadata, and the per-row fields with
their types.  Runs standalone (``python scripts/check_bench.py``) and as
tier-1 tests (`tests/test_serve.py`, `tests/test_resilience.py`,
`tests/test_strategies.py`).
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BENCH_JSON = REPO / "BENCH_serve.json"
CHAOS_JSON = REPO / "BENCH_serve_chaos.json"
SCHEDULE_JSON = REPO / "BENCH_schedule.json"

SCHEMA = "sptrsv-bench-serve"
VERSION = 1
CHAOS_SCHEMA = "sptrsv-bench-serve-chaos"
CHAOS_VERSION = 1
SCHEDULE_SCHEMA = "sptrsv-bench-schedule"
SCHEDULE_VERSION = 1

# required per-row fields -> accepted types
ROW_FIELDS = {
    "name": str,
    "n": int,
    "requests": int,
    "offered_batch": int,
    "batched_solves_per_s": (int, float),
    "sequential_solves_per_s": (int, float),
    "speedup": (int, float),
    "p50_ms": (int, float),
    "p99_ms": (int, float),
}
ENTRY_FIELDS = {
    "recorded": str,   # ISO date, checked below
    "label": str,
    "host": str,
    "offered_batch": int,
    "rows": list,
}

CHAOS_ROW_FIELDS = {
    "fault": str,
    "requests": int,
    "goodput": (int, float),
    "completed": int,
    "failed_typed": int,
    "shed": int,
    "silent_wrong": int,
    "p50_virtual_ms": (int, float),
    "p99_virtual_ms": (int, float),
    "retries": int,
    "degraded_flushes": int,
    "incidents": int,
}
CHAOS_ENTRY_FIELDS = {
    "recorded": str,
    "label": str,
    "host": str,
    "seed": int,
    "overhead_pct": (int, float),
    "rows": list,
}

# scheduling-strategy frontier (benchmarks/schedule_frontier.py): one
# cycles/stalls/spills triple per registered strategy, plus auto's pick
_STRATEGY_NAMES = ("paper", "level", "locality", "cpath", "eager")
SCHEDULE_ROW_FIELDS = {
    "name": str,
    "n": int,
    "nnz": int,
    "auto_pick": str,
    "auto_cycles": int,
    "auto_win": int,
    **{f"{s}_{m}": int for s in _STRATEGY_NAMES
       for m in ("cycles", "stalls", "spills")},
}
SCHEDULE_ENTRY_FIELDS = {
    "recorded": str,
    "label": str,
    "host": str,
    "wins": int,
    "rows": list,
}


def _check_file(path: Path, schema: str, version: int, entry_fields: dict,
                row_fields: dict, creator: str) -> list[str]:
    """Validate one trajectory file; returns human-readable problems."""
    if not path.exists():
        return [f"{path.name} missing (run {creator} --record to create it)"]
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"{path.name}: not valid JSON ({e})"]
    problems: list[str] = []
    if doc.get("schema") != schema:
        problems.append(f"{path.name}: schema must be {schema!r}, "
                        f"got {doc.get('schema')!r}")
    if doc.get("version") != version:
        problems.append(f"{path.name}: version must be {version}, "
                        f"got {doc.get('version')!r}")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        return problems + [f"{path.name}: entries must be a non-empty list"]
    for i, entry in enumerate(entries):
        where = f"{path.name}:entries[{i}]"
        for field, typ in entry_fields.items():
            if not isinstance(entry.get(field), typ):
                problems.append(f"{where}.{field}: expected {typ}, "
                                f"got {entry.get(field)!r}")
        rec = entry.get("recorded", "")
        if isinstance(rec, str) and (len(rec) != 10 or rec[4] != "-"
                                     or rec[7] != "-"):
            problems.append(f"{where}.recorded: expected YYYY-MM-DD, "
                            f"got {rec!r}")
        rows = entry.get("rows") or []
        if isinstance(rows, list) and not rows:
            problems.append(f"{where}.rows: empty")
        for j, row in enumerate(rows if isinstance(rows, list) else []):
            for field, typ in row_fields.items():
                if not isinstance(row.get(field), typ) or \
                        isinstance(row.get(field), bool):
                    problems.append(
                        f"{where}.rows[{j}].{field}: expected {typ}, "
                        f"got {row.get(field)!r}")
    return problems


def check(path: Path = BENCH_JSON) -> list[str]:
    """Validate the serve-load trajectory (empty == clean)."""
    return _check_file(path, SCHEMA, VERSION, ENTRY_FIELDS, ROW_FIELDS,
                       "benchmarks/serve_load.py")


def check_chaos(path: Path = CHAOS_JSON) -> list[str]:
    """Validate the serve-chaos trajectory (empty == clean)."""
    return _check_file(path, CHAOS_SCHEMA, CHAOS_VERSION, CHAOS_ENTRY_FIELDS,
                       CHAOS_ROW_FIELDS, "benchmarks/serve_chaos.py")


def check_schedule(path: Path = SCHEDULE_JSON) -> list[str]:
    """Validate the schedule-frontier trajectory (empty == clean)."""
    problems = _check_file(path, SCHEDULE_SCHEMA, SCHEDULE_VERSION,
                           SCHEDULE_ENTRY_FIELDS, SCHEDULE_ROW_FIELDS,
                           "benchmarks/schedule_frontier.py")
    if problems:
        return problems
    # the frontier invariant the trajectory exists to witness: auto is
    # never worse than the paper baseline, and each win is strict
    doc = json.loads(path.read_text())
    for i, entry in enumerate(doc["entries"]):
        for j, row in enumerate(entry["rows"]):
            where = f"{path.name}:entries[{i}].rows[{j}]"
            if row["auto_cycles"] > row["paper_cycles"]:
                problems.append(f"{where}: auto_cycles "
                                f"{row['auto_cycles']} worse than paper "
                                f"{row['paper_cycles']}")
            if row["auto_win"] != int(row["auto_cycles"]
                                      < row["paper_cycles"]):
                problems.append(f"{where}: auto_win flag inconsistent "
                                f"with the cycle counts")
    return problems


def main() -> int:
    problems = check() + check_chaos() + check_schedule()
    for p in problems:
        print(f"check_bench: {p}", file=sys.stderr)
    if problems:
        print(f"check_bench: {len(problems)} schema problem(s)",
              file=sys.stderr)
        return 1
    for path in (BENCH_JSON, CHAOS_JSON, SCHEDULE_JSON):
        doc = json.loads(path.read_text())
        n_rows = sum(len(e["rows"]) for e in doc["entries"])
        print(f"check_bench: {path.name} OK ({len(doc['entries'])} "
              f"trajectory entr{'y' if len(doc['entries']) == 1 else 'ies'}, "
              f"{n_rows} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
