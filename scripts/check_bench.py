#!/usr/bin/env python
"""BENCH_serve.json schema check: the perf trajectory stays machine-readable.

``BENCH_serve.json`` is the repo's perf *trajectory* — every
``benchmarks/serve_load.py --record`` run appends a dated entry, so
re-anchors can read a curve instead of a single CSV snapshot.  A
trajectory is only useful if every entry still parses years later, so
this check pins the schema: top-level envelope, per-entry metadata, and
the per-matrix row fields with their types.  Runs standalone
(``python scripts/check_bench.py``) and as a tier-1 test
(`tests/test_serve.py`).
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BENCH_JSON = REPO / "BENCH_serve.json"

SCHEMA = "sptrsv-bench-serve"
VERSION = 1

# required per-row fields -> accepted types
ROW_FIELDS = {
    "name": str,
    "n": int,
    "requests": int,
    "offered_batch": int,
    "batched_solves_per_s": (int, float),
    "sequential_solves_per_s": (int, float),
    "speedup": (int, float),
    "p50_ms": (int, float),
    "p99_ms": (int, float),
}
ENTRY_FIELDS = {
    "recorded": str,   # ISO date, checked below
    "label": str,
    "host": str,
    "offered_batch": int,
    "rows": list,
}


def check(path: Path = BENCH_JSON) -> list[str]:
    """Return a list of human-readable problems (empty == clean)."""
    if not path.exists():
        return [f"{path.name} missing (run benchmarks/serve_load.py "
                f"--record to create it)"]
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"{path.name}: not valid JSON ({e})"]
    problems: list[str] = []
    if doc.get("schema") != SCHEMA:
        problems.append(f"schema must be {SCHEMA!r}, got {doc.get('schema')!r}")
    if doc.get("version") != VERSION:
        problems.append(f"version must be {VERSION}, got {doc.get('version')!r}")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        return problems + ["entries must be a non-empty list"]
    for i, entry in enumerate(entries):
        where = f"entries[{i}]"
        for field, typ in ENTRY_FIELDS.items():
            if not isinstance(entry.get(field), typ):
                problems.append(f"{where}.{field}: expected {typ}, "
                                f"got {entry.get(field)!r}")
        rec = entry.get("recorded", "")
        if isinstance(rec, str) and (len(rec) != 10 or rec[4] != "-"
                                     or rec[7] != "-"):
            problems.append(f"{where}.recorded: expected YYYY-MM-DD, "
                            f"got {rec!r}")
        rows = entry.get("rows") or []
        if isinstance(rows, list) and not rows:
            problems.append(f"{where}.rows: empty")
        for j, row in enumerate(rows if isinstance(rows, list) else []):
            for field, typ in ROW_FIELDS.items():
                if not isinstance(row.get(field), typ) or \
                        isinstance(row.get(field), bool):
                    problems.append(
                        f"{where}.rows[{j}].{field}: expected {typ}, "
                        f"got {row.get(field)!r}")
    return problems


def main() -> int:
    problems = check()
    for p in problems:
        print(f"check_bench: {p}", file=sys.stderr)
    if problems:
        print(f"check_bench: {len(problems)} schema problem(s)",
              file=sys.stderr)
        return 1
    doc = json.loads(BENCH_JSON.read_text())
    n_rows = sum(len(e["rows"]) for e in doc["entries"])
    print(f"check_bench: OK ({len(doc['entries'])} trajectory entr"
          f"{'y' if len(doc['entries']) == 1 else 'ies'}, {n_rows} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
