#!/usr/bin/env python
"""CLI over the static analyzer: lint compiled programs (DESIGN.md §8).

Analyzes one or more compiled `Program`s — loaded from the checksummed
on-disk format or compiled on the fly from named suite matrices — with
the full hazard detector plus performance linter and renders the
`AnalysisReport`s as text (default) or JSON (``--json``).

    python scripts/lint_program.py ckt.prog other.prog
    python scripts/lint_program.py --matrix ckt_rajat04 --matrix band_cz
    python scripts/lint_program.py --suite --max-n 3000 --json
    python scripts/lint_program.py --matrix hub_mid --verify-ir
    python scripts/lint_program.py --matrix ckt_add20 --schedule paper \
        --frontier   # SPT208 when a better strategy exists

``--schedule`` compiles ``--matrix``/``--suite`` entries with a specific
scheduler strategy (or ``auto``); ``--frontier`` additionally computes
every strategy's predicted cost for the matrix and attaches it to the
program's stats, arming the SPT208 "cycles left on the table" lint for
non-auto compiles (DESIGN.md §11).

Exit status is 1 when any report carries an error-severity diagnostic
(warn/info lints alone exit 0), so the CLI slots into CI gates.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import api, matrices  # noqa: E402
from repro.core.analysis import LintConfig, analyze_program  # noqa: E402


def _reports(args):
    lc = LintConfig(cycles_per_block=args.cycles_per_block)
    names = list(args.matrix)
    if args.suite:
        names += matrices.suite_names(max_n=args.max_n)
    for path in args.programs:
        prog = api.load_program(path, verify=False)
        yield analyze_program(prog, lint=not args.no_lint, lint_cfg=lc)
    for name in names:
        mat = matrices.generate(name)
        prog = api.compile(mat, schedule=args.schedule,
                           verify_ir=args.verify_ir)
        if args.frontier and prog.stats.schedule_costs is None:
            from repro.core.compiler import strategies
            from repro.core.frontends.sptrsv import lower_tri

            prog.stats.schedule_costs = strategies.frontier_costs(
                lower_tri(mat), prog.config)
        yield analyze_program(prog, lint=not args.no_lint, lint_cfg=lc)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("programs", nargs="*", type=Path,
                    help="serialized program files (api.save_program)")
    ap.add_argument("--matrix", action="append", default=[],
                    help="suite matrix name to compile and lint "
                         "(repeatable)")
    ap.add_argument("--suite", action="store_true",
                    help="lint every suite matrix up to --max-n rows")
    ap.add_argument("--max-n", type=int, default=3000,
                    help="row cap for --suite (default 3000)")
    ap.add_argument("--verify-ir", action="store_true",
                    help="also run the per-pass IR contract verifiers "
                         "while compiling --matrix/--suite entries")
    ap.add_argument("--schedule", default="paper",
                    help="scheduler strategy for --matrix/--suite "
                         "compiles: a strategies.STRATEGIES name or "
                         "'auto' (default paper)")
    ap.add_argument("--frontier", action="store_true",
                    help="compute every strategy's predicted cost and "
                         "attach it to stats.schedule_costs, arming the "
                         "SPT208 frontier lint for non-auto compiles")
    ap.add_argument("--no-lint", action="store_true",
                    help="hazard/contract diagnostics only, skip the "
                         "SPT2xx performance lints")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit one JSON document instead of text")
    ap.add_argument("--cycles-per-block", type=int, default=128,
                    help="block granularity for the SPT205 placement "
                         "feasibility lint (default 128)")
    args = ap.parse_args(argv)
    if not args.programs and not args.matrix and not args.suite:
        ap.error("nothing to lint: pass program files, --matrix, or "
                 "--suite")

    reports = list(_reports(args))
    if args.as_json:
        print(json.dumps([r.to_dict() for r in reports], indent=2))
    else:
        print("\n\n".join(r.render() for r in reports))
    return 1 if any(not r.ok() for r in reports) else 0


if __name__ == "__main__":
    raise SystemExit(main())
