#!/usr/bin/env python
"""Docs cross-reference check: source citations must resolve to real text.

Source files cite design documentation by section anchor (``DESIGN.md``
followed by one or more ``§``-tokens, e.g. ``§5`` or ``§1/§3``) and point
readers at ``README.md`` / ``docs/benchmarks.md``. This check fails when

  * a cited section anchor has no matching ``## §... — ...`` heading,
  * a cited markdown file (DESIGN.md, README.md, docs/*.md) is missing,

so the documentation cannot silently rot out from under the code. Runs
standalone (``python scripts/check_docs.py``) and as a tier-1 test
(`tests/test_docs.py`).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
# directories whose sources may cite the docs
SOURCE_DIRS = ("src", "tests", "benchmarks", "examples", "scripts")
# markdown files sources are allowed to point at, by bare name
DOC_FILES = {
    "DESIGN.md": REPO / "DESIGN.md",
    "README.md": REPO / "README.md",
    "benchmarks.md": REPO / "docs" / "benchmarks.md",
    # perf-trajectory files sources/docs point at (benchmarks/serve_load.py
    # / serve_chaos.py / schedule_frontier.py --record append entries;
    # schemas pinned by scripts/check_bench.py)
    "BENCH_serve.json": REPO / "BENCH_serve.json",
    "BENCH_serve_chaos.json": REPO / "BENCH_serve_chaos.json",
    "BENCH_schedule.json": REPO / "BENCH_schedule.json",
}

# "DESIGN.md §1", "DESIGN.md §1/§3", "DESIGN.md §Perf head-folding"
_REF_RE = re.compile(r"DESIGN\.md\s+((?:§[A-Za-z0-9]+)(?:/§[A-Za-z0-9]+)*)")
_HEAD_RE = re.compile(r"^#{1,6}\s+§([A-Za-z0-9]+)\b", re.MULTILINE)
_FILE_RE = re.compile(
    r"\b(DESIGN\.md|README\.md|benchmarks\.md|BENCH_serve_chaos\.json"
    r"|BENCH_serve\.json|BENCH_schedule\.json)\b")


def design_headings() -> set[str]:
    path = DOC_FILES["DESIGN.md"]
    if not path.exists():
        return set()
    return set(_HEAD_RE.findall(path.read_text()))


def iter_sources():
    for d in SOURCE_DIRS:
        root = REPO / d
        if root.is_dir():
            yield from sorted(root.rglob("*.py"))


def check() -> list[str]:
    """Return a list of human-readable problems (empty == clean)."""
    problems: list[str] = []
    headings = design_headings()
    if not headings:
        problems.append("DESIGN.md missing or has no '## §X' headings")
    for path in iter_sources():
        rel = path.relative_to(REPO)
        text = path.read_text()
        for m in _FILE_RE.finditer(text):
            if not DOC_FILES[m.group(1)].exists():
                problems.append(f"{rel}: cites {m.group(1)}, file missing")
                break  # one report per file per missing doc is enough
        for m in _REF_RE.finditer(text):
            for sec in m.group(1).replace("/", " ").split():
                tok = sec.lstrip("§")
                if tok not in headings:
                    line = text.count("\n", 0, m.start()) + 1
                    problems.append(
                        f"{rel}:{line}: cites DESIGN.md §{tok}, no such "
                        f"heading (have: {', '.join(sorted(headings))})"
                    )
    return problems


def main() -> int:
    problems = check()
    for p in problems:
        print(f"check_docs: {p}", file=sys.stderr)
    if problems:
        print(f"check_docs: {len(problems)} stale cross-reference(s)",
              file=sys.stderr)
        return 1
    n_refs = sum(len(_REF_RE.findall(p.read_text())) for p in iter_sources())
    print(f"check_docs: OK ({n_refs} DESIGN.md section references resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
