#!/usr/bin/env python
"""Source lint guard for the core library (tier-1 via tests/test_lint.py).

Enforces the rule subset pinned in ``pyproject.toml`` ([tool.ruff]):
F401 unused imports, E501 lines over 100 columns, W291/W293 trailing
whitespace, E722 bare except.  Prefers a real ``ruff`` binary when the
environment has one (same config file); otherwise falls back to a
self-contained AST/line checker implementing the same subset, so the
guard runs in the hermetic container without installing anything.

``# noqa`` suppressions work in both modes: a bare ``# noqa`` silences
the whole line, ``# noqa: F401`` only the listed codes.  Names exported
via ``__all__`` count as used; ``from __future__ import ...`` is exempt
from F401 by definition.

    python scripts/check_lint.py            # lint the default roots
    python scripts/check_lint.py src/foo.py # lint specific files
"""

from __future__ import annotations

import ast
import re
import shutil
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_ROOTS = ("src/repro/core", "scripts")
MAX_LINE = 100
RULES = ("F401", "E501", "W291", "W293", "E722")

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9, ]+))?", re.I)


def _suppressed(line: str, code: str) -> bool:
    m = _NOQA_RE.search(line)
    if not m:
        return False
    codes = m.group("codes")
    if not codes:
        return True  # bare noqa
    return code in {c.strip().upper() for c in codes.split(",")}


def _exported_names(tree: ast.Module) -> set[str]:
    out: set[str] = set()
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        if not any(isinstance(t, ast.Name) and t.id == "__all__"
                   for t in targets):
            continue
        if isinstance(node.value, (ast.List, ast.Tuple)):
            out |= {elt.value for elt in node.value.elts
                    if isinstance(elt, ast.Constant)
                    and isinstance(elt.value, str)}
    return out


def _lint_file(path: Path) -> list[str]:
    text = path.read_text()
    lines = text.splitlines()
    problems: list[str] = []

    def report(lineno: int, code: str, msg: str) -> None:
        line = lines[lineno - 1] if 0 < lineno <= len(lines) else ""
        if not _suppressed(line, code):
            problems.append(f"{path.relative_to(REPO)}:{lineno}: "
                            f"{code} {msg}")

    for i, line in enumerate(lines, 1):
        if len(line) > MAX_LINE:
            report(i, "E501", f"line too long ({len(line)} > {MAX_LINE})")
        stripped = line.rstrip()
        if stripped != line:
            report(i, "W293" if not stripped else "W291",
                   "whitespace on blank line" if not stripped
                   else "trailing whitespace")

    try:
        tree = ast.parse(text, filename=str(path))
    except SyntaxError as e:
        problems.append(f"{path.relative_to(REPO)}:{e.lineno}: "
                        f"E999 syntax error: {e.msg}")
        return problems

    used = {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}
    used |= _exported_names(tree)
    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler) and node.type is None:
            report(node.lineno, "E722", "bare except")
        elif isinstance(node, ast.Import):
            for alias in node.names:
                bound = alias.asname or alias.name.split(".")[0]
                if bound not in used:
                    report(node.lineno, "F401",
                           f"unused import {alias.name!r}")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for alias in node.names:
                if alias.name == "*":
                    continue
                bound = alias.asname or alias.name
                if bound not in used:
                    report(node.lineno, "F401",
                           f"unused import {alias.name!r}")
    return problems


def _try_ruff(paths: list[Path]) -> int | None:
    """Run a real ruff when available; None when the binary is absent."""
    ruff = shutil.which("ruff")
    if ruff is None:
        return None
    proc = subprocess.run(
        [ruff, "check", *map(str, paths)], cwd=REPO,
        capture_output=True, text=True)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    return proc.returncode


def main(argv: list[str] | None = None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    roots = [Path(a) if Path(a).is_absolute() else REPO / a
             for a in args] or [REPO / r for r in DEFAULT_ROOTS]
    files = sorted(p for root in roots
                   for p in ([root] if root.is_file()
                             else root.rglob("*.py")))

    rc = _try_ruff(files)
    if rc is not None:
        return rc

    problems: list[str] = []
    for path in files:
        problems += _lint_file(path)
    if problems:
        print("\n".join(problems))
        print(f"\n{len(problems)} lint problem(s) "
              f"(rules: {', '.join(RULES)}; fallback checker)")
        return 1
    print(f"lint clean: {len(files)} file(s) "
          f"(rules: {', '.join(RULES)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
